"""Shared benchmark harness utilities."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dfedavg, failures as failures_lib, gossip
from repro.core.topology import (complete_adjacency, erdos_renyi_adjacency,
                                 expander_overlay, ring_overlay)
from repro.core.mixing import chow_matrix


def time_call(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds (CPU)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def topology_suite(n: int, degree: int = 3, seed: int = 0):
    """The paper's §5 topology set: ring / expander / ER / complete.

    Returns {name: (mix_fn, bytes_sent_per_client_per_round_weight)} where the
    mix function acts on a client-stacked pytree, and the comm weight is the
    number of neighbors each client ships its model to (paper's comm-cost
    metric: cost = degree x model_bytes).
    """
    out = {}
    ring = ring_overlay(n)
    out["ring"] = (gossip.make_gossip_spec(ring), 2)
    exp = expander_overlay(n, degree, seed=seed)
    out[f"expander-d{degree}"] = (gossip.make_gossip_spec(exp), degree)
    er = erdos_renyi_adjacency(n, seed=seed)
    out["erdos-renyi"] = (chow_matrix(er), float(er.sum() / n))
    comp = complete_adjacency(n)
    out["complete"] = (chow_matrix(comp), n - 1)
    return out


def mix_with(params, mixer):
    if isinstance(mixer, gossip.GossipSpec):
        return gossip.mix_schedules(params, mixer)
    return gossip.mix_dense(params, jnp.asarray(mixer, jnp.float32))


def run_dfl(params, loss_fn, batch_fn, mixer, rounds: int, dcfg,
            eval_fn=None, lr: float | None = None,
            failure_plan: failures_lib.FailurePlan | None = None,
            base_spec: gossip.GossipSpec | None = None):
    """Generic DFL loop over a client-stacked state (benchmark harness)."""

    @jax.jit
    def local_phase(params, batches, lr_val):
        def client(p, b):
            v = jax.tree.map(jnp.zeros_like, p)
            p, _, loss = dfedavg.local_round(p, v, b, loss_fn, dcfg, lr=lr_val)
            return p, loss
        return jax.vmap(client, in_axes=(0, 0))(params, batches)

    history = []
    for rnd in range(rounds):
        batches = batch_fn(rnd)
        params, losses = local_phase(params, batches,
                                     jnp.asarray(lr or dcfg.lr, jnp.float32))
        cur = mixer
        if failure_plan is not None:
            mask = failure_plan.alive_mask(rnd)
            if isinstance(mixer, gossip.GossipSpec):
                # alive-as-data masked engine round (the mask is a traced
                # argument, never baked into the spec)
                params = gossip.mix_packed_stacked(
                    params, mixer, alive=jnp.asarray(mask, jnp.float32))
                cur = None
            else:
                from repro.core.gossip import mix_dense_masked
                params = mix_dense_masked(params, jnp.asarray(mixer), mask)
                cur = None
        if cur is not None:
            params = mix_with(params, cur)
        rec = {"round": rnd, "train_loss": float(jnp.mean(losses))}
        if eval_fn is not None:
            rec.update(eval_fn(params, failure_plan.alive_mask(rnd)
                               if failure_plan else None))
        history.append(rec)
    return params, history


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The required CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def rounds_to_threshold(series, eps: float = 1e-2):
    """Rounds-to-consensus-threshold crossing: first index r with
    ``series[r] <= eps * series[0]`` (series[0] is the pre-mixing value, so
    the index IS the number of rounds applied); None when never crossed."""
    if not len(series):
        return None
    r0 = series[0]
    for r, v in enumerate(series):
        if v <= eps * r0:
            return r
    return None
