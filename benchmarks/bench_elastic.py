"""Elastic-runtime benchmark: churn throughput + recompile accounting.

Drives `ElasticTrainer` (the packed gossip path) through a scripted
`FailurePlan` — healthy rounds, rotating transient stragglers, a permanent
death with splice repair — and reports:

  * rounds/sec per phase (healthy vs straggler-churn vs post-repair);
  * the jit trace count (`n_traces`): straggler churn must add ZERO traces
    (the alive mask is a step argument); each membership change adds exactly
    one. The same guard runs for the **pipelined** trainer (gossip_delay=1):
    the in-flight snapshot is step state, never trace structure;
  * a delayed-vs-sync convergence proxy: final mean-square distance to the
    shared quadratic target after the same scripted churn, gossip_delay=0 vs
    1 — one-round staleness costs a bounded constant, not divergence.

Output: the usual ``name,us_per_call,derived`` CSV rows, plus one JSON
record written to ``<out>/elastic.json`` (default ``experiments/bench/``;
re-runs overwrite it, dryrun-cache style) with the bench JSON schema::

    {"bench": "elastic", "n_clients", "degree", "dim", "rounds",
     "phases": {name: {"rounds", "seconds", "rounds_per_sec"}},
     "n_traces", "expected_traces", "repairs": [{"dead", "n_after"}],
     "plan": [[round, [dead ids]], ...],
     "delayed": {"n_traces", "expected_traces", "rounds_per_sec",
                 "proxy_sync", "proxy_delayed"},
     "chebyshev": {"eps", "cells": {label: {"rounds_to_threshold",
                   "bytes_to_threshold", ...}}, "headline"}}

The ``chebyshev`` panel is the sub_rounds=k timing-axis study: rounds- and
bytes-to-consensus-threshold for ring/expander at k=1 vs k=2 (hard gate:
k=2 Chebyshev on the ring crosses before the plain ring engine); the
summary.json rounds_to_threshold table is fed from these rows.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, rounds_to_threshold
from repro.core import dfedavg, engine as engine_lib, failures
from repro.core.topology import expander_overlay
from repro.launch.elastic import ElasticTrainer


def quad_loss(params, batch):
    return jnp.mean(jnp.square(params["w"] - batch["target"])), {}


def _batches(targets, k):
    return {"target": jnp.broadcast_to(
        targets[:, None], (targets.shape[0], k, targets.shape[1]))}


def run(n_clients: int = 16, degree: int = 4, dim: int = 4096,
        rounds_per_phase: int = 8, seed: int = 0) -> dict:
    r = np.random.default_rng(seed)
    trainer = ElasticTrainer(
        overlay=expander_overlay(n_clients, degree, seed=seed),
        loss_fn=quad_loss,
        dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.1, momentum=0.9),
        straggler_rounds=1, failure_rounds=3)
    params = {"w": jnp.asarray(r.standard_normal((n_clients, dim)),
                               jnp.float32)}
    # the scripted plan: one client starts missing heartbeats at the start
    # of phase 3 and is declared dead after `failure_rounds` misses
    death_round = 2 * rounds_per_phase
    plan = failures.FailurePlan(n_clients=n_clients,
                                events=((death_round, (n_clients // 2,)),))
    orig2cur = np.arange(n_clients)  # original id -> current index (-1 dead)

    def heartbeats(rnd: int, straggler: int | None) -> np.ndarray:
        mask = np.ones(trainer.n_clients, dtype=np.float32)
        for orig in plan.dead_at(rnd):
            if orig2cur[orig] >= 0:
                mask[orig2cur[orig]] = 0.0
        if straggler is not None:
            mask[straggler % trainer.n_clients] = 0.0
        return mask

    phases = {}
    rnd = 0

    def phase(name: str, n_rounds: int, straggler_fn):
        nonlocal rnd, params, orig2cur
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            mask = heartbeats(rnd, straggler_fn(rnd))
            params, _, old2new = trainer.observe_heartbeats(mask, params)
            if old2new is not None:
                alive = orig2cur >= 0
                orig2cur[alive] = old2new[orig2cur[alive]]
            targets = jnp.zeros((trainer.n_clients, dim), jnp.float32)
            params, _ = trainer.step(params, _batches(targets, 2), 0.1)
            rnd += 1
        jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        phases[name] = {"rounds": n_rounds, "seconds": round(dt, 4),
                        "rounds_per_sec": round(n_rounds / dt, 2)}

    phase("healthy", rounds_per_phase, lambda r_: None)
    phase("straggler_churn", rounds_per_phase, lambda r_: r_)  # rotating
    phase("death_and_repair", rounds_per_phase, lambda r_: None)

    # one initial trace + exactly one per membership change (with very short
    # phases the scripted death may not complete — repairs is the truth)
    expected = 1 + len(trainer.repairs)
    rec = {
        "bench": "elastic", "n_clients": n_clients, "degree": degree,
        "dim": dim, "rounds": rnd, "phases": phases,
        "n_traces": trainer.n_traces, "expected_traces": expected,
        "repairs": trainer.repairs,
        "plan": [[int(e[0]), [int(i) for i in e[1]]] for e in plan.events],
    }
    assert trainer.n_traces == expected, (trainer.n_traces, expected)
    rec["delayed"] = run_delayed(n_clients=n_clients, degree=degree, dim=dim,
                                 rounds=2 * rounds_per_phase, seed=seed)
    return rec


def run_delayed(n_clients: int = 16, degree: int = 4, dim: int = 4096,
                rounds: int = 16, seed: int = 0) -> dict:
    """Pipelined (gossip_delay=1) vs synchronous trainer under identical
    straggler churn: retrace guard + convergence proxy + rounds/sec.

    The third line is the **pipelined + quantized** engine composition
    (gossip_codec="int8_block", delay=1): same churn, int8 wire snapshot —
    its retrace count must also stay 1 and its convergence proxy must land
    in the same neighborhood as the f32 pipeline (the int8 error is bounded
    by the per-tile scales, not compounding)."""
    r = np.random.default_rng(seed)
    targets = jnp.zeros((n_clients, dim), jnp.float32)  # consensus: origin
    proxies = {}
    timing = {}
    traces = {}
    for name, delay, codec in (("sync", 0, "f32"), ("delayed", 1, "f32"),
                               ("delayed_quant", 1, "int8_block")):
        trainer = ElasticTrainer(
            overlay=expander_overlay(n_clients, degree, seed=seed),
            loss_fn=quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.2, momentum=0.9),
            straggler_rounds=1, failure_rounds=10**9,
            engine=engine_lib.GossipEngineConfig(
                substrate="stacked", codec=codec, delay=delay))
        params = {"w": jnp.asarray(r.standard_normal((n_clients, dim)),
                                   jnp.float32)}
        rng = np.random.default_rng(seed + 1)
        t0 = time.perf_counter()
        for rnd in range(rounds):
            mask = (rng.random(n_clients) > 0.25).astype(np.float32)
            if mask.sum() < 2:
                mask[:] = 1.0
            params, _, _ = trainer.observe_heartbeats(mask, params)
            params, _ = trainer.step(params, _batches(targets, 2), 0.2)
        jax.block_until_ready(params)
        timing[name] = rounds / (time.perf_counter() - t0)
        proxies[name] = float(jnp.mean(jnp.square(params["w"])))
        traces[name] = trainer.n_traces
        # the pipelined retrace guard: churn is data in EVERY mode,
        # including the quantized pipeline (the CI bench-smoke gate)
        assert trainer.n_traces == 1, (name, trainer.n_traces)
    # the quantized pipeline must not diverge from the f32 pipeline
    assert proxies["delayed_quant"] <= 4 * proxies["delayed"] + 1e-4, proxies
    emit(f"elastic/delayed_vs_sync/n{n_clients}-d{degree}", 0.0,
         f"proxy_sync={proxies['sync']:.6f};"
         f"proxy_delayed={proxies['delayed']:.6f};"
         f"proxy_delayed_quant={proxies['delayed_quant']:.6f};"
         f"rps_sync={timing['sync']:.2f};"
         f"rps_delayed={timing['delayed']:.2f};"
         f"rps_delayed_quant={timing['delayed_quant']:.2f};"
         f"n_traces={traces['delayed']}")
    return {"n_traces": traces["delayed"], "expected_traces": 1,
            "n_traces_quant": traces["delayed_quant"],
            "rounds": rounds,
            "rounds_per_sec": round(timing["delayed"], 2),
            "rounds_per_sec_sync": round(timing["sync"], 2),
            "rounds_per_sec_quant": round(timing["delayed_quant"], 2),
            "proxy_sync": proxies["sync"],
            "proxy_delayed": proxies["delayed"],
            "proxy_delayed_quant": proxies["delayed_quant"]}


def run_chebyshev(n_clients: int = 16, dim: int = 256, rounds: int = 80,
                  eps: float = 1e-2, seed: int = 0) -> dict:
    """Chebyshev timing-axis panel: rounds- and bytes-to-consensus-threshold
    per (overlay family x sub_rounds) cell, pure gossip (no local SGD, so
    the crossing measures the mixing operator alone).

    The headline trade under test: does sub_rounds=2 Chebyshev on the CHEAP
    ring (2 wires/client/sub-round) beat the plain engine on the ring in
    rounds-to-threshold — the hard gate below — and how does it stand next
    to the costlier d=4 expander at k=1 in BYTES-to-threshold (recorded,
    per cell, in the JSON; the summary's rounds_to_threshold table picks
    these rows up)."""
    from repro.core import gossip, packing, spectral
    from repro.overlay import registry

    r = np.random.default_rng(seed)
    init = {"w": jnp.asarray(r.standard_normal((n_clients, dim)),
                             jnp.float32)}
    pack = packing.make_stacked_pack_spec(
        {"w": jax.ShapeDtypeStruct((dim,), jnp.float32)})

    def resid(t):
        w = t["w"]
        return float(jnp.sum(jnp.square(w - w.mean(axis=0, keepdims=True))))

    record = {"eps": eps, "n_clients": n_clients, "dim": dim,
              "max_rounds": rounds, "cells": {}}
    for family, k in (("ring", 1), ("ring", 2),
                      ("expander", 1), ("expander", 2)):
        overlay, meta = registry.build(family, n_clients, degree=4,
                                       seed=seed)
        spec = gossip.make_gossip_spec(overlay)
        ex = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="stacked",
                                          sub_rounds=k), spec)
        # exact wire accounting from the shard_map twin's wire structs
        # (already k-fold for the sub-round loop)
        wire_pr = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="shard_map",
                                          sub_rounds=k),
            spec, axis_names="client",
            pack_spec=pack).wire_bytes_per_round()
        if k > 1:
            cheby = jnp.asarray(ex.cheby_coeffs())
            step = jax.jit(lambda t, c, ex=ex: ex(t, cheby=c))
        else:
            step = jax.jit(lambda t, ex=ex: ex(t))
        x = init
        resids = [resid(x)]
        for _ in range(rounds):
            x = step(x, cheby) if k > 1 else step(x)
            resids.append(resid(x))
            if resids[-1] <= eps * resids[0]:
                break
        rt = rounds_to_threshold(resids, eps)
        label = f"{family}_k{k}"
        record["cells"][label] = {
            "label": label, "family": overlay.name, "sub_rounds": k,
            "lam": round(meta["lam"], 6),
            "cheby_lambda": round(spectral.chebyshev_lambda(meta["lam"], k),
                                  6),
            "rounds_to_threshold": rt,
            "wire_bytes_per_round": wire_pr,
            "bytes_to_threshold": rt * wire_pr if rt is not None else None,
            "resid_first": round(resids[0], 4),
            "resid_last": round(resids[-1], 6),
        }
        emit(f"elastic/chebyshev/{label}/n{n_clients}", 0.0,
             f"rounds_to_threshold={rt};"
             f"bytes_to_threshold={rt * wire_pr if rt is not None else None};"
             f"lam={meta['lam']:.4f};"
             f"wire_bytes_per_round={wire_pr}")
    cells = record["cells"]
    rk1 = cells["ring_k1"]["rounds_to_threshold"]
    rk2 = cells["ring_k2"]["rounds_to_threshold"]
    # the acceptance gate: k=2 Chebyshev on the ring crosses strictly
    # earlier than the plain ring engine
    assert rk2 is not None and (rk1 is None or rk2 < rk1), (rk1, rk2)
    ek1 = cells["expander_k1"]
    record["headline"] = {
        "ring_k2_beats_ring_k1_rounds": True,
        "ring_rounds_k1_vs_k2": [rk1, rk2],
        "ring_k2_vs_expander_k1_rounds":
            [rk2, ek1["rounds_to_threshold"]],
        "ring_k2_vs_expander_k1_bytes":
            [cells["ring_k2"]["bytes_to_threshold"],
             ek1["bytes_to_threshold"]],
    }
    return record


def main(rounds: int = 8, out_dir: str | None = "experiments/bench") -> None:
    rec = run(rounds_per_phase=rounds)
    rec["chebyshev"] = run_chebyshev()
    for name, ph in rec["phases"].items():
        emit(f"elastic/{name}/n{rec['n_clients']}-d{rec['degree']}",
             ph["seconds"] * 1e6 / ph["rounds"],
             f"rounds_per_sec={ph['rounds_per_sec']};"
             f"n_traces={rec['n_traces']}")
    emit(f"elastic/traces/n{rec['n_clients']}-d{rec['degree']}", 0.0,
         f"n_traces={rec['n_traces']};expected={rec['expected_traces']};"
         f"repairs={len(rec['repairs'])}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "elastic.json"), "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()
    main(rounds=args.rounds, out_dir=args.out)
