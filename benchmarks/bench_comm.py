"""Paper's communication-cost panels + the production gossip cost table.

Four views:
  1. algorithmic: bytes shipped per client per round for each topology at the
     paper's model sizes (degree x model bytes) — the paper's bar panels;
  2. packed layout: collective count + padding overhead of the flat-buffer
     gossip payloads, per architecture (smoke AND full-size trees: full pads
     <= 0.003%, smoke 17-38%); the per-arch numbers are also written as a
     JSON record to ``experiments/bench/comm.json``;
  3. pipelined overlap: measured per-round wall-clock of the synchronous vs
     the delay=1 (pipelined) packed gossip round at equal payload, smoke and
     arch-shard sized (same ``comm.json`` record, key ``overlap``);
  4. compiled: per-device wire bytes of the *lowered production gossip* for a
     mid-size LM on the single-pod mesh, dense-mixing vs ppermute vs
     int8-quantized ppermute (from the dry-run JSONs when present);
  5. sparse: top-k + error-feedback gossip (codec="topk_ef") at
     k in {1%, 10%} — exact per-codec wire bytes/round (hard gate: the k=1%
     wire is <= 10% of the dense f32 wire) plus a convergence proxy on the
     stacked consensus cell, with the 10% variant registered through the
     public ``register_codec`` hook (same ``comm.json`` record, key
     ``sparse``);
  6. sparse sweep: rounds-to-consensus-threshold AND mean retention for
     ``topk_ef`` at k_fraction in {0.5%, 1%, 5%, 10%} on the pure-gossip
     stacked cell — the replace-with-sparse EF wire does not preserve the
     network average, so the sweep reports the disagreement crossing
     together with how much of the initial mean survives at that round;
     k_fraction buys retention roughly linearly in wire bytes (same
     ``comm.json`` record, key ``sparse_k_sweep``; the summary.json
     rounds_to_threshold table picks these rows up).
"""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import emit
from repro.core import topology
from repro.roofline import analysis


def algorithmic(n: int = 100, model_bytes: int = 4 * 10**6) -> None:
    entries = {
        "ring": 2.0,
        "expander-d3": 3.0,
        "expander-d4": 4.0,
        "erdos-renyi": float(topology.erdos_renyi_adjacency(n, seed=0).sum() / n),
        "complete": float(n - 1),
    }
    for name, deg in entries.items():
        emit(f"comm/algorithmic/{name}/n{n}", 0.0,
             f"bytes_per_client_per_round={int(deg * model_bytes)};degree={deg:.1f}")


def packed_vs_per_leaf(arch: str = "qwen2.5-3b", d: int = 4) -> None:
    """Collective count / payload structure of packed vs per-leaf gossip for a
    real model's parameter tree (the tentpole's win, measurable offline)."""
    from repro.configs import registry
    from repro.core import packing
    from repro.models import params as params_lib
    from repro.models.api import ModelAPI

    struct = ModelAPI(registry.reduced(arch)).param_struct()
    structs = params_lib.shape_structs(struct)
    spec = packing.make_pack_spec(structs)
    n_leaves = spec.n_leaves
    emit(f"comm/packed_vs_per_leaf/{arch}-smoke/d{d}", 0.0,
         f"leaves={n_leaves};"
         f"permutes_per_round_per_leaf={d * n_leaves};"
         f"permutes_per_round_packed={d * spec.n_buffers};"
         f"payload_MB={spec.payload_bytes / 2**20:.3f};"
         f"padded_MB={spec.padded_bytes / 2**20:.3f};"
         f"pad_overhead={spec.padded_bytes / max(spec.payload_bytes, 1):.3f}x")


def padding_by_arch(out_dir: str | None = "experiments/bench") -> dict:
    """Packed-padding overhead across ALL registered architectures, smoke
    and full size. PackSpecs are host-side (shapes only — no device memory,
    so even the 1T-param tree is cheap to lay out). The claim under test:
    lane/tile padding is a smoke-model artifact; at real sizes the padded
    fraction is negligible, so the packed engine's wire/HBM numbers hold."""
    from repro.configs import registry
    from repro.core import packing
    from repro.models import params as params_lib
    from repro.models.api import ModelAPI

    record = {}
    for arch in registry.ARCH_IDS:
        row = {}
        for label, cfg in (("smoke", registry.reduced(arch)),
                           ("full", registry.get(arch))):
            structs = params_lib.shape_structs(ModelAPI(cfg).param_struct())
            rep = analysis.packing_report(packing.make_pack_spec(structs))
            row[label] = rep
            emit(f"comm/packed_padding/{arch}-{label}", 0.0,
                 f"payload_MB={rep['payload_bytes'] / 2**20:.3f};"
                 f"pad_overhead={rep['pad_overhead']:.5f};"
                 f"buffers={rep['n_buffers']};leaves={rep['n_leaves']}")
        record[arch] = row
    if out_dir:
        _merge_record(out_dir, {"padding_by_arch": record})
    return record


def _merge_record(out_dir: str, updates: dict) -> None:
    """Update keys of experiments/bench/comm.json in place: a direct call to
    one panel must not clobber the keys the other panels wrote (main() and
    the CI artifact rely on both "padding_by_arch" and "overlap")."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "comm.json")
    record = {"bench": "comm"}
    if os.path.exists(path):
        try:
            with open(path) as f:
                record.update(json.load(f))
        except (OSError, json.JSONDecodeError):
            pass  # unreadable cache: rewrite from scratch
    record.update(updates)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def wire_bytes_per_round(dim: int, degree: int) -> dict:
    """Per-client wire bytes one gossip round ships for a ``(dim,)`` f32
    payload, per engine codec (d collectives x the codec's wire buffer —
    the int8 codecs fold their scales INTO the wire, so the overhead rows
    are counted here too)."""
    import jax
    import numpy as np
    from repro.core import engine as engine_lib
    from repro.core import packing

    ps = packing.make_pack_spec(
        {"w": jax.ShapeDtypeStruct((dim,), "float32")})
    out = {}
    for name in engine_lib.CODECS:
        codec = engine_lib.get_codec(name)
        total = 0
        for b in range(ps.n_buffers):
            s = codec.wire_struct(ps.buffer_struct(b), ps.buffer_blocks(b))
            total += int(np.prod(s.shape)) * s.dtype.itemsize
        out[name] = degree * total
    return out


def overlap_speedup(rounds: int = 12, fast: bool = False) -> dict:
    """Measured per-round wall-clock: synchronous f32 vs pipelined
    (delay=1) f32 vs pipelined **int8** (async+quant, the free engine
    composition) packed gossip at equal payload — executed on whatever
    backend is present.

    All modes run the identical stacked engine (vmapped local DFedAvgM +
    packed mixing) on the same (n, dim) payload; only the dataflow and the
    wire codec differ — the delayed rounds' gathers/permutes read the
    carried snapshot (a step input), so the scheduler may run the
    communication under the local-step scan, and the quantized codec ships
    (and carries) 4x fewer wire bytes. On a TPU/ICI backend that turns
    compute + comm into max(compute, comm/4); on a host-CPU run the modes
    do near-identical total work and the ratio mostly reflects the shorter
    critical path, so treat the CPU number as a floor, not the claim. The
    "arch_shard" config sizes the payload like a real per-client gossip
    shard (16M f32 = 64 MiB — the order of a ~1B-param bf16 model split
    over an 8-wide fsdp x tp block), i.e. a non-smoke payload. The JSON
    record also carries the per-codec wire bytes/round (exact, from the
    wire structs).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import dfedavg, engine as engine_lib, gossip
    from repro.core.topology import expander_overlay

    def quad_loss(params, batch):
        return jnp.mean(jnp.square(params["w"] - batch["target"])), {}

    n, d, local_steps = 8, 4, 4
    dcfg = dfedavg.DFedAvgMConfig(local_steps=local_steps, lr=0.05,
                                  momentum=0.9)
    spec = gossip.make_gossip_spec(expander_overlay(n, d, seed=0))
    quant_ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="stacked",
                                      codec="int8_block", delay=1), spec)
    configs = {"smoke": 1 << 16}
    if not fast:
        configs["arch_shard"] = 1 << 24

    def client(p, b, lr):
        v = jax.tree.map(jnp.zeros_like, p)
        p, _, loss = dfedavg.local_round(p, v, b, quad_loss, dcfg, lr=lr)
        return p, loss

    @jax.jit
    def sync_round(params, batches, lr):
        params, losses = jax.vmap(client, in_axes=(0, 0, None))(
            params, batches, lr)
        return gossip.mix_packed_stacked(params, spec), losses

    @jax.jit
    def delayed_round(params, inflight, batches, lr):
        params, losses = jax.vmap(client, in_axes=(0, 0, None))(
            params, batches, lr)
        params, inflight = gossip.mix_packed_stacked_delayed(
            params, inflight, spec)
        return params, inflight, losses

    @jax.jit
    def delayed_quant_round(params, inflight, batches, lr):
        # async+quant: same round, int8 wire snapshot (zero extra code —
        # the composition IS the engine cell)
        params, losses = jax.vmap(client, in_axes=(0, 0, None))(
            params, batches, lr)
        params, inflight = quant_ex(params, state=inflight)
        return params, inflight, losses

    record = {}
    r = np.random.default_rng(0)
    for name, dim in configs.items():
        # the 64 MiB rounds run seconds each on CPU; fewer repeats suffice
        reps = rounds if name == "smoke" else max(5, rounds // 2)
        params0 = {"w": jnp.asarray(r.standard_normal((n, dim)) * 0.1,
                                    jnp.float32)}
        batches = {"target": jnp.zeros((n, local_steps, dim), jnp.float32)}
        lr = jnp.float32(0.05)
        timings = {}

        # rounds run back-to-back (no per-round block: the steady-state
        # driver never blocks, and the pipelined mode's point is exactly the
        # cross-dependency freedom); median over trials absorbs host-timer
        # drift on shared machines
        trials = {"sync": [], "delayed": [], "delayed_quant": []}
        for _trial in range(3):
            p = jax.tree.map(jnp.copy, params0)
            p, _ = sync_round(p, batches, lr)      # warm the jit cache
            jax.block_until_ready(p)
            t0 = time.perf_counter()
            for _ in range(reps):
                p, _ = sync_round(p, batches, lr)
            jax.block_until_ready(p)
            trials["sync"].append((time.perf_counter() - t0) / reps)

            p = jax.tree.map(jnp.copy, params0)
            snap = gossip.pack_state_stacked(p)
            p, snap, _ = delayed_round(p, snap, batches, lr)   # warm
            jax.block_until_ready(p)
            t0 = time.perf_counter()
            for _ in range(reps):
                p, snap, _ = delayed_round(p, snap, batches, lr)
            jax.block_until_ready(p)
            trials["delayed"].append((time.perf_counter() - t0) / reps)

            p = jax.tree.map(jnp.copy, params0)
            qsnap = quant_ex.init_state(p)
            p, qsnap, _ = delayed_quant_round(p, qsnap, batches, lr)  # warm
            jax.block_until_ready(p)
            t0 = time.perf_counter()
            for _ in range(reps):
                p, qsnap, _ = delayed_quant_round(p, qsnap, batches, lr)
            jax.block_until_ready(p)
            trials["delayed_quant"].append((time.perf_counter() - t0) / reps)
        for mode, ts in trials.items():
            timings[mode] = float(np.median(ts))

        speedup = timings["sync"] / timings["delayed"]
        speedup_quant = timings["sync"] / timings["delayed_quant"]
        record[name] = {
            "n_clients": n, "degree": d, "dim": dim,
            "payload_bytes_per_client": dim * 4,
            "wire_bytes_per_round": wire_bytes_per_round(dim, d),
            "local_steps": local_steps, "rounds": reps,
            "sync_s_per_round": round(timings["sync"], 5),
            "delayed_s_per_round": round(timings["delayed"], 5),
            "delayed_quant_s_per_round": round(timings["delayed_quant"], 5),
            "speedup": round(speedup, 4),
            "speedup_quant": round(speedup_quant, 4),
            "backend": jax.default_backend(),
        }
        emit(f"comm/overlap/{name}/n{n}-d{d}-dim{dim}",
             timings["delayed"] * 1e6,
             f"sync_us={timings['sync'] * 1e6:.0f};"
             f"speedup={speedup:.3f}x;"
             f"speedup_quant={speedup_quant:.3f}x;"
             f"payload_MB_per_client={dim * 4 / 2**20:.1f};"
             f"backend={jax.default_backend()}")
        del p, snap, qsnap
    return record


def sparse_convergence(rounds: int = 20, fast: bool = False, n: int = 8,
                       degree: int = 2, dim: int = 4096) -> dict:
    """Top-k + EF gossip: wire acceptance gate + convergence proxy.

    Registers the 10% variant through the PUBLIC registry hook — after
    ``register_codec`` the name is a first-class codec everywhere (the
    trainers' ``engine=`` front door below, and the wire accounting) — and
    hard-asserts the ISSUE acceptance: the k=1% topk_ef wire ships <= 10%
    of the dense f32 bytes per round. The proxy column is final mean-square
    distance to the consensus target after identical stacked rounds; EF
    keeps the sparse cells contracting (each must end below where it
    started), and every cell keeps the one-executable guard."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core import dfedavg, engine as engine_lib
    from repro.core.topology import expander_overlay
    from repro.launch.elastic import ElasticTrainer

    if "topk_ef_k10" not in engine_lib.CODECS:
        engine_lib.register_codec(
            "topk_ef_k10", engine_lib.TopKEFCodec(0.1, name="topk_ef_k10"))

    rounds = max(6, rounds // 2) if fast else rounds
    wire = wire_bytes_per_round(dim, degree)
    ratios = {name: wire[name] / wire["f32"]
              for name in ("topk_ef", "topk_ef_k10")}
    assert ratios["topk_ef"] <= 0.10, (
        f"topk_ef (k=1%) wire must be <= 10% of f32: {ratios['topk_ef']}")

    def quad_loss(params, batch):
        return jnp.mean(jnp.square(params["w"] - batch["target"])), {}

    r = np.random.default_rng(0)
    init = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
    init_msd = float(jnp.mean(jnp.square(init)))
    batches = {"target": jnp.zeros((n, 2, dim), jnp.float32)}
    proxies = {}
    for codec in ("f32", "topk_ef_k10", "topk_ef"):
        trainer = ElasticTrainer(
            overlay=expander_overlay(n, degree, seed=0), loss_fn=quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.2, momentum=0.9),
            failure_rounds=10**9,
            engine=engine_lib.GossipEngineConfig(substrate="stacked",
                                                 codec=codec))
        params = {"w": init}
        for _ in range(rounds):
            params, _ = trainer.step(params, batches, 0.2)
        proxies[codec] = float(jnp.mean(jnp.square(params["w"])))
        assert trainer.n_traces == 1, (codec, trainer.n_traces)
        assert np.isfinite(proxies[codec]) and proxies[codec] < init_msd, (
            codec, proxies[codec], init_msd)
        emit(f"comm/sparse/{codec}/n{n}-d{degree}-dim{dim}", 0.0,
             f"proxy={proxies[codec]:.3e};"
             f"wire_bytes_per_round={wire[codec]};"
             f"wire_ratio_vs_f32={wire[codec] / wire['f32']:.4f}")
    return {"n_clients": n, "degree": degree, "dim": dim, "rounds": rounds,
            "init_msd": round(init_msd, 6),
            "wire_bytes_per_round": wire,
            "wire_ratio_vs_f32": {k: round(v, 4) for k, v in ratios.items()},
            "proxy": proxies}


def sparse_k_sweep(max_rounds: int = 120, fast: bool = False, n: int = 16,
                   degree: int = 4, dim: int = 16384,
                   eps: float = 2e-2) -> dict:
    """Satellite: topk_ef sparsity sweep — rounds-to-consensus-threshold AND
    mean retention at k_fraction in {0.5%, 1%, 5%, 10%}, pure gossip.

    Each cell runs the stacked engine with a registered TopKEFCodec variant
    on the same random client states (no local SGD: the crossing measures
    the sparse mixing operator + error feedback alone). Two axes per cell:

    * ``rounds_to_threshold`` — first round where the disagreement residual
      sum ||x_i - mean(x)||^2 drops below ``eps`` of its start;
    * ``mean_keep_at_rt`` — <mean(x_r), mean(x_0)> / ||mean(x_0)||^2 at
      that round. Dense gossip keeps this at exactly 1.0; the
      replace-with-sparse EF wire shrinks unshipped coordinates toward
      zero, so sparse cells cross the raw disagreement threshold partly by
      destroying the average. Retention is what k_fraction buys: it grows
      monotonically with the wire bytes (~0.07 at 0.5% up to ~0.69 at 10%
      on the default cell), which IS the study's headline — raw crossings
      alone would crown the sparsest wire for agreeing on a shrunken model.

    Gates: every cell keeps the one-executable guard, wire bytes are
    strictly monotone in k_fraction and below the dense f32 wire, every
    cell crosses, the f32 cell keeps the mean exactly, and retention at
    the crossing is strictly increasing in k_fraction.

    ``dim`` defaults to 16384 so even the 0.5% wire is genuinely lossy —
    at small dims the pack-padding floor makes the top-k wire larger than
    the payload and decode(encode(x)) == x bitwise, degenerating every
    sparse cell into the f32 reference."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from benchmarks.common import rounds_to_threshold
    from repro.core import engine as engine_lib, gossip
    from repro.core.topology import expander_overlay
    from repro.telemetry import TraceCounter

    fractions = (0.005, 0.01, 0.05, 0.1)
    max_rounds = max(30, max_rounds // 3) if fast else max_rounds
    names = {}
    for frac in fractions:
        name = f"topk_ef_k{frac:g}".replace(".", "p")
        if name not in engine_lib.CODECS:
            engine_lib.register_codec(
                name, engine_lib.TopKEFCodec(frac, name=name))
        names[frac] = name
    # registration above must precede the accounting: wire_bytes_per_round
    # walks engine_lib.CODECS at call time
    wire = wire_bytes_per_round(dim, degree)
    assert all(wire[names[a]] < wire[names[b]]
               for a, b in zip(fractions, fractions[1:])), wire
    assert wire[names[fractions[-1]]] < wire["f32"], wire

    spec = gossip.make_gossip_spec(expander_overlay(n, degree, seed=0))
    r = np.random.default_rng(0)
    w0 = np.asarray(r.standard_normal((n, dim)), np.float32)
    init = {"w": jnp.asarray(w0)}
    mean0 = w0.mean(axis=0, keepdims=True)
    mean0_sq = float(np.vdot(mean0, mean0))

    def stats(t):
        w = np.asarray(t["w"])
        m = w.mean(axis=0, keepdims=True)
        disagree = float(np.sum(np.square(w - m)))
        keep = float(np.vdot(m, mean0)) / mean0_sq
        return disagree, keep

    record = {"eps": eps, "n_clients": n, "degree": degree, "dim": dim,
              "max_rounds": max_rounds, "cells": {}}
    for frac in (None,) + fractions:  # None = the dense f32 reference
        codec = "f32" if frac is None else names[frac]
        ex = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="stacked", codec=codec),
            spec)
        if ex.stateful:
            step = jax.jit(lambda t, cs, ex=ex: ex(t, codec_state=cs))
            cstate = ex.init_codec_state(init)
        else:
            step = jax.jit(lambda t, ex=ex: ex(t))
            cstate = None
        x = init
        d, kp = stats(x)
        resids, keeps = [d], [kp]
        for _ in range(max_rounds):
            if cstate is None:
                x = step(x)
            else:
                x, cstate = step(x, cstate)
            d, kp = stats(x)
            resids.append(d)
            keeps.append(kp)
            if d <= eps * resids[0]:
                break
        assert TraceCounter.cache_size(step) == 1, codec
        rt = rounds_to_threshold(resids, eps)
        label = "f32" if frac is None else f"k{frac:g}"
        keep_at_rt = keeps[rt] if rt is not None else None
        record["cells"][label] = {
            "label": f"sparse_sweep_{label}", "codec": codec,
            "k_fraction": frac,
            "rounds_to_threshold": rt,
            "wire_bytes_per_round": wire[codec],
            "bytes_to_threshold": (rt * wire[codec] if rt is not None
                                   else None),
            "mean_keep_at_rt": (round(keep_at_rt, 4)
                                if keep_at_rt is not None else None),
            "mean_keep_last": round(keeps[-1], 4),
            "resid_first": round(resids[0], 4),
            "resid_last": round(resids[-1], 6),
        }
        emit(f"comm/sparse_k_sweep/{label}/n{n}-d{degree}-dim{dim}", 0.0,
             f"rounds_to_threshold={rt};"
             f"wire_bytes_per_round={wire[codec]};"
             f"bytes_to_threshold="
             f"{rt * wire[codec] if rt is not None else None};"
             f"mean_keep_at_rt="
             f"{None if keep_at_rt is None else round(keep_at_rt, 4)}")
    cells = record["cells"]
    assert cells["f32"]["rounds_to_threshold"] is not None
    assert abs(cells["f32"]["mean_keep_at_rt"] - 1.0) < 1e-3, cells["f32"]
    keeps_by_k = []
    for frac in fractions:
        cell = cells[f"k{frac:g}"]
        assert cell["rounds_to_threshold"] is not None, (frac, cell)
        keeps_by_k.append(cell["mean_keep_at_rt"])
    # retention is the monotone axis: more wire, more of the average kept
    assert all(a < b for a, b in zip(keeps_by_k, keeps_by_k[1:])), keeps_by_k
    assert keeps_by_k[-1] < 0.99, keeps_by_k
    return record


def compiled(dryrun_dir: str = "experiments/dryrun") -> None:
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*train_4k*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        r = rec["roofline"]
        emit(f"comm/compiled/{rec['arch']}/{rec['mesh']}"
             + (f"/{rec['label']}" if rec.get("label") else ""),
             0.0,
             f"wire_MB_per_dev={r['wire_bytes']/2**20:.1f};"
             f"permute_MB={r['collectives']['collective-permute']/2**20:.1f};"
             f"allreduce_MB={r['collectives']['all-reduce']/2**20:.1f};"
             f"allgather_MB={r['collectives']['all-gather']/2**20:.1f};"
             f"gossip={rec.get('gossip_impl')}")


def main(fast: bool = False, out_dir: str | None = "experiments/bench") -> None:
    algorithmic()
    packed_vs_per_leaf()
    padding = padding_by_arch(out_dir=None)
    overlap = overlap_speedup(rounds=6 if fast else 12, fast=fast)
    sparse = sparse_convergence(fast=fast)
    sweep = sparse_k_sweep(fast=fast)
    if out_dir:
        _merge_record(out_dir, {"padding_by_arch": padding,
                                "overlap": overlap,
                                "sparse": sparse,
                                "sparse_k_sweep": sweep})
    compiled()


if __name__ == "__main__":
    main()
