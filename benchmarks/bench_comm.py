"""Paper's communication-cost panels + the production gossip cost table.

Three views:
  1. algorithmic: bytes shipped per client per round for each topology at the
     paper's model sizes (degree x model bytes) — the paper's bar panels;
  2. packed layout: collective count + padding overhead of the flat-buffer
     gossip payloads, per architecture (smoke AND full-size trees — the
     ROADMAP follow-up: smoke models pad ~17%, real archs must be <<1%);
     the per-arch numbers are also written as a JSON record to
     ``experiments/bench/comm.json``;
  3. compiled: per-device wire bytes of the *lowered production gossip* for a
     mid-size LM on the single-pod mesh, dense-mixing vs ppermute vs
     int8-quantized ppermute (from the dry-run JSONs when present).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.core import topology
from repro.core.mixing import chow_matrix
from repro.roofline import analysis


def algorithmic(n: int = 100, model_bytes: int = 4 * 10**6) -> None:
    entries = {
        "ring": 2.0,
        "expander-d3": 3.0,
        "expander-d4": 4.0,
        "erdos-renyi": float(topology.erdos_renyi_adjacency(n, seed=0).sum() / n),
        "complete": float(n - 1),
    }
    for name, deg in entries.items():
        emit(f"comm/algorithmic/{name}/n{n}", 0.0,
             f"bytes_per_client_per_round={int(deg * model_bytes)};degree={deg:.1f}")


def packed_vs_per_leaf(arch: str = "qwen2.5-3b", d: int = 4) -> None:
    """Collective count / payload structure of packed vs per-leaf gossip for a
    real model's parameter tree (the tentpole's win, measurable offline)."""
    from repro.configs import registry
    from repro.core import packing
    from repro.models import params as params_lib
    from repro.models.api import ModelAPI

    struct = ModelAPI(registry.reduced(arch)).param_struct()
    structs = params_lib.shape_structs(struct)
    spec = packing.make_pack_spec(structs)
    n_leaves = spec.n_leaves
    emit(f"comm/packed_vs_per_leaf/{arch}-smoke/d{d}", 0.0,
         f"leaves={n_leaves};"
         f"permutes_per_round_per_leaf={d * n_leaves};"
         f"permutes_per_round_packed={d * spec.n_buffers};"
         f"payload_MB={spec.payload_bytes / 2**20:.3f};"
         f"padded_MB={spec.padded_bytes / 2**20:.3f};"
         f"pad_overhead={spec.padded_bytes / max(spec.payload_bytes, 1):.3f}x")


def padding_by_arch(out_dir: str | None = "experiments/bench") -> None:
    """Packed-padding overhead across ALL registered architectures, smoke
    and full size. PackSpecs are host-side (shapes only — no device memory,
    so even the 1T-param tree is cheap to lay out). The claim under test:
    lane/tile padding is a smoke-model artifact; at real sizes the padded
    fraction is negligible, so the packed engine's wire/HBM numbers hold."""
    from repro.configs import registry
    from repro.core import packing
    from repro.models import params as params_lib
    from repro.models.api import ModelAPI

    record = {}
    for arch in registry.ARCH_IDS:
        row = {}
        for label, cfg in (("smoke", registry.reduced(arch)),
                           ("full", registry.get(arch))):
            structs = params_lib.shape_structs(ModelAPI(cfg).param_struct())
            rep = analysis.packing_report(packing.make_pack_spec(structs))
            row[label] = rep
            emit(f"comm/packed_padding/{arch}-{label}", 0.0,
                 f"payload_MB={rep['payload_bytes'] / 2**20:.3f};"
                 f"pad_overhead={rep['pad_overhead']:.5f};"
                 f"buffers={rep['n_buffers']};leaves={rep['n_leaves']}")
        record[arch] = row
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "comm.json"), "w") as f:
            json.dump({"bench": "comm", "padding_by_arch": record}, f,
                      indent=1)


def compiled(dryrun_dir: str = "experiments/dryrun") -> None:
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*train_4k*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            continue
        r = rec["roofline"]
        emit(f"comm/compiled/{rec['arch']}/{rec['mesh']}"
             + (f"/{rec['label']}" if rec.get("label") else ""),
             0.0,
             f"wire_MB_per_dev={r['wire_bytes']/2**20:.1f};"
             f"permute_MB={r['collectives']['collective-permute']/2**20:.1f};"
             f"allreduce_MB={r['collectives']['all-reduce']/2**20:.1f};"
             f"allgather_MB={r['collectives']['all-gather']/2**20:.1f};"
             f"gossip={rec.get('gossip_impl')}")


def main() -> None:
    algorithmic()
    packed_vs_per_leaf()
    padding_by_arch()
    compiled()


if __name__ == "__main__":
    main()
