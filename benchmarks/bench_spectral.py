"""Paper §3 theory table: lambda / kappa / C_lambda / mixing time per topology.

Numerically regenerates the paper's connectivity-vs-cost comparison
(ring quadratic blowup, ER log-degree, expander constant-degree bounded
lambda) across network sizes.
"""
from __future__ import annotations

import time

from repro.core import spectral, topology
from repro.core.mixing import chow_matrix
from benchmarks.common import emit


def rows(sizes=(16, 64, 100, 256)) -> list[dict]:
    out = []
    for n in sizes:
        entries = {
            "ring": topology.ring_overlay(n).simple_adjacency(),
            "expander-d3": topology.expander_overlay(n, 3, seed=0).simple_adjacency(),
            "expander-d4": topology.expander_overlay(n, 4, seed=0).simple_adjacency(),
            "erdos-renyi": topology.erdos_renyi_adjacency(n, seed=0),
            "complete": topology.complete_adjacency(n),
        }
        for name, adj in entries.items():
            kap = spectral.kappa(adj)
            lam = spectral.mixing_lambda(chow_matrix(adj))
            out.append({
                "n": n, "topology": name,
                "degree": float(adj.sum() / n),
                "kappa": kap,
                "lambda": lam,
                "c_lambda": spectral.c_lambda(lam),
                "t_mix_1e3": spectral.mixing_time(lam),
            })
    return out


def main() -> None:
    t0 = time.perf_counter()
    table = rows()
    us = (time.perf_counter() - t0) * 1e6 / len(table)
    for r in table:
        emit(f"spectral/{r['topology']}/n{r['n']}", us,
             f"deg={r['degree']:.1f};lambda={r['lambda']:.4f};"
             f"kappa={r['kappa']:.1f};Tmix={r['t_mix_1e3']:.1f}")
    # headline check mirrored from the paper: expander lambda ~ constant in n
    lams = [r["lambda"] for r in table if r["topology"] == "expander-d4"]
    rings = [r["lambda"] for r in table if r["topology"] == "ring"]
    emit("spectral/summary", us,
         f"expander_lam_range=({min(lams):.3f},{max(lams):.3f});"
         f"ring_lam_at_max_n={rings[-1]:.5f}")


if __name__ == "__main__":
    main()
