"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV rows.
Mapping to the paper:
  bench_spectral  -> §3 connectivity theory comparison (Fig. 2 + bounds)
  bench_mnist     -> Figs. 4 (IID) and 5 (non-IID)
  bench_lm        -> Fig. 6 (Shakespeare LM)
  bench_failures  -> Figs. 7 & 8 (10%/20% client failures)
  bench_comm      -> communication-cost panels (+ compiled gossip bytes,
                     topk_ef k_fraction sweep: crossing + mean retention)
  bench_kernels   -> Pallas kernel traffic models (TPU target)
  bench_elastic   -> elastic runtime churn throughput + recompile count +
                     the Chebyshev sub-round panel (rounds/bytes-to-
                     threshold, ring k=2 vs expander k=1; JSON record to
                     experiments/bench/)
  bench_overlay   -> overlay-lab Pareto sweep: spectral gap vs degree vs
                     packed mixing rounds/sec per graph family, static and
                     one-peer time-varying (JSON record to experiments/bench/)
  bench_robust    -> Byzantine screens vs scripted attackers: convergence
                     proxy over f x screen x topology, per-round screen
                     overhead, zero-retrace guard under attacker churn
                     (JSON record to experiments/bench/robust.json)
  bench_telemetry -> telemetry on/off overhead gate + per-codec wire bytes
                     + event-stream completeness; folds every bench JSON +
                     the run stream into experiments/bench/summary.json
                     (run LAST so the summary sees the other records)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer rounds (CI-sized)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_comm, bench_elastic, bench_failures,
                            bench_kernels, bench_lm, bench_mnist,
                            bench_overlay, bench_robust, bench_spectral,
                            bench_telemetry)

    rounds = 6 if args.fast else 10
    suite = [
        ("spectral", lambda: bench_spectral.main()),
        ("kernels", lambda: bench_kernels.main()),
        ("comm", lambda: bench_comm.main(fast=args.fast)),
        ("overlay", lambda: bench_overlay.main(rounds=3 * rounds)),
        ("mnist", lambda: bench_mnist.main(rounds=rounds)),
        ("lm", lambda: bench_lm.main(rounds=rounds + 4)),
        ("failures", lambda: bench_failures.main(rounds=rounds)),
        ("elastic", lambda: bench_elastic.main(rounds=rounds)),
        ("robust", lambda: bench_robust.main(rounds=rounds)),
        # keep last: its summary.json folds in the records written above
        ("telemetry", lambda: bench_telemetry.main(rounds=rounds)),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suite:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
