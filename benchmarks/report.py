"""Render EXPERIMENTS.md tables from the dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.report [--dir experiments/dryrun]
        [--label ""] [--what dryrun|roofline|candidates]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.roofline import hw

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["stablelm-12b", "gemma2-2b", "qwen2-72b", "qwen2.5-3b",
              "grok-1-314b", "kimi-k2-1t-a32b", "musicgen-medium",
              "rwkv6-1.6b", "internvl2-1b", "zamba2-2.7b"]


def load(dir_: str, label: str = "") -> list[dict]:
    recs = []
    seen_skips = set()
    for path in glob.glob(os.path.join(dir_, "*.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("skipped"):
            key = (rec["arch"], rec["shape"], rec["mesh"])
            if key in seen_skips:
                continue
            seen_skips.add(key)
        elif rec.get("label", "") != label:
            continue
        recs.append(rec)
    recs.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"]), r["mesh"]))
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dominant_fraction(r: dict) -> float:
    """useful-time / dominant-term: how close the dominant term is to the
    analytic lower bound for that term. For compute-dominated cells this is
    MFU-at-the-bound; for others it is the fraction of the dominant term that
    is 'useful' compute."""
    roof = r["roofline"]
    dom = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
    useful_s = r["model_flops_per_chip"] / hw.PEAK_FLOPS_BF16
    return useful_s / dom if dom else 0.0


def table_dryrun(recs):
    print("| arch | shape | mesh | peak GiB/chip | fits 16G | lower s | compile s | clients | gossip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | — | — | SKIP: {r['skipped'][:40]}… |")
            continue
        m = r["memory"]
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt_bytes(m['peak_bytes'])} | {'Y' if m['fits_16g'] else 'N'} "
              f"| {r['seconds_lower']} | {r['seconds_compile']} "
              f"| {r.get('n_clients', '—')} | {r.get('gossip_impl', '—')} |")


def table_roofline(recs, mesh="single"):
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| MODEL_TF/chip | HLO_TF/chip | useful ratio | frac of roofline | one-liner |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("skipped") or r["mesh"] != mesh:
            continue
        roof = r["roofline"]
        frac = dominant_fraction(r)
        hint = {
            "compute": "cut redundant FLOPs (remat/mask waste) or shard wider",
            "memory": "raise arithmetic intensity: fuse, larger tiles, bf16",
            "collective": "reshard to kill all-gathers / overlap gossip",
        }[roof["dominant"]]
        print(f"| {r['arch']} | {r['shape']} "
              f"| {roof['compute_s']:.3f} | {roof['memory_s']:.3f} "
              f"| {roof['collective_s']:.3f} | **{roof['dominant']}** "
              f"| {r['model_flops_per_chip']/1e12:.2f} | {roof['flops']/1e12:.2f} "
              f"| {r['useful_flop_ratio']:.3f} | {frac:.3f} | {hint} |")


def candidates(recs):
    live = [r for r in recs if not r.get("skipped") and r["mesh"] == "single"]
    by_coll = max(live, key=lambda r: r["roofline"]["collective_s"]
                  / max(sum([r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                             r["roofline"]["collective_s"]]), 1e-12))
    by_frac = min(live, key=dominant_fraction)
    print("most collective-bound:", by_coll["arch"], by_coll["shape"],
          by_coll["roofline"]["collective_s"])
    print("worst roofline fraction:", by_frac["arch"], by_frac["shape"],
          dominant_fraction(by_frac))
    over = [(r["arch"], r["shape"], r["mesh"],
             round(r["memory"]["peak_bytes"] / 2**30, 1))
            for r in recs if not r.get("skipped")
            and not r["memory"]["fits_16g"]]
    print("cells over 16GiB:", len(over))
    for o in over:
        print("   ", o)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--label", default="")
    ap.add_argument("--what", default="candidates",
                    choices=["dryrun", "roofline", "candidates"])
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir, args.label)
    if args.what == "dryrun":
        table_dryrun(recs)
    elif args.what == "roofline":
        table_roofline(recs, args.mesh)
    else:
        candidates(recs)


if __name__ == "__main__":
    main()
