"""Overlay-lab Pareto sweep: spectral gap vs degree vs mixing throughput.

For every registered graph family (plus degree variants where the family is
parameterized) this builds the overlay at a common n, records the theory
numbers (Chow lambda, spectral gap, kappa, mixing time), and measures the
*executed* mixing throughput of the packed engine on a synthetic
client-stacked state — both the static all-schedules round and the one-peer
time-varying round (gates-as-data: both share one jitted executable, and the
trace count is asserted).

The Pareto story the sweep renders: degree buys spectral gap (fewer rounds
to consensus) but costs per-round collectives; time-varying plans move along
that frontier at runtime without recompiling.

Output: the usual ``name,us_per_call,derived`` CSV rows plus one JSON record
at ``<out>/overlay.json`` (re-runs overwrite, dryrun-cache style)::

    {"bench": "overlay", "n", "dim", "rounds",
     "families": [{family, n_schedules, degree_max, lam, spectral_gap,
                   kappa, mixing_time_1e3, rounds_per_sec,
                   rounds_per_sec_one_peer, n_traces}, ...]}
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import gossip
from repro.overlay import plan as plan_lib, registry
from repro.telemetry import TraceCounter

# (family, degree) cells; degree is ignored by fixed-degree families
SWEEP: tuple[tuple[str, int], ...] = (
    ("ring", 2),
    ("torus", 4),
    ("hypercube", 0),
    ("expander", 4),
    ("expander", 6),
    ("random_regular", 4),
    ("random_regular", 6),
    ("onepeer_exp", 0),
    ("erdos_renyi", 0),
    ("complete", 0),
)


def _time_rounds(fn, params, gates_fn, rounds: int) -> float:
    """Seconds for `rounds` mixing rounds (jit warm; gates rebuilt per round
    exactly as a real driver would)."""
    out = fn(params, gates_fn(0))
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for rnd in range(rounds):
        out = fn(out, gates_fn(rnd))
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def run(n: int = 32, dim: int = 1 << 16, rounds: int = 30,
        seed: int = 0) -> dict:
    r = np.random.default_rng(seed)
    params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
    rows = []
    for family, degree in SWEEP:
        overlay, meta = registry.build(family, n, degree=max(degree, 2),
                                       seed=seed)
        spec = gossip.make_gossip_spec(overlay)
        tracer = TraceCounter(f"overlay/{family}")

        @jax.jit
        @tracer.wrap
        def mix(p, gates, spec=spec):
            return gossip.mix_packed_stacked(p, spec, gates=gates)

        s_count = spec.degree
        ones = lambda rnd: jnp.ones(s_count, jnp.float32)
        one_peer = plan_lib.OnePeerPlan()
        rotate = lambda rnd: jnp.asarray(one_peer.gates(rnd, s_count))

        dt_static = _time_rounds(mix, params, ones, rounds)
        dt_onepeer = _time_rounds(mix, params, rotate, rounds)
        tracer.expect(1, what=f"{family} gates-are-data")

        label = (f"{family}-d{degree}" if degree else family)
        row = dict(meta, label=label,
                   rounds_per_sec=round(rounds / dt_static, 2),
                   rounds_per_sec_one_peer=round(rounds / dt_onepeer, 2),
                   n_traces=tracer.count)
        rows.append(row)
        emit(f"overlay/{label}/n{n}", dt_static * 1e6 / rounds,
             f"spectral_gap={row['spectral_gap']:.4f};"
             f"n_schedules={row['n_schedules']};"
             f"lam={row['lam']:.4f};"
             f"rounds_per_sec={row['rounds_per_sec']};"
             f"one_peer_rounds_per_sec={row['rounds_per_sec_one_peer']};"
             f"mixing_time={row['mixing_time_1e3']:.1f}")
    return {"bench": "overlay", "n": n, "dim": dim, "rounds": rounds,
            "families": rows}


# sparse-schedule families only: at O(10^3-10^4) clients the dense-matrix
# baselines (complete / erdos_renyi / onepeer_exp) would measure an (n, n)
# matmul, not the overlay engine; random_regular duplicates expander's cell
SCALE_SWEEP: tuple[tuple[str, int], ...] = (
    ("ring", 2),
    ("torus", 4),
    ("hypercube", 0),
    ("expander", 4),
    ("expander", 6),
)


def run_scale(n: int = 4096, dim: int = 512, rounds: int = 5,
              seed: int = 0) -> dict:
    """The massive-client Pareto: spectral gap vs executed rounds/sec at
    O(10^3-10^4) clients on the stacked substrate (single host; the blocked
    cell's cross-device cost at this n is bench_scale's job). The per-client
    slice packs with block_rows=8, shrinking the Pallas-tile padding floor
    so 4096 tiny clients stay a few MB of state."""
    from repro.core import packing

    r = np.random.default_rng(seed)
    params = {"w": jnp.asarray(r.standard_normal((n, dim)) * 0.02,
                               jnp.float32)}
    pack = packing.make_stacked_pack_spec(params, block_rows=8)
    rows = []
    for family, degree in SCALE_SWEEP:
        overlay, meta = registry.build(family, n, degree=max(degree, 2),
                                       seed=seed)
        spec = gossip.make_gossip_spec(overlay)
        tracer = TraceCounter(f"overlay_scale/{family}")

        @jax.jit
        @tracer.wrap
        def mix(p, gates, spec=spec):
            return gossip.mix_packed_stacked(p, spec, gates=gates,
                                             pack_spec=pack)

        ones = lambda rnd: jnp.ones(spec.degree, jnp.float32)
        dt = _time_rounds(mix, params, ones, rounds)
        tracer.expect(1, what=f"{family} gates-are-data")

        label = (f"{family}-d{degree}" if degree else family)
        row = dict(meta, label=label,
                   rounds_per_sec=round(rounds / dt, 3),
                   n_traces=tracer.count)
        rows.append(row)
        emit(f"overlay_scale/{label}/n{n}", dt * 1e6 / rounds,
             f"spectral_gap={row['spectral_gap']:.4f};"
             f"n_schedules={row['n_schedules']};"
             f"rounds_per_sec={row['rounds_per_sec']};"
             f"mixing_time={row['mixing_time_1e3']:.1f}")
    return {"n": n, "dim": dim, "rounds": rounds, "families": rows}


def main(rounds: int = 30, out_dir: str | None = "experiments/bench",
         scale: bool = False, scale_n: int = 4096) -> None:
    rec = run(rounds=rounds)
    if scale:
        rec["scale"] = run_scale(n=scale_n)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "overlay.json"), "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--out", default="experiments/bench")
    ap.add_argument("--scale", action="store_true",
                    help="add the massive-client Pareto (n=4096) to the record")
    ap.add_argument("--scale-n", type=int, default=4096)
    args = ap.parse_args()
    main(rounds=args.rounds, out_dir=args.out, scale=args.scale,
         scale_n=args.scale_n)
