"""Paper Figs. 7 & 8: robustness to client failures (10% / 20% drops).

Non-IID MNIST MLP; clients are dropped mid-training and excluded from
results; the mixing renormalizes over alive in-neighbors (the paper's masked
protocol). Compares ring / expander / complete.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, run_dfl, topology_suite
from repro.core import dfedavg, failures
from repro.data import federated, mnist, pipeline
from repro.models import mlp
from repro.models.params import init_params

N_CLIENTS = 10


def run(drop_fraction: float, rounds: int = 10, seed: int = 0) -> list[dict]:
    tr, te = mnist.make_mnist_like(4000, 800, seed=0)
    parts = federated.label_shard_split(tr.y, N_CLIENTS, seed=seed)
    batcher = pipeline.ClientBatcher(tr.x, tr.y, parts, batch_size=20,
                                     local_steps=3, seed=seed)
    dcfg = dfedavg.DFedAvgMConfig(local_steps=3, lr=0.05, momentum=0.9)
    struct = mlp.param_struct()
    init = jax.vmap(lambda i: init_params(struct, jax.random.key(0)))(
        jnp.arange(N_CLIENTS))
    plan = failures.sample_failures(N_CLIENTS, drop_fraction, at_round=3,
                                    seed=seed)
    tex, tey = jnp.asarray(te.x), jnp.asarray(te.y)

    def eval_fn(params, alive):
        # average over ALIVE clients (dropped nodes excluded, per the paper)
        accs = []
        for c in range(N_CLIENTS):
            if alive is not None and alive[c] == 0:
                continue
            pc = jax.tree.map(lambda x: x[c], params)
            _, aux = mlp.loss_fn(pc, {"x": tex, "y": tey})
            accs.append(float(aux["acc"]))
        return {"test_acc": sum(accs) / len(accs)}

    def batch_fn(rnd):
        b = batcher.round_batches(rnd)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    out = []
    suite = topology_suite(N_CLIENTS, degree=3, seed=seed)
    suite.pop("erdos-renyi", None)
    for name, (mixer, _deg) in suite.items():
        t0 = time.perf_counter()
        _, hist = run_dfl(init, lambda p, b: mlp.loss_fn(p, b), batch_fn,
                          mixer, rounds, dcfg, eval_fn=eval_fn,
                          failure_plan=plan)
        out.append({"topology": name, "drop": drop_fraction,
                    "final_acc": hist[-1]["test_acc"],
                    "seconds": time.perf_counter() - t0, "rounds": rounds})
    return out


def main(rounds: int = 10) -> None:
    for frac in (0.1, 0.2):
        for r in run(frac, rounds=rounds):
            emit(f"failures/{int(frac*100)}pct/{r['topology']}",
                 r["seconds"] * 1e6 / r["rounds"],
                 f"final_acc={r['final_acc']:.3f}")


if __name__ == "__main__":
    main()
