"""Telemetry-overhead smoke — the CI guard for the observability layer.

Drives the SAME ElasticTrainer cell with telemetry OFF and ON (engine
round metrics + JSONL event stream) and hard-asserts:

  * the overhead tolerance: with in-graph metrics on (no event stream)
    the round keeps at least ``MIN_SPEED_RATIO`` of the plain rounds/sec
    (the metrics ride values the round already materializes; CPU timing
    is noisy, so the gate is deliberately loose — the real regression
    guard is the zero-added-collectives HLO assert in
    tests/test_telemetry.py).  The streamed cell (JSONL logger attached)
    is *reported, not gated*: the per-round record is a deliberate
    device->host sync, the cost of reading the numbers;
  * zero retraces with telemetry on, under straggler churn + one-peer gate
    rotation (churn/gates/metrics are data, never trace structure);
  * the event stream arrives complete: one run header, one compile event,
    one round record per round, each with the consensus residual.

Records the exact per-codec wire bytes/round from the engine's
``wire_struct`` accounting, writes ``experiments/bench/telemetry.json``,
and folds every bench record + the run stream into the ONE summary
artifact ``experiments/bench/summary.json`` (repro.telemetry.report).

Usage (CI bench-smoke lane):
    PYTHONPATH=src python -m benchmarks.run --fast --only telemetry
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import dfedavg, engine as engine_lib, gossip, packing, \
    topology
from repro.launch.elastic import ElasticTrainer
from repro.overlay import plan as plan_lib
from repro.telemetry import TelemetryConfig, TelemetryLogger, read_jsonl, \
    report as tel_report

N_CLIENTS = 32
DEGREE = 4
DIM = 1 << 14
# The telemetered round must keep at least this fraction of the plain
# round's throughput. This cell is the WORST CASE for the ratio: the quad
# loss is ~free, so the round is nearly pure gossip, and the consensus
# residual costs one extra decode+sqnorm pass per schedule — the same FLOP
# order as the mix it instruments (measured ~0.35-0.4x here; in a real
# train step the local compute dominates and the ratio approaches 1).
# Telemetry adds zero collectives either way (HLO-asserted in
# tests/test_telemetry.py); this gate only catches gross regressions.
MIN_SPEED_RATIO = 0.25


def quad_loss(p, b):
    return jnp.mean(jnp.square(p["w"] - b["t"])), {}


def _batches(n, local_steps=2):
    return {"t": jnp.zeros((n, local_steps, DIM), jnp.float32)}


def _run_cell(codec: str, delay: int, telemetry: bool, rounds: int,
              log_path: str | None = None, seed: int = 0) -> dict:
    logger = (TelemetryLogger(log_path, run=f"{codec}_tel", codec=codec)
              if log_path else None)
    trainer = ElasticTrainer(
        overlay=topology.expander_overlay(N_CLIENTS, DEGREE, seed=seed),
        loss_fn=quad_loss,
        dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.2, momentum=0.9),
        plan=plan_lib.OnePeerPlan(),
        engine=engine_lib.GossipEngineConfig(
            substrate="stacked", codec=codec, delay=delay,
            telemetry=TelemetryConfig() if telemetry else None),
        logger=logger)
    r = np.random.default_rng(seed)
    params = {"w": jnp.asarray(r.standard_normal((N_CLIENTS, DIM)) * 0.02,
                               jnp.float32)}
    batches = _batches(N_CLIENTS)
    # warmup compile outside the timed window
    params, _, _ = trainer.observe_heartbeats(
        np.ones(N_CLIENTS, np.float32), params)
    params, _ = trainer.step(params, batches, 0.2)
    t0 = time.perf_counter()
    for rnd in range(rounds):
        alive = (r.random(N_CLIENTS) > 0.1).astype(np.float32)  # churn
        params, _, _ = trainer.observe_heartbeats(alive, params)
        params, _ = trainer.step(params, batches, 0.2)
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    trainer.tracer.expect(1, what="churn + one-peer gates are data")
    if logger is not None:
        logger.close()
    mode = ("stream" if log_path else "on") if telemetry else "off"
    return {"label": f"{codec}{'_delay' if delay else ''}/{mode}",
            "codec": codec, "delay": delay, "telemetry": telemetry,
            "rounds_per_sec": round(rounds / dt, 2),
            "n_traces": trainer.n_traces}


def _wire_bytes() -> dict[str, int]:
    """Exact bytes/round per codec for this bench's model (one client's
    tree through the shard_map engine's wire_struct accounting)."""
    spec = gossip.make_gossip_spec(
        topology.expander_overlay(N_CLIENTS, DEGREE, seed=0))
    pack = packing.make_stacked_pack_spec({"w": jnp.zeros(DIM, jnp.float32)})
    out = {}
    for codec in ("f32", "int8", "int8_block"):
        ex = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="shard_map", codec=codec),
            spec, axis_names="clients", pack_spec=pack)
        out[codec] = ex.wire_bytes_per_round()
    return out


def main(rounds: int = 8, out_dir: str | None = "experiments/bench") -> None:
    os.makedirs(out_dir or ".", exist_ok=True)
    log_path = os.path.join(out_dir or ".", "telemetry_run.jsonl")

    cells = []
    overhead = {}
    for codec, delay in (("f32", 0), ("int8_block", 1)):
        off = _run_cell(codec, delay, False, rounds)
        on = _run_cell(codec, delay, True, rounds)
        cells += [off, on]
        ratio = on["rounds_per_sec"] / off["rounds_per_sec"]
        name = off["label"].split("/")[0]
        overhead[name] = round(ratio, 3)
        assert ratio >= MIN_SPEED_RATIO, \
            f"telemetry overhead too high: {on} vs {off}"
        emit(f"telemetry/{name}/n{N_CLIENTS}", 0.0,
             f"rps_off={off['rounds_per_sec']};rps_on={on['rounds_per_sec']};"
             f"on_over_off={ratio:.3f};n_traces={on['n_traces']}")

    # streamed cell: reported only — each round record is a host sync
    if os.path.exists(log_path):
        os.remove(log_path)  # the logger appends; start this run fresh
    stream = _run_cell("f32", 0, True, rounds, log_path=log_path)
    cells.append(stream)
    overhead["f32_stream"] = round(
        stream["rounds_per_sec"] / cells[0]["rounds_per_sec"], 3)
    emit(f"telemetry/f32_stream/n{N_CLIENTS}", 0.0,
         f"rps={stream['rounds_per_sec']};"
         f"vs_off={overhead['f32_stream']:.3f}")

    # the stream cell's run log: header + 1 compile + a round record per
    # executed round (warmup + timed), each carrying the consensus proxy
    recs = read_jsonl(log_path)
    kinds = [r["kind"] for r in recs]
    assert kinds.count("run") == 1 and kinds.count("compile") == 1, kinds
    round_recs = [r for r in recs if r["kind"] == "round"]
    assert len(round_recs) == rounds + 1, len(round_recs)
    assert all("resid_sqnorm" in r for r in round_recs)

    wire = _wire_bytes()
    assert wire["f32"] // 4 <= wire["int8_block"] < wire["f32"] // 2
    emit(f"telemetry/wire_bytes/n{N_CLIENTS}", 0.0,
         ";".join(f"{c}={b}" for c, b in wire.items()))

    if out_dir:
        with open(os.path.join(out_dir, "telemetry.json"), "w") as f:
            json.dump({
                "bench": "telemetry", "n_clients": N_CLIENTS,
                "degree": DEGREE, "dim": DIM, "rounds": rounds,
                "min_speed_ratio": MIN_SPEED_RATIO,
                "wire_bytes": wire, "overhead_ratio": overhead,
                "cells": cells,
            }, f, indent=1)
        # the ONE artifact: every bench record + this run's stream
        tel_report.build_summary(out_dir, logs=(log_path,),
                                 out=os.path.join(out_dir, "summary.json"))
    print("BENCH_TELEMETRY_OK")


if __name__ == "__main__":
    main()
