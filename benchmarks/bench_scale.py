import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8"
                           ).strip()
# ^ MUST precede the first jax import (jax locks the device count on init);
# standalone module for the same reason as bench_engine_smoke.

"""Massive-client blocked-substrate smoke — the CI guard for the
client-count/device-count decoupling.

4096 simulated clients on 8 fake devices (block = 512 clients per device),
expander d=4 overlay, blocked engine cell inside a fully-manual shard_map
island, with a RandomK active-set cohort rotating as traced step data.
Hard asserts on every push:

  * ONE executable across >= 3 distinct active-set cohorts under straggler
    churn (participation is data, never trace structure);
  * the lowered HLO ships exactly ``blocked.n_transfers`` collective-
    permutes — the schedule partition is the wire cost, nothing more;
  * rounds/sec at 4096 clients recorded to the CSV contract and to the
    JSON artifact ``experiments/bench/scale.json``.

Usage (CI bench-smoke lane):
    PYTHONPATH=src python -m benchmarks.bench_scale
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit

N_CLIENTS = 4096
BLOCK = 512  # clients per device -> 8 devices
DEGREE = 4
ROUNDS = 4
ACTIVE_K = 1024


def main() -> None:
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core import engine as engine_lib, gossip, packing, topology
    from repro.launch import mesh as mesh_lib
    from repro.overlay import plan as plan_lib

    assert len(jax.devices()) == N_CLIENTS // BLOCK, jax.devices()
    ov = topology.expander_overlay(N_CLIENTS, DEGREE, seed=0)
    spec = gossip.make_gossip_spec(ov)

    r = np.random.default_rng(0)
    tree = {"w": jnp.asarray(r.standard_normal((N_CLIENTS, 256)) * 0.02,
                             jnp.float32),
            "b": jnp.asarray(r.standard_normal((N_CLIENTS, 64)) * 0.02,
                             jnp.float32)}
    # tiny per-client slice: shrink the padding floor from the Pallas tile
    # (256 rows) to 8 so 4096 clients stay a few MB of wire, not GBs
    pack = packing.make_stacked_pack_spec(tree, block_rows=8)

    executor = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="blocked", block=BLOCK),
        spec, axis_names="clients", pack_spec=pack)
    bs = executor.blocked
    mesh = Mesh(np.asarray(jax.devices()), ("clients",))
    sh = NamedSharding(mesh, P("clients"))
    tree = jax.device_put(tree, sh)

    from repro.telemetry import TraceCounter
    tracer = TraceCounter("scale_round")

    @tracer.wrap
    def round_fn(params, alive):
        # stand-in local phase (the smoke measures the mixing round)
        params = jax.tree.map(lambda x: x * 0.999, params)

        def island(p, a):
            return executor(p, alive=a, gates=None)

        return mesh_lib.shard_map(island, mesh, in_specs=(P("clients"), P()),
                                  out_specs=P("clients"))(params, alive)

    fn = jax.jit(round_fn)

    # --- wire-cost guard: HLO collective-permutes == schedule partition
    alive0 = jnp.ones(N_CLIENTS, jnp.float32)
    n_perm = fn.lower(tree, alive0).as_text().count("collective_permute")
    assert n_perm == bs.n_transfers, (n_perm, bs.n_transfers)

    # --- execute under cohort rotation + churn; ONE executable
    plan = plan_lib.RandomKActiveSet(k=ACTIVE_K, seed=0)
    cohorts = set()
    jax.block_until_ready(fn(tree, alive0))  # warmup compile
    t0 = time.perf_counter()
    for rnd in range(ROUNDS):
        active = plan.active(rnd, N_CLIENTS)
        cohorts.add(active.tobytes())
        hb = (r.random(N_CLIENTS) > 0.05).astype(np.float32)  # churn
        tree = fn(tree, jnp.asarray(hb * active))
    jax.block_until_ready(tree)
    dt = time.perf_counter() - t0
    assert len(cohorts) >= 3, "active-set plan failed to rotate"
    tracer.expect(1, what="blocked round: churn + cohorts are data")
    for leaf in jax.tree.leaves(tree):
        assert bool(jnp.isfinite(leaf).all())

    rounds_per_sec = ROUNDS / dt
    emit(f"scale/blocked/{N_CLIENTS}x{len(jax.devices())}dev",
         dt * 1e6 / ROUNDS,
         f"rounds_per_sec={rounds_per_sec:.2f};n_transfers={bs.n_transfers};"
         f"cross_schedules={bs.cross_schedules};n_traces={tracer.count};"
         f"cohorts={len(cohorts)}")

    os.makedirs("experiments/bench", exist_ok=True)
    with open("experiments/bench/scale.json", "w") as f:
        json.dump({
            "n_clients": N_CLIENTS, "block": BLOCK,
            "n_devices": len(jax.devices()), "degree": DEGREE,
            "overlay": "expander", "codec": "f32",
            "n_transfers": bs.n_transfers,
            "cross_schedules": bs.cross_schedules,
            "hlo_collective_permutes": n_perm,
            "rounds": ROUNDS, "rounds_per_sec": rounds_per_sec,
            "n_traces": tracer.count, "active_k": ACTIVE_K,
            "distinct_cohorts": len(cohorts),
        }, f, indent=1)
    print("BENCH_SCALE_OK")


if __name__ == "__main__":
    main()
