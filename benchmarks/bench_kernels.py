"""Pallas kernel microbenchmarks.

On this CPU container the Pallas TPU kernels execute in interpret mode
(Python), so wall-times are NOT hardware numbers; we therefore report (a) the
jnp reference path wall-time (what actually runs on CPU) and (b) the
*structural* HBM-traffic model of the kernel vs its unfused form — the number
that matters on the TPU target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.fused_sgdm import ops as sgdm_ops
from repro.kernels.gossip_mix import ops as mix_ops
from repro.kernels.quant_gossip import ops as q_ops


def main() -> None:
    r = np.random.default_rng(0)
    size = 1 << 20  # 1M params per leaf

    # gossip_mix: fused (d+1)-way weighted reduce
    for d in (2, 4, 8):
        stack = jnp.asarray(r.standard_normal((d + 1, size)), jnp.float32)
        w = jnp.asarray(r.standard_normal(d + 1), jnp.float32)
        us = time_call(lambda s=stack, ww=w: mix_ops.gossip_mix(s, ww), iters=10)
        bytes_fused = (d + 2) * size * 4          # d+1 reads + 1 write
        bytes_unfused = (3 * d + 1 + 1) * size * 4  # d adds: 2 reads+1 write each (+initial scale)
        emit(f"kernels/gossip_mix/d{d}", us,
             f"hbm_fused_MB={bytes_fused/2**20:.1f};"
             f"hbm_unfused_MB={bytes_unfused/2**20:.1f};"
             f"traffic_saving={bytes_unfused/bytes_fused:.2f}x")

    # fused_sgdm
    w_ = jnp.asarray(r.standard_normal(size), jnp.float32)
    v_ = jnp.zeros(size, jnp.float32)
    g_ = jnp.asarray(r.standard_normal(size), jnp.float32)
    us = time_call(lambda: sgdm_ops.sgdm(w_, v_, g_, 0.01, 0.9), iters=10)
    emit("kernels/fused_sgdm", us,
         f"hbm_fused_B={5*size*4};hbm_unfused_B={8*size*4};"
         f"traffic_saving={8/5:.2f}x")

    # quantized gossip payload
    x = jnp.asarray(r.standard_normal(size), jnp.float32)
    us = time_call(lambda: q_ops.quantize_int8(x), iters=10)
    emit("kernels/quant_gossip", us,
         f"wire_bytes_f32={4*size};wire_bytes_int8={size+4};"
         f"ici_saving={4*size/(size+4):.2f}x")

    # interpret-mode correctness spot check folded into the bench
    got = mix_ops.gossip_mix(jnp.ones((3, 1024)), jnp.asarray([0.5, 0.25, 0.25]),
                             impl="pallas_interpret")
    assert float(jnp.max(jnp.abs(got - 1.0))) < 1e-6
    emit("kernels/interpret_check", 0.0, "pallas_interpret=ok")


if __name__ == "__main__":
    main()
