"""Pallas kernel microbenchmarks.

On this CPU container the Pallas TPU kernels execute in interpret mode
(Python), so wall-times are NOT hardware numbers; we therefore report (a) the
jnp reference path wall-time (what actually runs on CPU) and (b) the
*structural* HBM-traffic model of the kernel vs its unfused form — the number
that matters on the TPU target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import packing
from repro.kernels.fused_sgdm import ops as sgdm_ops
from repro.kernels.gossip_mix import ops as mix_ops
from repro.kernels.quant_gossip import ops as q_ops


def packed_vs_per_leaf_gossip(d: int = 4, n_leaves: int = 24) -> None:
    """The tentpole's reduction, leaf-by-leaf vs packed-fused.

    Simulates one gossip round's *local* arithmetic (payloads already
    exchanged): per-leaf does d+1 unfused read-modify-write adds per leaf;
    packed runs self + d received flat buffers through one fused reduction
    (pack/unpack of the self tree included in its timing, as in the real
    step). Wall-times are CPU-jnp; the HBM traffic model is the TPU number.
    """
    r = np.random.default_rng(1)
    # odd-shaped leaves, ~4M elements total — nothing lane-aligned
    shapes = [(257, 129 + (i % 7)) for i in range(n_leaves)]
    tree = {f"l{i}": jnp.asarray(r.standard_normal(s), jnp.float32)
            for i, s in enumerate(shapes)}
    neighbors = [jax.tree.map(
        lambda x: jnp.asarray(r.standard_normal(x.shape), jnp.float32), tree)
        for _ in range(d)]
    w0, c = 0.6, 0.1

    @jax.jit
    def per_leaf(t, recv):
        def one(x, *rs):
            out = w0 * x
            for rr in rs:
                out = out + c * rr
            return out
        return jax.tree.map(one, t, *recv)

    spec = packing.make_pack_spec(tree)
    recv_bufs = [packing.pack_tree(nb, spec) for nb in neighbors]
    weights = jnp.asarray([w0] + [c] * d, jnp.float32)

    @jax.jit
    def packed(t, recv):
        bufs = packing.pack_tree(t, spec)
        outs = tuple(
            mix_ops.gossip_mix_packed(jnp.stack((b,) + tuple(rb[i] for rb in recv)),
                                      weights)
            for i, b in enumerate(bufs))
        return packing.unpack_tree(outs, spec)

    us_leaf = time_call(lambda: per_leaf(tree, neighbors), iters=10)
    us_pack = time_call(lambda: packed(tree, recv_bufs), iters=10)
    total = sum(int(np.prod(s)) for s in shapes)
    bytes_unfused = (3 * d + 2) * total * 4   # per leaf: scale + d RMW adds
    bytes_fused = (d + 2) * total * 4         # d+1 reads + 1 write
    emit(f"kernels/gossip_packed_vs_per_leaf/d{d}/L{n_leaves}", us_pack,
         f"us_per_leaf={us_leaf:.1f};us_packed={us_pack:.1f};"
         f"collectives_per_leaf={d * n_leaves};collectives_packed={d};"
         f"hbm_unfused_MB={bytes_unfused/2**20:.1f};"
         f"hbm_fused_MB={bytes_fused/2**20:.1f};"
         f"traffic_saving={bytes_unfused/bytes_fused:.2f}x")


def main() -> None:
    r = np.random.default_rng(0)
    size = 1 << 20  # 1M params per leaf

    # gossip_mix: fused (d+1)-way weighted reduce
    for d in (2, 4, 8):
        stack = jnp.asarray(r.standard_normal((d + 1, size)), jnp.float32)
        w = jnp.asarray(r.standard_normal(d + 1), jnp.float32)
        us = time_call(lambda s=stack, ww=w: mix_ops.gossip_mix(s, ww), iters=10)
        bytes_fused = (d + 2) * size * 4          # d+1 reads + 1 write
        bytes_unfused = (3 * d + 1 + 1) * size * 4  # d adds: 2 reads+1 write each (+initial scale)
        emit(f"kernels/gossip_mix/d{d}", us,
             f"hbm_fused_MB={bytes_fused/2**20:.1f};"
             f"hbm_unfused_MB={bytes_unfused/2**20:.1f};"
             f"traffic_saving={bytes_unfused/bytes_fused:.2f}x")

    # fused_sgdm
    w_ = jnp.asarray(r.standard_normal(size), jnp.float32)
    v_ = jnp.zeros(size, jnp.float32)
    g_ = jnp.asarray(r.standard_normal(size), jnp.float32)
    us = time_call(lambda: sgdm_ops.sgdm(w_, v_, g_, 0.01, 0.9), iters=10)
    emit("kernels/fused_sgdm", us,
         f"hbm_fused_B={5*size*4};hbm_unfused_B={8*size*4};"
         f"traffic_saving={8/5:.2f}x")

    # quantized gossip payload
    x = jnp.asarray(r.standard_normal(size), jnp.float32)
    us = time_call(lambda: q_ops.quantize_int8(x), iters=10)
    emit("kernels/quant_gossip", us,
         f"wire_bytes_f32={4*size};wire_bytes_int8={size+4};"
         f"ici_saving={4*size/(size+4):.2f}x")

    # packed-vs-per-leaf gossip round (the tentpole's win)
    packed_vs_per_leaf_gossip(d=4, n_leaves=24)

    # interpret-mode correctness spot check folded into the bench
    got = mix_ops.gossip_mix(jnp.ones((3, 1024)), jnp.asarray([0.5, 0.25, 0.25]),
                             impl="pallas_interpret")
    assert float(jnp.max(jnp.abs(got - 1.0))) < 1e-6
    # packed fast path through the same interpreted kernel body
    stack = jnp.ones((3, packing.PACK_BLOCK_ROWS, packing.LANE))
    got2 = mix_ops.gossip_mix_packed(stack, jnp.asarray([0.5, 0.25, 0.25]),
                                     impl="pallas_interpret")
    assert float(jnp.max(jnp.abs(got2 - 1.0))) < 1e-6
    emit("kernels/interpret_check", 0.0, "pallas_interpret=ok")


if __name__ == "__main__":
    main()
