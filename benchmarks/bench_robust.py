"""Byzantine-robustness benchmark: screens vs scripted attackers.

Drives `ElasticTrainer` (the stacked engine round) on the shared quadratic
consensus task through a scripted `AttackPlan` and sweeps the grid

    attackers f x screen ("none" | "norm_clip" | "trimmed_mean")
               x topology (ring vs expander)

reporting, per cell:

  * a convergence proxy — final mean-square distance to the consensus
    target over the *honest measurable* clients (honest AND attacker
    in-multiplicity <= trim: a receiver fed the same attacker on two
    schedules needs trim >= 2 by the order-statistics contract, so those
    receivers are excluded from the fairness comparison, not hidden);
  * rounds/sec and the per-round overhead of each screen against the
    unscreened round on the same cell (median us/round);
  * the retrace guard: the attack vector is traced DATA, so a plan whose
    attacker set *changes mid-run* must keep ``n_traces == 1`` (hard
    assert, the CI bench-smoke gate).

Acceptance (hard-asserted): under f >= 1 sign-flip attackers the
trimmed-mean proxy stays within a small factor of the attack-free
baseline, while screen="none" degrades by orders of magnitude.

Output: the usual ``name,us_per_call,derived`` CSV rows plus one JSON
record written to ``experiments/bench/robust.json``::

    {"bench": "robust", "n_clients", "degree", "dim", "rounds",
     "grid": [{"topology", "screen", "f", "proxy", "rounds_per_sec",
               "n_traces", "n_measured"}, ...],
     "overhead_us": {screen: us_per_round, ...},
     "acceptance": {"proxy_clean", "proxy_none_f1", "proxy_trimmed_f1"}}
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import dfedavg, engine as engine_lib, failures, gossip
from repro.core.topology import expander_overlay, ring_overlay
from repro.launch.elastic import ElasticTrainer


def quad_loss(params, batch):
    return jnp.mean(jnp.square(params["w"] - batch["target"])), {}


def _batches(n, dim, k=2):
    t = jnp.zeros((n, dim), jnp.float32)  # consensus target: the origin
    return {"target": jnp.broadcast_to(t[:, None], (n, k, dim))}


def _attack_multiplicity(overlay, attackers) -> np.ndarray:
    """Per-receiver count of schedules that deliver some attacker."""
    spec = gossip.make_gossip_spec(overlay)
    mult = np.zeros(overlay.n, dtype=int)
    for rf, m in zip(spec.recv_from, spec.live_masks):
        rf, m = np.asarray(rf), np.asarray(m).astype(bool)
        mult += np.isin(rf, list(attackers)) & m
    return mult


def _run_cell(overlay_fn, screen, f, *, dim, rounds, trim, seed=0):
    overlay = overlay_fn()
    n = overlay.n
    plan = None
    attackers: tuple[int, ...] = ()
    if f > 0:
        # the attacker set CHANGES mid-run (new ids join) — the retrace
        # guard below proves attacker churn is data, not trace structure
        plan = failures.sample_attackers(n, f, mode="sign_flip",
                                         magnitude=5.0, seed=seed)
        extra = failures.sample_attackers(n, f, mode="sign_flip",
                                          magnitude=5.0, seed=seed + 1)
        plan = failures.AttackPlan(
            n, events=plan.events + tuple(
                (rounds // 2, e[1], e[2], e[3]) for e in extra.events))
        attackers = tuple(sorted({i for e in plan.events for i in e[1]}))
    trainer = ElasticTrainer(
        overlay=overlay, loss_fn=quad_loss,
        dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.2, momentum=0.9),
        failure_rounds=10**9, attack_plan=plan,
        engine=engine_lib.GossipEngineConfig(
            substrate="stacked", screen=screen, clip_tau=3.0, trim_f=trim))
    r = np.random.default_rng(seed)
    params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
    batches = _batches(n, dim)
    t0 = time.perf_counter()
    for _ in range(rounds):
        params, _ = trainer.step(params, batches, 0.2)
    jax.block_until_ready(params)
    rps = rounds / (time.perf_counter() - t0)
    # proxy over honest receivers whose attacker in-multiplicity the trim
    # budget can actually cover (see module docstring)
    mult = _attack_multiplicity(overlay, attackers)
    measured = np.array([i for i in range(n)
                         if i not in attackers and mult[i] <= trim])
    proxy = float(jnp.mean(jnp.square(params["w"][measured])))
    assert trainer.n_traces == 1, (screen, f, trainer.n_traces)
    return {"proxy": proxy, "rounds_per_sec": round(rps, 2),
            "n_traces": trainer.n_traces, "n_measured": int(len(measured))}


def _screen_overhead(n, degree, dim, *, trim, seed=0):
    """Median us/round of each screened round vs the unscreened one.

    CPU caveat: these are XLA-CPU schedules (the trimmed cell's single
    fused reduction can even beat the unscreened gather+einsum mix here);
    the TPU relationship is the kernel-analytic one in bench_kernels."""
    out = {}
    for screen in ("none", "norm_clip", "trimmed_mean"):
        trainer = ElasticTrainer(
            overlay=expander_overlay(n, degree, seed=seed),
            loss_fn=quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.2, momentum=0.9),
            failure_rounds=10**9,
            engine=engine_lib.GossipEngineConfig(
                substrate="stacked", screen=screen, trim_f=trim))
        r = np.random.default_rng(seed)
        params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
        alive = jnp.ones(n, jnp.float32)
        gates = trainer.gates_for_round(0)
        lr = jnp.asarray(0.2, jnp.float32)
        out[screen] = time_call(trainer._round, params, _batches(n, dim),
                                lr, alive, gates, None, None, iters=10)
    return out


def run(n_clients: int = 16, degree: int = 4, dim: int = 512,
        rounds: int = 10, trim: int = 1, seed: int = 0) -> dict:
    topos = {
        "ring": lambda: ring_overlay(n_clients),
        f"expander-d{degree}": lambda: expander_overlay(n_clients, degree,
                                                        seed=seed),
    }
    grid = []
    for tname, ofn in topos.items():
        for f in (0, 1, 2):
            for screen in ("none", "norm_clip", "trimmed_mean"):
                if f == 0 and screen != "none":
                    continue  # attack-free screened cells covered by tests
                cell = _run_cell(ofn, screen, f, dim=dim, rounds=rounds,
                                 trim=trim, seed=seed)
                cell.update(topology=tname, screen=screen, f=f)
                grid.append(cell)
                emit(f"robust/{tname}/f{f}/{screen}", 0.0,
                     f"proxy={cell['proxy']:.6f};"
                     f"rps={cell['rounds_per_sec']};"
                     f"n_traces={cell['n_traces']}")

    overhead = _screen_overhead(n_clients, degree, dim, trim=trim, seed=seed)
    for screen, us in overhead.items():
        emit(f"robust/overhead/{screen}", us,
             f"delta_vs_none={us - overhead['none']:.1f}us")

    def cell(tname, f, screen):
        return next(c for c in grid if c["topology"] == tname
                    and c["f"] == f and c["screen"] == screen)

    # acceptance: screens neutralize what the plain mean cannot. Proxies
    # are mean-square distances to the consensus target, so "neighborhood"
    # = a small constant factor of the attack-free run; "degrades" = an
    # order of magnitude or more. Asserted on the ring, where every edge
    # delivers once (in-multiplicity 1 == trim) and a single sign-flipper
    # visibly poisons the unscreened mean; the expander *dilutes* one
    # attacker across d+1 in-weights (its f=1 gap is real but smaller) —
    # that contrast is the paper's degree/robustness trade-off and is
    # recorded in the grid rather than asserted
    clean = cell("ring", 0, "none")["proxy"]
    none_f1 = cell("ring", 1, "none")["proxy"]
    trim_f1 = cell("ring", 1, "trimmed_mean")["proxy"]
    assert none_f1 > 10 * clean, (none_f1, clean)
    assert trim_f1 < 10 * clean + 1e-6, (trim_f1, clean)
    assert trim_f1 < none_f1 / 10, (trim_f1, none_f1)

    return {"bench": "robust", "n_clients": n_clients, "degree": degree,
            "dim": dim, "rounds": rounds, "trim": trim, "grid": grid,
            "overhead_us": {k: round(v, 1) for k, v in overhead.items()},
            "acceptance": {"proxy_clean": clean, "proxy_none_f1": none_f1,
                           "proxy_trimmed_f1": trim_f1}}


def main(rounds: int = 10, out_dir: str | None = "experiments/bench") -> None:
    rec = run(rounds=rounds)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, "robust.json"), "w") as f:
            json.dump(rec, f, indent=1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()
    main(rounds=args.rounds, out_dir=args.out)
