"""Paper Fig. 6: language modeling (char-level Shakespeare) per topology.

The paper uses 100 LSTM clients; we default to a CPU-friendly client count
while keeping the protocol (overlapping non-IID spans, 3 local epochs,
momentum 0.9) and report loss/accuracy + communication cost per topology.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, run_dfl, topology_suite
from repro.core import dfedavg
from repro.data import federated, pipeline, shakespeare
from repro.models import lstm
from repro.models.params import count_params, init_params


def run(n_clients: int = 8, rounds: int = 6, seed: int = 0) -> list[dict]:
    toks, vocab = shakespeare.corpus()
    spans = federated.span_split(len(toks), n_clients, seed=seed)
    batcher = pipeline.TokenBatcher(toks, spans, batch_size=6, seq_len=48,
                                    local_steps=2, seed=seed)
    struct = lstm.param_struct(vocab=len(vocab))
    model_bytes = count_params(struct) * 4
    dcfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.5, momentum=0.9)
    init = jax.vmap(lambda i: init_params(struct, jax.random.key(0)))(
        jnp.arange(n_clients))

    ev = pipeline.TokenBatcher(toks, [(int(len(toks) * 0.9), len(toks))],
                               batch_size=32, seq_len=48, local_steps=1,
                               seed=seed + 1)
    eb = ev.round_batches(0)
    etoks = jnp.asarray(eb["tokens"][0, 0])
    elabs = jnp.asarray(eb["labels"][0, 0])

    def eval_fn(params, _alive):
        p0 = jax.tree.map(lambda x: x[0], params)
        loss, aux = lstm.loss_fn(p0, {"tokens": etoks, "labels": elabs})
        return {"test_loss": float(loss), "test_acc": float(aux["acc"])}

    def batch_fn(rnd):
        b = batcher.round_batches(rnd)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    out = []
    for name, (mixer, degree) in topology_suite(n_clients, degree=3,
                                                seed=seed).items():
        t0 = time.perf_counter()
        _, hist = run_dfl(init, lambda p, b: lstm.loss_fn(p, b), batch_fn,
                          mixer, rounds, dcfg, eval_fn=eval_fn)
        dt = time.perf_counter() - t0
        out.append({
            "topology": name,
            "final_acc": hist[-1]["test_acc"],
            "final_loss": hist[-1]["test_loss"],
            "comm_bytes_per_round_per_client": degree * model_bytes,
            "seconds": dt, "rounds": rounds,
        })
    return out


def main(rounds: int = 6) -> None:
    for r in run(rounds=rounds):
        emit(f"shakespeare/{r['topology']}", r["seconds"] * 1e6 / r["rounds"],
             f"final_acc={r['final_acc']:.3f};final_loss={r['final_loss']:.3f};"
             f"comm_B={int(r['comm_bytes_per_round_per_client'])}")


if __name__ == "__main__":
    main()
