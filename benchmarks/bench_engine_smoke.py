import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=16"
                           ).strip()
# ^ MUST precede the first jax import (jax locks the device count on init),
# which is why this smoke is a standalone module instead of a benchmarks.run
# suite: run.py imports jax before any suite can set the flag. Appended (not
# setdefault) so a pre-exported XLA_FLAGS keeps its flags without dropping
# the fake device count this smoke requires.

"""Pipelined + quantized engine smoke — the CI guard for the composition.

Builds the PRODUCTION train step (launch.steps.build_train_step, fully-
manual shard_map island) on a 16-fake-device (4, 4) mesh with
``gossip_impl="ppermute_packed_async"``, ``gossip_delay=1``,
``gossip_codec="int8_block"`` and hard-asserts the engine acceptance
criteria on every push:

  * the lowered HLO ships exactly **d** collective-permutes per round and
    every one of them carries the **int8 wire buffer** (quantize + fold
    happened before the wire, scales ride inside);
  * the donated in-flight snapshot is the int8 wire (4x smaller state);
  * the async impl at ``gossip_delay=0`` still lowers to HLO *textually
    identical* to ``ppermute_packed`` (no drift from the codec plumbing);
  * executing rounds under straggler churn + rotating one-peer gates reuses
    ONE executable (``_cache_size() == 1`` — alive/gates/snapshot are step
    data, never trace structure);
  * the **sparse EF** cell (``gossip_codec="topk_ef"``): same d-collective
    count with the lane-folded int8 top-k wire, per-round wire bytes <= 10%
    of the dense f32 build, the EF residual threading the donated
    ``codec_state`` operand (nonzero after one round), and the same
    one-executable guard under churn + gate rotation;
  * the **Chebyshev** cell (``gossip_sub_rounds=2``): exactly 2*d
    collective-permutes in the lowered step, the ``gossip_sub_rounds=1``
    build lowering to HLO *textually identical* to the default packed
    build (the sub-round plumbing is invisible at k=1), and ONE executable
    across rounds that vary the traced Chebyshev coefficients alongside
    churn + gate rotation.

Usage (CI bench-smoke lane):
    PYTHONPATH=src python -m benchmarks.bench_engine_smoke
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit


def main() -> None:
    from repro.configs import registry
    from repro.configs.base import DFLConfig, ParallelConfig, ShapeConfig
    from repro.launch import steps
    from repro.models import params as params_lib

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = registry.reduced("qwen2.5-3b")  # single-dtype smoke tree
    shape = ShapeConfig("t", 64, 8, "train")
    dfl = DFLConfig(degree=2, round_plan="one_peer")

    texts = {}
    setups = {}
    for key, delay, codec in (("packed", 0, "auto"),
                              ("async_sync", 0, "auto"),
                              ("async_quant", 1, "int8_block")):
        par = ParallelConfig(clients_per_pod=4, local_steps=2, grad_accum=2,
                             gossip_impl=("ppermute_packed" if key == "packed"
                                          else "ppermute_packed_async"),
                             gossip_delay=delay, gossip_codec=codec)
        setup = steps.build_train_step(cfg, shape, mesh, par, dfl)
        args = [params_lib.shape_structs(setup.param_struct),
                setup.input_specs["batch"], setup.input_specs["lr"],
                setup.input_specs["alive"], setup.input_specs["gates"]]
        if "inflight" in setup.input_specs:
            args.append(setup.input_specs["inflight"])
        texts[key] = setup.step_fn.lower(*args).as_text()
        setups[key] = setup

    # --- d collectives, all of them int8 wire, snapshot dtype int8
    setup = setups["async_quant"]
    d = setup.gossip_spec.degree
    perms = [ln for ln in texts["async_quant"].splitlines()
             if "collective_permute" in ln]
    assert len(perms) == d, (len(perms), d)
    assert all("xi8>" in ln for ln in perms), "non-int8 wire on a permute"
    assert all(str(s.dtype) == "int8"
               for s in setup.input_specs["inflight"])
    # --- delay=0 bit-identity anchor survives the codec plumbing
    assert texts["async_sync"] == texts["packed"], \
        "async delay=0 no longer lowers identically to ppermute_packed"

    # --- execute: churn + one-peer gate rotation must reuse ONE executable
    r = np.random.default_rng(0)
    structs = params_lib.shape_structs(setup.param_struct)
    params = jax.tree.map(
        lambda s, sh: jax.device_put(
            jnp.asarray(r.standard_normal(s.shape) * 0.02, s.dtype), sh),
        structs, setup.in_shardings[0])
    batch = {k: jnp.zeros(v.shape, v.dtype)
             for k, v in setup.input_specs["batch"].items()}
    inflight = setup.init_inflight(params)
    n, d = setup.n_clients, setup.gossip_spec.degree
    t0 = time.perf_counter()
    rounds = 3
    for rnd in range(rounds):
        alive = (r.random(n) > 0.3).astype(np.float32)
        if alive.sum() < 2:
            alive[:] = 1.0
        gates = np.zeros(d, np.float32)
        gates[rnd % d] = 1.0
        params, _m, inflight = setup.step_fn(
            params, batch, jnp.float32(0.01), jnp.asarray(alive),
            jnp.asarray(gates), inflight)
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    from repro.telemetry import TraceCounter
    n_traces = TraceCounter.cache_size(setup.step_fn)
    assert n_traces == 1, f"pipelined+quant step retraced: {n_traces}"
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(jnp.asarray(leaf, jnp.float32)).all())

    emit("engine_smoke/async_quant/4x4", dt * 1e6 / rounds,
         f"d_collectives={len(perms)};int8_wire=1;n_traces={n_traces};"
         f"rounds={rounds};delay0_identity=1")

    # --- sparse EF cell: topk_ef through the SAME production step
    par_s = ParallelConfig(clients_per_pod=4, local_steps=2, grad_accum=2,
                           gossip_impl="ppermute_packed",
                           gossip_codec="topk_ef")
    s_t = steps.build_train_step(cfg, shape, mesh, par_s, dfl)
    args = [params_lib.shape_structs(s_t.param_struct),
            s_t.input_specs["batch"], s_t.input_specs["lr"],
            s_t.input_specs["alive"], s_t.input_specs["gates"],
            s_t.input_specs["codec_state"]]
    sperms = [ln for ln in s_t.step_fn.lower(*args).as_text().splitlines()
              if "collective_permute" in ln]
    assert len(sperms) == d, (len(sperms), d)
    assert all("xi8>" in ln for ln in sperms), "non-int8 top-k wire"
    # wire accounting rides the telemetry builds (wire_bytes_per_round is
    # the executor's exact wire-struct sum, populated when telemetry is on)
    wire = {}
    for codec in ("f32", "topk_ef"):
        par_w = ParallelConfig(clients_per_pod=4, local_steps=2,
                               grad_accum=2, gossip_impl="ppermute_packed",
                               gossip_codec=codec, gossip_telemetry=True)
        wire[codec] = steps.build_train_step(
            cfg, shape, mesh, par_w, dfl).wire_bytes_per_round
    ratio = wire["topk_ef"] / wire["f32"]
    assert ratio <= 0.10, f"topk_ef wire ratio vs f32: {ratio}"

    cstate = s_t.init_codec_state(params)
    t0 = time.perf_counter()
    for rnd in range(rounds):
        alive = (r.random(n) > 0.3).astype(np.float32)
        if alive.sum() < 2:
            alive[:] = 1.0
        gates = np.zeros(d, np.float32)
        gates[rnd % d] = 1.0
        params, _m, cstate = s_t.step_fn(
            params, batch, jnp.float32(0.01), jnp.asarray(alive),
            jnp.asarray(gates), cstate)
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    s_traces = TraceCounter.cache_size(s_t.step_fn)
    assert s_traces == 1, f"sparse EF step retraced: {s_traces}"
    resid = sum(float(jnp.sum(jnp.abs(c))) for c in cstate)
    assert resid > 0, "EF residual stayed zero — error feedback inert"
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(jnp.asarray(leaf, jnp.float32)).all())
    emit("engine_smoke/sparse_ef/4x4", dt * 1e6 / rounds,
         f"d_collectives={len(sperms)};wire_ratio_vs_f32={ratio:.4f};"
         f"n_traces={s_traces};rounds={rounds};residual_mass={resid:.3e}")

    # --- Chebyshev cell: sub_rounds=2 through the SAME production step
    par_c1 = ParallelConfig(clients_per_pod=4, local_steps=2, grad_accum=2,
                            gossip_impl="ppermute_packed",
                            gossip_sub_rounds=1)
    c1 = steps.build_train_step(cfg, shape, mesh, par_c1, dfl)
    args = [params_lib.shape_structs(c1.param_struct),
            c1.input_specs["batch"], c1.input_specs["lr"],
            c1.input_specs["alive"], c1.input_specs["gates"]]
    assert c1.cheby_coeffs is None and "cheby" not in c1.input_specs
    assert c1.step_fn.lower(*args).as_text() == texts["packed"], \
        "sub_rounds=1 no longer lowers identically to the packed build"

    par_c2 = ParallelConfig(clients_per_pod=4, local_steps=2, grad_accum=2,
                            gossip_impl="ppermute_packed",
                            gossip_sub_rounds=2)
    c2 = steps.build_train_step(cfg, shape, mesh, par_c2, dfl)
    om = np.asarray(c2.cheby_coeffs)
    assert om.shape == (2,) and om[0] == 1.0, om
    assert c2.input_specs["cheby"].shape == (2,)
    args = [params_lib.shape_structs(c2.param_struct),
            c2.input_specs["batch"], c2.input_specs["lr"],
            c2.input_specs["alive"], c2.input_specs["gates"],
            c2.input_specs["cheby"]]
    cperms = [ln for ln in c2.step_fn.lower(*args).as_text().splitlines()
              if "collective_permute" in ln]
    assert len(cperms) == 2 * d, (len(cperms), d)

    t0 = time.perf_counter()
    for rnd in range(rounds):
        alive = (r.random(n) > 0.3).astype(np.float32)
        if alive.sum() < 2:
            alive[:] = 1.0
        gates = np.zeros(d, np.float32)
        gates[rnd % d] = 1.0
        # coefficients are step DATA: vary them every round, expect 1 trace
        cheby = jnp.asarray([1.0, float(om[1]) * (1.0 + 0.05 * rnd)],
                            jnp.float32)
        params, _m = c2.step_fn(
            params, batch, jnp.float32(0.01), jnp.asarray(alive),
            jnp.asarray(gates), cheby)
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    c_traces = TraceCounter.cache_size(c2.step_fn)
    assert c_traces == 1, f"chebyshev step retraced: {c_traces}"
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(jnp.asarray(leaf, jnp.float32)).all())
    emit("engine_smoke/chebyshev_k2/4x4", dt * 1e6 / rounds,
         f"kd_collectives={len(cperms)};n_traces={c_traces};"
         f"rounds={rounds};k1_identity=1")
    print("ENGINE_SMOKE_OK")


if __name__ == "__main__":
    main()
