"""Paper Figs. 4 & 5: MNIST(-like) MLP, IID and non-IID, per topology.

Reports rounds-to-threshold accuracy and final accuracy for
ring / expander-d3 / complete (and ER for non-IID), mirroring the paper's
panels and their communication-cost readout.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, run_dfl, topology_suite
from repro.core import dfedavg
from repro.data import federated, mnist, pipeline
from repro.models import mlp
from repro.models.params import init_params

N_CLIENTS = 10
MODEL_BYTES = (784 * 200 + 200 + 200 * 10 + 10) * 4  # f32 MLP-200 (paper model)


def run(noniid: bool, rounds: int = 10, seed: int = 0) -> list[dict]:
    tr, te = mnist.make_mnist_like(4000, 800, seed=0)
    if noniid:
        parts = federated.label_shard_split(tr.y, N_CLIENTS, seed=seed)
    else:
        parts = federated.iid_split(len(tr.x), N_CLIENTS, seed=seed)
    batcher = pipeline.ClientBatcher(tr.x, tr.y, parts, batch_size=20,
                                     local_steps=3, seed=seed)
    dcfg = dfedavg.DFedAvgMConfig(local_steps=3, lr=0.05, momentum=0.9)
    struct = mlp.param_struct()
    init = jax.vmap(lambda i: init_params(struct, jax.random.key(0)))(
        jnp.arange(N_CLIENTS))
    tex, tey = jnp.asarray(te.x), jnp.asarray(te.y)

    def eval_fn(params, _alive):
        p0 = jax.tree.map(lambda x: x[0], params)
        _, aux = mlp.loss_fn(p0, {"x": tex, "y": tey})
        return {"test_acc": float(aux["acc"]), "test_loss": float(aux["loss"])}

    def batch_fn(rnd):
        b = batcher.round_batches(rnd)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    out = []
    suite = topology_suite(N_CLIENTS, degree=3, seed=seed)
    if not noniid:  # paper omits ER for MNIST (inconsistent at small n)
        suite.pop("erdos-renyi", None)
    for name, (mixer, degree) in suite.items():
        t0 = time.perf_counter()
        _, hist = run_dfl(init, lambda p, b: mlp.loss_fn(p, b), batch_fn,
                          mixer, rounds, dcfg, eval_fn=eval_fn)
        dt = time.perf_counter() - t0
        accs = [h["test_acc"] for h in hist]
        thresh = 0.9 if not noniid else 0.8
        reach = next((i + 1 for i, a in enumerate(accs) if a >= thresh), None)
        out.append({
            "setting": "noniid" if noniid else "iid",
            "topology": name,
            "final_acc": accs[-1],
            "rounds_to_thresh": reach,
            "comm_bytes_per_round_per_client": degree * MODEL_BYTES,
            "seconds": dt,
        })
    return out


def main(rounds: int = 10) -> None:
    for noniid in (False, True):
        for r in run(noniid, rounds=rounds):
            emit(f"mnist/{r['setting']}/{r['topology']}",
                 r["seconds"] * 1e6 / rounds,
                 f"final_acc={r['final_acc']:.3f};"
                 f"rounds_to_thresh={r['rounds_to_thresh']};"
                 f"comm_B={r['comm_bytes_per_round_per_client']}")


if __name__ == "__main__":
    main()
