"""Pallas TPU kernel: fused DFedAvgM heavy-ball update (paper eq. 2.1).

    v' = beta * v - lr * g
    w' = w + v'

runs K times per communication round over the whole parameter state — a pure
memory-bound streaming op. Fused: 3 reads (w, v, g) + 2 writes (w', v') per
element; the unfused jnp graph without XLA fusion would be 5 reads + 3 writes
(and on TPU the fused kernel also guarantees a single pass regardless of how
XLA schedules the surrounding graph).

Accumulation is in f32 even for bf16 state, matching `dfedavg.momentum_update`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _sgdm_kernel(w_ref, v_ref, g_ref, s_ref, wo_ref, vo_ref):
    """s = (lr, beta) as a (1, 2) f32 VMEM operand."""
    lr = s_ref[0, 0]
    beta = s_ref[0, 1]
    v = beta * v_ref[...].astype(jnp.float32) - lr * g_ref[...].astype(jnp.float32)
    vo_ref[...] = v.astype(vo_ref.dtype)
    wo_ref[...] = (w_ref[...].astype(jnp.float32) + v).astype(wo_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sgdm_2d(w: jax.Array, v: jax.Array, g: jax.Array, scalars: jax.Array, *,
            block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """w, v, g: (rows, LANE) with rows % block_rows == 0; scalars: (1, 2) f32."""
    rows, lane = w.shape
    assert lane == LANE and rows % block_rows == 0
    grid = (rows // block_rows,)
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _sgdm_kernel,
        grid=grid,
        in_specs=[blk, blk, blk, pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[blk, blk],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), w.dtype),
                   jax.ShapeDtypeStruct((rows, LANE), v.dtype)],
        interpret=interpret,
    )(w, v, g, scalars)
