"""Jitted public wrapper for the fused_sgdm kernel.

`sgdm_update` is a drop-in replacement for `core.dfedavg.momentum_update`
(pytree in, pytree out) — pass it as ``update_fn`` to `local_round`.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.fused_sgdm import kernel as _k
from repro.kernels.fused_sgdm import ref as _ref

PyTree = Any


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def sgdm(w: jax.Array, v: jax.Array, g: jax.Array, lr, beta, *,
         block_rows: int = _k.DEFAULT_BLOCK_ROWS,
         impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Single-leaf fused heavy-ball update; any shape/dtype."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.sgdm(w, v, g, lr, beta)

    shape = w.shape
    flat = lambda x: x.reshape(-1)
    t = w.size
    tile = block_rows * _k.LANE
    pad = (-t) % tile
    def prep(x):
        xf = flat(x)
        if pad:
            xf = jnp.pad(xf, (0, pad))
        return xf.reshape(-1, _k.LANE)
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         jnp.asarray(beta, jnp.float32)]).reshape(1, 2)
    wo, vo = _k.sgdm_2d(prep(w), prep(v), prep(g), scalars,
                        block_rows=block_rows,
                        interpret=(impl == "pallas_interpret"))
    unprep = lambda x, d: x.reshape(-1)[:t].reshape(shape).astype(d)
    return unprep(wo, w.dtype), unprep(vo, v.dtype)


def sgdm_update(params: PyTree, velocity: PyTree, grads: PyTree, lr, beta,
                impl: str = "auto") -> tuple[PyTree, PyTree]:
    """Pytree version, signature-compatible with dfedavg.momentum_update."""
    flat_p, treedef = jax.tree.flatten(params)
    flat_v = jax.tree.leaves(velocity)
    flat_g = jax.tree.leaves(grads)
    outs = [sgdm(p, v, g, lr, beta, impl=impl)
            for p, v, g in zip(flat_p, flat_v, flat_g)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))
