"""Pure-jnp oracle for the fused_sgdm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sgdm(w: jax.Array, v: jax.Array, g: jax.Array, lr, beta
         ) -> tuple[jax.Array, jax.Array]:
    """v' = beta v - lr g ; w' = w + v' (f32 accumulation, cast back)."""
    v32 = (jnp.asarray(beta, jnp.float32) * v.astype(jnp.float32)
           - jnp.asarray(lr, jnp.float32) * g.astype(jnp.float32))
    w32 = w.astype(jnp.float32) + v32
    return w32.astype(w.dtype), v32.astype(v.dtype)
