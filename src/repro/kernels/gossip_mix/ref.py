"""Pure-jnp oracle for the gossip_mix kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gossip_mix(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """out = sum_k weights[k] * stack[k] (computed in f32, cast back)."""
    w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (stack.ndim - 1))
    return jnp.sum(w * stack.astype(jnp.float32), axis=0).astype(stack.dtype)
