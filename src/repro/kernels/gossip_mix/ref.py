"""Pure-jnp oracles for the gossip_mix kernel family (plain, masked,
trimmed-mean, and the int8 dequant-side trimmed variant)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gossip_mix(stack: jax.Array, weights: jax.Array,
               alive: jax.Array | None = None) -> jax.Array:
    """out = sum_k weights[k] * stack[k] (computed in f32, cast back).

    With ``alive`` (K,): the renormalized masked reduction — weights are
    masked by alive, rescaled to sum to 1 over the live contributors, and a
    dead self (alive[0] == 0) yields the identity ``stack[0]``.
    """
    if alive is None:
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (stack.ndim - 1))
        return jnp.sum(w * stack.astype(jnp.float32), axis=0).astype(stack.dtype)
    wa = weights.astype(jnp.float32) * alive.astype(jnp.float32)
    tot = jnp.sum(wa)
    # no renormalizable mass => identity fallback REPLACES the renormalized
    # term (inv zeroed, so tiny fractional mass cannot double-count)
    ok = tot > 1e-12
    inv = jnp.where(ok, 1.0 / jnp.maximum(tot, 1e-12), 0.0)
    a_self = alive.astype(jnp.float32)[0]
    eff = a_self * wa * inv
    eff = eff.at[0].add((1.0 - a_self) + a_self * (1.0 - ok))
    w = eff.reshape((-1,) + (1,) * (stack.ndim - 1))
    return jnp.sum(w * stack.astype(jnp.float32), axis=0).astype(stack.dtype)


def trimmed_mix(stack: jax.Array, u: jax.Array, live: jax.Array,
                trim: int) -> jax.Array:
    """Coordinate-wise trimmed weighted mean over the contributor stack.

    stack: (K, *payload) — entry 0 is the receiver's own fresh value, entries
    1..K-1 the received payloads. ``live`` (K,) flags which entries
    participate in the per-coordinate order statistics (dead senders, gated
    or fixed-point schedules carry 0 and are invisible to the sort); ``u``
    (K,) holds the *nonnegative* mixing weights of the participants. Per
    coordinate, the ``t`` largest and ``t`` smallest live values are dropped
    with ``t = min(trim, floor((n_live - 1) / 2))`` (so at least one value
    always survives), and the output is the u-weighted mean renormalized
    over the survivors. ``trim = 0`` therefore reduces to the renormalized
    masked mean. A non-live self (live[0] == 0) or zero surviving weight
    mass falls back to the identity ``stack[0]``.

    Ranks are stable (ties broken by stack index), so exactly
    ``n_live - 2t`` values survive per coordinate.
    """
    x = stack.astype(jnp.float32)
    k = x.shape[0]
    lv = live.astype(jnp.float32)
    uw = u.astype(jnp.float32)
    n_live = jnp.sum(lv)
    t = jnp.minimum(jnp.float32(trim),
                    jnp.maximum(jnp.floor((n_live - 1.0) * 0.5), 0.0))
    num = jnp.zeros(x.shape[1:], jnp.float32)
    den = jnp.zeros(x.shape[1:], jnp.float32)
    for i in range(k):  # K is small (d+1): O(K^2) elementwise compares
        rank = jnp.zeros(x.shape[1:], jnp.float32)
        for j in range(k):
            if j == i:
                continue
            cmp = (x[j] <= x[i]) if j < i else (x[j] < x[i])
            rank = rank + lv[j] * cmp.astype(jnp.float32)
        surv = lv[i] * ((rank >= t) & (rank < n_live - t)).astype(jnp.float32)
        num = num + surv * uw[i] * x[i]
        den = den + surv * uw[i]
    ok = den > 1e-12
    mean = jnp.where(ok, num / jnp.maximum(den, 1e-12), x[0])
    out = lv[0] * mean + (1.0 - lv[0]) * x[0]
    return out.astype(stack.dtype)


def trimmed_mix_quant(fresh: jax.Array, qstack: jax.Array, scales: jax.Array,
                      u: jax.Array, live: jax.Array, trim: int) -> jax.Array:
    """Dequant-side trimmed mix: entries 1..K-1 arrive as int8 payloads with
    per-buffer (n_s == 1) or per-row-block (n_s == n_blocks) f32 scales.

    fresh: (rows, LANE) f32; qstack: (K-1, rows, LANE) int8;
    scales: (K-1, n_s). Dequantizes then applies :func:`trimmed_mix`.
    """
    km1, rows, lane = qstack.shape
    n_s = scales.shape[1]
    q = qstack.astype(jnp.float32)
    if n_s == 1:
        deq = q * scales.astype(jnp.float32)[:, :, None]
    else:
        block = rows // n_s
        deq = (q.reshape(km1, n_s, block, lane)
               * scales.astype(jnp.float32)[:, :, None, None]
               ).reshape(km1, rows, lane)
    stack = jnp.concatenate([fresh.astype(jnp.float32)[None], deq])
    return trimmed_mix(stack, u, live, trim).astype(fresh.dtype)


def block_sqnorms(buf: jax.Array, block_rows: int) -> jax.Array:
    """Per-row-block squared norms of a packed (rows, LANE) buffer: the
    (n_blocks,) f32 partials the norm-clip screen reduces over."""
    rows = buf.shape[0]
    x = buf.astype(jnp.float32).reshape(rows // block_rows, -1)
    return jnp.sum(x * x, axis=1)
