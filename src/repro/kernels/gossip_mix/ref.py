"""Pure-jnp oracle for the gossip_mix kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gossip_mix(stack: jax.Array, weights: jax.Array,
               alive: jax.Array | None = None) -> jax.Array:
    """out = sum_k weights[k] * stack[k] (computed in f32, cast back).

    With ``alive`` (K,): the renormalized masked reduction — weights are
    masked by alive, rescaled to sum to 1 over the live contributors, and a
    dead self (alive[0] == 0) yields the identity ``stack[0]``.
    """
    if alive is None:
        w = weights.astype(jnp.float32).reshape((-1,) + (1,) * (stack.ndim - 1))
        return jnp.sum(w * stack.astype(jnp.float32), axis=0).astype(stack.dtype)
    wa = weights.astype(jnp.float32) * alive.astype(jnp.float32)
    tot = jnp.sum(wa)
    # no renormalizable mass => identity fallback REPLACES the renormalized
    # term (inv zeroed, so tiny fractional mass cannot double-count)
    ok = tot > 1e-12
    inv = jnp.where(ok, 1.0 / jnp.maximum(tot, 1e-12), 0.0)
    a_self = alive.astype(jnp.float32)[0]
    eff = a_self * wa * inv
    eff = eff.at[0].add((1.0 - a_self) + a_self * (1.0 - ok))
    w = eff.reshape((-1,) + (1,) * (stack.ndim - 1))
    return jnp.sum(w * stack.astype(jnp.float32), axis=0).astype(stack.dtype)
