"""Jitted public wrapper for the gossip_mix kernel (any shape/dtype).

On TPU this runs the Pallas kernel; elsewhere it runs the kernel in interpret
mode (bit-accurate kernel-body semantics on CPU) unless ``force_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gossip_mix import kernel as _k
from repro.kernels.gossip_mix import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def gossip_mix(stack: jax.Array, weights: jax.Array,
               alive: jax.Array | None = None, *,
               block_rows: int = _k.DEFAULT_BLOCK_ROWS,
               impl: str = "auto") -> jax.Array:
    """out = sum_k weights[k] * stack[k] for stack of shape (K, *payload).

    With ``alive`` (K,): the renormalized masked reduction over the live
    contributors (dead self => identity). Same HBM traffic either way.

    impl: "auto" (pallas on TPU, ref elsewhere), "pallas", "pallas_interpret",
    or "ref".
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.gossip_mix(stack, weights, alive)

    k = stack.shape[0]
    payload_shape = stack.shape[1:]
    flat = stack.reshape(k, -1)
    t = flat.shape[1]
    tile = block_rows * _k.LANE
    pad = (-t) % tile
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    rows = (t + pad) // _k.LANE
    out = _k.gossip_mix_2d(flat.reshape(k, rows, _k.LANE), weights, alive,
                           block_rows=block_rows,
                           interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)[:t].reshape(payload_shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def gossip_mix_packed(stack: jax.Array, weights: jax.Array,
                      alive: jax.Array | None = None, *,
                      block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                      impl: str = "auto") -> jax.Array:
    """Fast path for pre-packed payloads: stack is (K, rows, LANE) with
    rows % block_rows == 0 (a PackSpec buffer stacked over self + received),
    so the Pallas kernel runs with zero flatten/pad work in the step.
    ``alive`` (K,) selects the renormalized masked reduction.
    """
    k, rows, lane = stack.shape
    assert lane == _k.LANE and rows % block_rows == 0, (stack.shape, block_rows)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.gossip_mix(stack, weights, alive)
    return _k.gossip_mix_2d(stack, weights, alive, block_rows=block_rows,
                            interpret=(impl == "pallas_interpret"))
