"""Jitted public wrapper for the gossip_mix kernel (any shape/dtype).

On TPU this runs the Pallas kernel; elsewhere it runs the kernel in interpret
mode (bit-accurate kernel-body semantics on CPU) unless ``force_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gossip_mix import kernel as _k
from repro.kernels.gossip_mix import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def gossip_mix(stack: jax.Array, weights: jax.Array,
               alive: jax.Array | None = None, *,
               block_rows: int = _k.DEFAULT_BLOCK_ROWS,
               impl: str = "auto") -> jax.Array:
    """out = sum_k weights[k] * stack[k] for stack of shape (K, *payload).

    With ``alive`` (K,): the renormalized masked reduction over the live
    contributors (dead self => identity). Same HBM traffic either way.

    impl: "auto" (pallas on TPU, ref elsewhere), "pallas", "pallas_interpret",
    or "ref".
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.gossip_mix(stack, weights, alive)

    k = stack.shape[0]
    payload_shape = stack.shape[1:]
    flat = stack.reshape(k, -1)
    t = flat.shape[1]
    tile = block_rows * _k.LANE
    pad = (-t) % tile
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    rows = (t + pad) // _k.LANE
    out = _k.gossip_mix_2d(flat.reshape(k, rows, _k.LANE), weights, alive,
                           block_rows=block_rows,
                           interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)[:t].reshape(payload_shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def gossip_mix_packed(stack: jax.Array, weights: jax.Array,
                      alive: jax.Array | None = None, *,
                      block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                      impl: str = "auto") -> jax.Array:
    """Fast path for pre-packed payloads: stack is (K, rows, LANE) with
    rows % block_rows == 0 (a PackSpec buffer stacked over self + received),
    so the Pallas kernel runs with zero flatten/pad work in the step.
    ``alive`` (K,) selects the renormalized masked reduction.
    """
    k, rows, lane = stack.shape
    assert lane == _k.LANE and rows % block_rows == 0, (stack.shape, block_rows)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.gossip_mix(stack, weights, alive)
    return _k.gossip_mix_2d(stack, weights, alive, block_rows=block_rows,
                            interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("trim", "block_rows", "impl"))
def gossip_mix_trimmed(stack: jax.Array, u: jax.Array, live: jax.Array, *,
                       trim: int, block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                       impl: str = "auto") -> jax.Array:
    """Coordinate-wise trimmed renormalized mean over stack (K, *payload).

    ``live`` (K,) flags the participants of the per-element order statistics
    (entry 0 = self; 0 => identity fallback), ``u`` (K,) their nonnegative
    weights, ``trim`` the static per-side drop count (clamped per element so
    at least one live value survives). trim=0 reduces to the renormalized
    masked mean. Any-shape wrapper (flatten/pad); padded elements are
    trimmed independently and discarded.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.trimmed_mix(stack, u, live, trim)
    k = stack.shape[0]
    payload_shape = stack.shape[1:]
    flat = stack.reshape(k, -1)
    t = flat.shape[1]
    tile = block_rows * _k.LANE
    pad = (-t) % tile
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    rows = (t + pad) // _k.LANE
    out = _k.gossip_mix_2d_trimmed(flat.reshape(k, rows, _k.LANE), u, live,
                                   trim=trim, block_rows=block_rows,
                                   interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)[:t].reshape(payload_shape)


@functools.partial(jax.jit, static_argnames=("trim", "block_rows", "impl"))
def gossip_mix_trimmed_packed(stack: jax.Array, u: jax.Array,
                              live: jax.Array, *, trim: int,
                              block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                              impl: str = "auto") -> jax.Array:
    """:func:`gossip_mix_trimmed` fast path for pre-packed (K, rows, LANE)
    stacks (zero flatten/pad work in the step)."""
    k, rows, lane = stack.shape
    assert lane == _k.LANE and rows % block_rows == 0, (stack.shape,
                                                       block_rows)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.trimmed_mix(stack, u, live, trim)
    return _k.gossip_mix_2d_trimmed(stack, u, live, trim=trim,
                                    block_rows=block_rows,
                                    interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("trim", "block_rows", "impl"))
def gossip_mix_trimmed_quant_packed(fresh: jax.Array, qstack: jax.Array,
                                    scales: jax.Array, u: jax.Array,
                                    live: jax.Array, *, trim: int,
                                    block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                                    impl: str = "auto") -> jax.Array:
    """Dequant-side trimmed mix for the int8 codecs: fresh (rows, LANE) f32,
    qstack (K-1, rows, LANE) int8 received payloads, scales (K-1, n_s) f32
    (n_s = 1 per-buffer, n_s = n_blocks per-row-block). Dequantization
    happens inside the same fused pass as the trim reduction."""
    km1, rows, lane = qstack.shape
    assert lane == _k.LANE and rows % block_rows == 0, (qstack.shape,
                                                       block_rows)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.trimmed_mix_quant(fresh, qstack, scales, u, live, trim)
    return _k.gossip_mix_2d_trimmed_quant(
        fresh, qstack, scales, u, live, trim=trim, block_rows=block_rows,
        interpret=(impl == "pallas_interpret"))


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def packed_sqnorms(buf: jax.Array, *,
                   block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                   impl: str = "auto") -> jax.Array:
    """Per-row-block squared norms of a packed (rows, LANE) buffer:
    (n_blocks,) f32 — the per-sender norm pass of the norm-clip screen
    (per-tile partials reduced on-chip, finished with one tiny lane sum).
    Blocks match the quant codecs' row-block granularity, so int8 wires
    combine these with their per-block scales squared."""
    rows, lane = buf.shape
    assert lane == _k.LANE and rows % block_rows == 0, (buf.shape, block_rows)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.block_sqnorms(buf, block_rows)
    part = _k.sqnorms_2d(buf, block_rows=block_rows,
                         interpret=(impl == "pallas_interpret"))
    return jnp.sum(part, axis=1)
