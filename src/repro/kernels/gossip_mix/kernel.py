"""Pallas TPU kernel: fused weighted reduction of gossip payloads.

Computes ``out = sum_k w[k] * stack[k]`` over a stacked axis of K = d+1
buffers (self + d received neighbor shards) in a single HBM pass.

Why a kernel: the unfused jnp form materializes d intermediate adds, each a
full HBM read+write of the parameter shard; the paper's gossip runs every K
local steps on the *entire* parameter state, so this reduction is pure memory
traffic. The fused kernel reads (d+1) x bytes and writes 1 x bytes — the HBM
lower bound.

Layout: the wrapper flattens/pads the payload to (rows, 128) so tiles are
(sublane=8·m, lane=128)-aligned; the stacked operand is (K, rows, 128) and the
weight vector lives in VMEM as (K, 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256  # 256 x 128 x f32 = 128 KiB per buffer tile


def _mix_kernel(x_ref, w_ref, o_ref):
    """o = sum_k w[k] * x[k]; x tile: (K, BR, LANE), w: (K, 1), o: (BR, LANE)."""
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for k in range(x.shape[0]):  # K is small (d+1), unrolled on the VPU
        acc = acc + w[k, 0].astype(jnp.float32) * x[k].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gossip_mix_2d(stack: jax.Array, weights: jax.Array, *,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False) -> jax.Array:
    """stack: (K, rows, LANE) with rows % block_rows == 0; weights: (K,)."""
    k, rows, lane = stack.shape
    assert lane == LANE and rows % block_rows == 0, (stack.shape, block_rows)
    w2 = weights.reshape(k, 1).astype(jnp.float32)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, block_rows, LANE), lambda i: (0, i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), stack.dtype),
        interpret=interpret,
    )(stack, w2)
