"""Pallas TPU kernel: fused weighted reduction of gossip payloads.

Computes ``out = sum_k w[k] * stack[k]`` over a stacked axis of K = d+1
buffers (self + d received neighbor shards) in a single HBM pass.

Why a kernel: the unfused jnp form materializes d intermediate adds, each a
full HBM read+write of the parameter shard; the paper's gossip runs every K
local steps on the *entire* parameter state, so this reduction is pure memory
traffic. The fused kernel reads (d+1) x bytes and writes 1 x bytes — the HBM
lower bound.

Failure-aware variant (paper §5.2): passing an ``alive`` vector (K,) —
``alive[0]`` for self, ``alive[k]`` = liveness of the k-th received schedule's
sender — switches to the renormalized reduction

    out = sum_k (w[k] * alive[k] / sum_j w[j] * alive[j]) * stack[k]

with a dead self falling back to the identity (``out = stack[0]``). The
renormalization is a K-element scalar fixup computed once per tile on the VPU,
so the masked reduction is still one HBM pass — this is what lets the elastic
runtime treat stragglers as a *data* change (the alive vector is a step
argument) instead of a recompile.

Layout: the wrapper flattens/pads the payload to (rows, 128) so tiles are
(sublane=8·m, lane=128)-aligned; the stacked operand is (K, rows, 128) and the
weight/alive vectors live in VMEM as (K, 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256  # 256 x 128 x f32 = 128 KiB per buffer tile


def _mix_kernel(x_ref, w_ref, o_ref):
    """o = sum_k w[k] * x[k]; x tile: (K, BR, LANE), w: (K, 1), o: (BR, LANE)."""
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for k in range(x.shape[0]):  # K is small (d+1), unrolled on the VPU
        acc = acc + w[k, 0].astype(jnp.float32) * x[k].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _mix_alive_kernel(x_ref, w_ref, a_ref, o_ref):
    """Renormalized masked reduction (see module docstring).

    x tile: (K, BR, LANE); w: (K, 1) raw weights (w0, c, ..., c);
    a: (K, 1) alive weights (a[0] = self). Per-tile scalar math only —
    the payload traffic is identical to `_mix_kernel`.
    """
    x = x_ref[...]
    wa = w_ref[...].astype(jnp.float32) * a_ref[...].astype(jnp.float32)
    tot = jnp.sum(wa)
    # no renormalizable mass (all contributors gated/masked away) => the
    # identity fallback REPLACES the renormalized term: inv is zeroed so
    # tiny fractional mass cannot add a second copy of the row
    ok = (tot > 1e-12).astype(jnp.float32)
    inv = ok / jnp.maximum(tot, 1e-12)
    a_self = a_ref[0, 0].astype(jnp.float32)
    # dead self => identity row (weight 1 on x[0], 0 elsewhere)
    eff0 = a_self * wa[0, 0] * inv + (1.0 - a_self) + a_self * (1.0 - ok)
    acc = eff0 * x[0].astype(jnp.float32)
    for k in range(1, x.shape[0]):  # K is small (d+1), unrolled on the VPU
        acc = acc + (a_self * wa[k, 0] * inv) * x[k].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gossip_mix_2d(stack: jax.Array, weights: jax.Array,
                  alive: jax.Array | None = None, *,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False) -> jax.Array:
    """stack: (K, rows, LANE) with rows % block_rows == 0; weights: (K,);
    alive: optional (K,) per-contributor alive weights (renormalized path)."""
    k, rows, lane = stack.shape
    assert lane == LANE and rows % block_rows == 0, (stack.shape, block_rows)
    w2 = weights.reshape(k, 1).astype(jnp.float32)
    grid = (rows // block_rows,)
    stack_spec = pl.BlockSpec((k, block_rows, LANE), lambda i: (0, i, 0))
    vec_spec = pl.BlockSpec((k, 1), lambda i: (0, 0))
    out_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, LANE), stack.dtype)
    if alive is None:
        return pl.pallas_call(
            _mix_kernel, grid=grid, in_specs=[stack_spec, vec_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(stack, w2)
    a2 = alive.reshape(k, 1).astype(jnp.float32)
    return pl.pallas_call(
        _mix_alive_kernel, grid=grid,
        in_specs=[stack_spec, vec_spec, vec_spec],
        out_specs=out_spec, out_shape=out_shape, interpret=interpret,
    )(stack, w2, a2)
