"""Pallas TPU kernel: fused weighted reduction of gossip payloads.

Computes ``out = sum_k w[k] * stack[k]`` over a stacked axis of K = d+1
buffers (self + d received neighbor shards) in a single HBM pass.

Why a kernel: the unfused jnp form materializes d intermediate adds, each a
full HBM read+write of the parameter shard; the paper's gossip runs every K
local steps on the *entire* parameter state, so this reduction is pure memory
traffic. The fused kernel reads (d+1) x bytes and writes 1 x bytes — the HBM
lower bound.

Failure-aware variant (paper §5.2): passing an ``alive`` vector (K,) —
``alive[0]`` for self, ``alive[k]`` = liveness of the k-th received schedule's
sender — switches to the renormalized reduction

    out = sum_k (w[k] * alive[k] / sum_j w[j] * alive[j]) * stack[k]

with a dead self falling back to the identity (``out = stack[0]``). The
renormalization is a K-element scalar fixup computed once per tile on the VPU,
so the masked reduction is still one HBM pass — this is what lets the elastic
runtime treat stragglers as a *data* change (the alive vector is a step
argument) instead of a recompile.

Layout: the wrapper flattens/pads the payload to (rows, 128) so tiles are
(sublane=8·m, lane=128)-aligned; the stacked operand is (K, rows, 128) and the
weight/alive vectors live in VMEM as (K, 1).

Byzantine-robust variants (the engine's ``screen`` layer):

* ``gossip_mix_2d_trimmed`` replaces the weighted sum with a coordinate-wise
  trimmed mean: per element, the live contributors are ranked by a stable
  O(K^2) comparison network (K = d+1 is tiny, fully unrolled on the VPU),
  the top/bottom ``trim`` values are dropped, and the output renormalizes
  the nonnegative weights over the survivors. Dead/gated senders carry
  ``live = 0`` and are invisible to the order statistics. Same one-HBM-pass
  structure as `_mix_kernel` — the ranking is K^2 elementwise compares over
  data already resident in VMEM.
* ``gossip_mix_2d_trimmed_quant`` is the dequant-side variant for the int8
  codecs: received payloads stay int8 on the wire and dequantize in-register
  (per-buffer or per-row-block scales) before the same trim reduction.
* ``sqnorms_2d`` computes per-row-block partial squared norms (reduced to
  per-lane partials on-chip), the per-sender pass behind the norm-clip
  screen.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256  # 256 x 128 x f32 = 128 KiB per buffer tile


def _mix_kernel(x_ref, w_ref, o_ref):
    """o = sum_k w[k] * x[k]; x tile: (K, BR, LANE), w: (K, 1), o: (BR, LANE)."""
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for k in range(x.shape[0]):  # K is small (d+1), unrolled on the VPU
        acc = acc + w[k, 0].astype(jnp.float32) * x[k].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _mix_alive_kernel(x_ref, w_ref, a_ref, o_ref):
    """Renormalized masked reduction (see module docstring).

    x tile: (K, BR, LANE); w: (K, 1) raw weights (w0, c, ..., c);
    a: (K, 1) alive weights (a[0] = self). Per-tile scalar math only —
    the payload traffic is identical to `_mix_kernel`.
    """
    x = x_ref[...]
    wa = w_ref[...].astype(jnp.float32) * a_ref[...].astype(jnp.float32)
    tot = jnp.sum(wa)
    # no renormalizable mass (all contributors gated/masked away) => the
    # identity fallback REPLACES the renormalized term: inv is zeroed so
    # tiny fractional mass cannot add a second copy of the row
    ok = (tot > 1e-12).astype(jnp.float32)
    inv = ok / jnp.maximum(tot, 1e-12)
    a_self = a_ref[0, 0].astype(jnp.float32)
    # dead self => identity row (weight 1 on x[0], 0 elsewhere)
    eff0 = a_self * wa[0, 0] * inv + (1.0 - a_self) + a_self * (1.0 - ok)
    acc = eff0 * x[0].astype(jnp.float32)
    for k in range(1, x.shape[0]):  # K is small (d+1), unrolled on the VPU
        acc = acc + (a_self * wa[k, 0] * inv) * x[k].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _trimmed_reduce(vals, u, lv, trim, out_shape):
    """Shared trim body: vals = list of K f32 (BR, LANE) tiles, u/lv (K, 1)
    weight/live vectors, trim a *static* per-side drop count. Returns the
    f32 trimmed renormalized mean tile (identity fallback included)."""
    k = len(vals)
    n_live = jnp.sum(lv)
    t = jnp.minimum(jnp.float32(trim),
                    jnp.maximum(jnp.floor((n_live - 1.0) * 0.5), 0.0))
    num = jnp.zeros(out_shape, jnp.float32)
    den = jnp.zeros(out_shape, jnp.float32)
    for i in range(k):  # K = d+1 is small: the network fully unrolls
        rank = jnp.zeros(out_shape, jnp.float32)
        for j in range(k):
            if j == i:
                continue
            # stable ranks (ties broken by stack index) => exactly
            # n_live - 2t survivors per element
            cmp = (vals[j] <= vals[i]) if j < i else (vals[j] < vals[i])
            rank = rank + lv[j, 0] * cmp.astype(jnp.float32)
        surv = lv[i, 0] * ((rank >= t)
                           & (rank < n_live - t)).astype(jnp.float32)
        num = num + surv * u[i, 0] * vals[i]
        den = den + surv * u[i, 0]
    ok = den > 1e-12
    mean = jnp.where(ok, num / jnp.maximum(den, 1e-12), vals[0])
    l0 = lv[0, 0]
    return l0 * mean + (1.0 - l0) * vals[0]


def _mix_trimmed_kernel(x_ref, u_ref, l_ref, o_ref, *, trim):
    """Coordinate-wise trimmed renormalized mean (see module docstring).

    x tile: (K, BR, LANE); u: (K, 1) nonnegative weights; l: (K, 1) 0/1
    participation flags (l[0] = self; 0 => identity fallback).
    """
    x = x_ref[...]
    u = u_ref[...].astype(jnp.float32)
    lv = l_ref[...].astype(jnp.float32)
    vals = [x[i].astype(jnp.float32) for i in range(x.shape[0])]
    o_ref[...] = _trimmed_reduce(vals, u, lv, trim,
                                 o_ref.shape).astype(o_ref.dtype)


def _mix_trimmed_quant_kernel(f_ref, q_ref, s_ref, u_ref, l_ref, o_ref, *,
                              trim):
    """Dequant-side trimmed mix: the self tile is fresh f32, the K-1
    received tiles are int8 with their (per-buffer or per-row-block) f32
    scale riding in s_ref (K-1, 1) — dequantized in-register, then the same
    trim reduction as `_mix_trimmed_kernel`.
    """
    fresh = f_ref[...].astype(jnp.float32)
    q = q_ref[...]
    s = s_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    lv = l_ref[...].astype(jnp.float32)
    vals = [fresh] + [q[i].astype(jnp.float32) * s[i, 0]
                      for i in range(q.shape[0])]
    o_ref[...] = _trimmed_reduce(vals, u, lv, trim,
                                 o_ref.shape).astype(o_ref.dtype)


def _sqnorm_kernel(x_ref, o_ref):
    """Per-lane partial squared norms of one (BR, LANE) tile: o = (1, LANE).
    The host-side wrapper finishes the reduction with one (n_blocks, LANE)
    sum — the payload is read exactly once."""
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.sum(x * x, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gossip_mix_2d(stack: jax.Array, weights: jax.Array,
                  alive: jax.Array | None = None, *,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False) -> jax.Array:
    """stack: (K, rows, LANE) with rows % block_rows == 0; weights: (K,);
    alive: optional (K,) per-contributor alive weights (renormalized path)."""
    k, rows, lane = stack.shape
    assert lane == LANE and rows % block_rows == 0, (stack.shape, block_rows)
    w2 = weights.reshape(k, 1).astype(jnp.float32)
    grid = (rows // block_rows,)
    stack_spec = pl.BlockSpec((k, block_rows, LANE), lambda i: (0, i, 0))
    vec_spec = pl.BlockSpec((k, 1), lambda i: (0, 0))
    out_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, LANE), stack.dtype)
    if alive is None:
        return pl.pallas_call(
            _mix_kernel, grid=grid, in_specs=[stack_spec, vec_spec],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(stack, w2)
    a2 = alive.reshape(k, 1).astype(jnp.float32)
    return pl.pallas_call(
        _mix_alive_kernel, grid=grid,
        in_specs=[stack_spec, vec_spec, vec_spec],
        out_specs=out_spec, out_shape=out_shape, interpret=interpret,
    )(stack, w2, a2)


@functools.partial(jax.jit,
                   static_argnames=("trim", "block_rows", "interpret"))
def gossip_mix_2d_trimmed(stack: jax.Array, u: jax.Array, live: jax.Array, *,
                          trim: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = False) -> jax.Array:
    """Trimmed-mean mix over a packed stack: stack (K, rows, LANE) with
    rows % block_rows == 0; u (K,) nonnegative weights; live (K,) 0/1
    participation flags; trim = static per-side drop count."""
    k, rows, lane = stack.shape
    assert lane == LANE and rows % block_rows == 0, (stack.shape, block_rows)
    u2 = u.reshape(k, 1).astype(jnp.float32)
    l2 = live.reshape(k, 1).astype(jnp.float32)
    grid = (rows // block_rows,)
    stack_spec = pl.BlockSpec((k, block_rows, LANE), lambda i: (0, i, 0))
    vec_spec = pl.BlockSpec((k, 1), lambda i: (0, 0))
    out_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, LANE), stack.dtype)
    return pl.pallas_call(
        functools.partial(_mix_trimmed_kernel, trim=trim), grid=grid,
        in_specs=[stack_spec, vec_spec, vec_spec],
        out_specs=out_spec, out_shape=out_shape, interpret=interpret,
    )(stack, u2, l2)


@functools.partial(jax.jit,
                   static_argnames=("trim", "block_rows", "interpret"))
def gossip_mix_2d_trimmed_quant(fresh: jax.Array, qstack: jax.Array,
                                scales: jax.Array, u: jax.Array,
                                live: jax.Array, *, trim: int,
                                block_rows: int = DEFAULT_BLOCK_ROWS,
                                interpret: bool = False) -> jax.Array:
    """Dequant-side trimmed mix: fresh (rows, LANE) f32 self buffer,
    qstack (K-1, rows, LANE) int8 received payloads, scales (K-1, n_s) f32
    with n_s == 1 (per-buffer) or n_s == rows // block_rows (per-row-block;
    the scale column advances with the grid). u/live are (K,) over
    [self] + received."""
    km1, rows, lane = qstack.shape
    assert lane == LANE and rows % block_rows == 0, (qstack.shape, block_rows)
    assert fresh.shape == (rows, LANE), (fresh.shape, qstack.shape)
    n_blocks = rows // block_rows
    n_s = scales.shape[1]
    assert n_s in (1, n_blocks), (scales.shape, n_blocks)
    k = km1 + 1
    u2 = u.reshape(k, 1).astype(jnp.float32)
    l2 = live.reshape(k, 1).astype(jnp.float32)
    grid = (n_blocks,)
    fresh_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    q_spec = pl.BlockSpec((km1, block_rows, LANE), lambda i: (0, i, 0))
    s_spec = (pl.BlockSpec((km1, 1), lambda i: (0, i)) if n_s == n_blocks
              else pl.BlockSpec((km1, 1), lambda i: (0, 0)))
    vec_spec = pl.BlockSpec((k, 1), lambda i: (0, 0))
    out_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((rows, LANE), fresh.dtype)
    return pl.pallas_call(
        functools.partial(_mix_trimmed_quant_kernel, trim=trim), grid=grid,
        in_specs=[fresh_spec, q_spec, s_spec, vec_spec, vec_spec],
        out_specs=out_spec, out_shape=out_shape, interpret=interpret,
    )(fresh, qstack, scales.astype(jnp.float32), u2, l2)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def sqnorms_2d(buf: jax.Array, *, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False) -> jax.Array:
    """Per-row-block per-lane partial squared norms: (rows, LANE) ->
    (n_blocks, LANE) f32 (callers finish with a lane sum)."""
    rows, lane = buf.shape
    assert lane == LANE and rows % block_rows == 0, (buf.shape, block_rows)
    n_blocks = rows // block_rows
    grid = (n_blocks,)
    in_spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out_spec = pl.BlockSpec((1, LANE), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((n_blocks, LANE), jnp.float32)
    return pl.pallas_call(
        _sqnorm_kernel, grid=grid, in_specs=[in_spec],
        out_specs=out_spec, out_shape=out_shape, interpret=interpret,
    )(buf)
