"""Pallas TPU kernels: int8 quantize / dequantize for gossip payloads.

Beyond-paper optimization for the *collective* roofline term: gossip payloads
are symmetrically quantized to int8 before the ppermute, cutting ICI bytes 4x
(f32) or 2x (bf16). The amax reduction is a cheap jnp reduce in the wrapper;
the kernels do the per-tile scale/round/clip and the fused
dequantize-accumulate.

Two scale granularities share the same kernel bodies:

* per-buffer (`quantize_2d` / `dequant_accumulate_2d`): one f32 scale for the
  whole buffer — error is governed by the buffer-wide amax;
* per-row-block (`quantize_2d_blockwise` / `dequant_accumulate_2d_blockwise`):
  one f32 scale per (block_rows x LANE) kernel tile, selected by the grid
  index map — a tile of small-magnitude parameters no longer inherits the
  quantization step of the buffer's global amax. Only the scalar-operand
  BlockSpecs differ; the payload traffic is identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 256


def _quant_kernel(x_ref, s_ref, q_ref):
    inv = 1.0 / s_ref[0, 0]
    x = x_ref[...].astype(jnp.float32) * inv
    q_ref[...] = jnp.clip(jnp.round(x), -127.0, 127.0).astype(jnp.int8)


def _dequant_acc_kernel(q_ref, s_ref, acc_ref, o_ref):
    """o = acc + alive * c * (q * s).

    s_ref = (1, 2) holding (scale, c), or (1, 3) holding (scale, c, alive) —
    the failure-aware gossip path folds the sender's (renormalized) alive
    weight into the same fused pass instead of adding a masking pass.
    """
    scale = s_ref[0, 0]
    c = s_ref[0, 1]
    if s_ref.shape[1] == 3:
        c = c * s_ref[0, 2]
    o_ref[...] = (acc_ref[...].astype(jnp.float32)
                  + c * scale * q_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def _scatter_acc_kernel(v_ref, i_ref, s_ref, acc_ref, o_ref):
    """o = acc + alive * c * scatter(vals at flat idx).

    ``v_ref`` / ``i_ref`` hold the lane-folded sparse entries — (k_rows,
    LANE) f32 values and int32 flat indices into THIS (dense) buffer, zero-
    padded past k (val 0 at idx 0 is a no-op). ``s_ref`` = (1, 1) holding
    (c,) or (1, 2) holding (c, alive) — the failure-aware gossip path folds
    the sender's renormalized alive weight into the same fused pass, exactly
    like ``_dequant_acc_kernel``. Grid tiles cover the dense accumulator;
    every tile walks all k entries and lands the ones inside its flat range
    (top-k keeps k small — the walk is k scalar ops per tile, while the
    dense copy stays one vector pass).
    """
    c = s_ref[0, 0]
    if s_ref.shape[1] == 2:
        c = c * s_ref[0, 1]
    block_rows, lane = o_ref.shape
    tile = block_rows * lane
    base = pl.program_id(0) * tile
    o_ref[...] = acc_ref[...]
    kr, kl = i_ref.shape

    def body(e, carry):
        j = i_ref[e // kl, e % kl] - base

        @pl.when((j >= 0) & (j < tile))
        def _():
            r = j // lane
            col = j - r * lane
            o_ref[r, col] = (o_ref[r, col].astype(jnp.float32)
                             + c * v_ref[e // kl, e % kl]
                             ).astype(o_ref.dtype)

        return carry

    jax.lax.fori_loop(0, kr * kl, body, 0)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def scatter_accumulate_2d(vals: jax.Array, idx: jax.Array,
                          c_alive: jax.Array, acc: jax.Array, *,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = False) -> jax.Array:
    """Fused sparse scatter-accumulate over a packed (rows, LANE) buffer.

    ``vals`` / ``idx`` are (k_rows, LANE) lane-folded sparse entries (f32 /
    int32, zero-padded); ``c_alive`` is (1, 1) = (c,) or (1, 2) =
    (c, alive weight). The whole sparse set rides into every grid tile
    (index map (0, 0)) — it is ~k_fraction of one tile, so the duplicated
    VMEM traffic is noise next to the dense acc pass."""
    rows, lane = acc.shape
    assert lane == LANE and rows % block_rows == 0
    kr, kl = vals.shape
    assert kl == LANE and idx.shape == vals.shape, (vals.shape, idx.shape)
    n_scalars = int(c_alive.size)
    assert n_scalars in (1, 2), c_alive.shape
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    full = pl.BlockSpec((kr, LANE), lambda i: (0, 0))
    return pl.pallas_call(
        _scatter_acc_kernel,
        grid=(rows // block_rows,),
        in_specs=[full, full,
                  pl.BlockSpec((1, n_scalars), lambda i: (0, 0)), blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), acc.dtype),
        interpret=interpret,
    )(vals, idx.astype(jnp.int32),
      c_alive.reshape(1, n_scalars).astype(jnp.float32), acc)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_2d(x: jax.Array, scale: jax.Array, *,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = False) -> jax.Array:
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _quant_kernel,
        grid=(rows // block_rows,),
        in_specs=[blk, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int8),
        interpret=interpret,
    )(x, scale.reshape(1, 1).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dequant_accumulate_2d(q: jax.Array, scale_c: jax.Array, acc: jax.Array, *,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = False) -> jax.Array:
    """scale_c: (1, 2) = (scale, c) or (1, 3) = (scale, c, alive weight)."""
    rows, lane = q.shape
    assert lane == LANE and rows % block_rows == 0
    n_scalars = int(scale_c.size)
    assert n_scalars in (2, 3), scale_c.shape
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _dequant_acc_kernel,
        grid=(rows // block_rows,),
        in_specs=[blk, pl.BlockSpec((1, n_scalars), lambda i: (0, 0)), blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), acc.dtype),
        interpret=interpret,
    )(q, scale_c.reshape(1, n_scalars).astype(jnp.float32), acc)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_2d_blockwise(x: jax.Array, scales: jax.Array, *,
                          block_rows: int = DEFAULT_BLOCK_ROWS,
                          interpret: bool = False) -> jax.Array:
    """Per-row-block quantize: ``scales`` is (n_blocks,), one f32 scale per
    (block_rows, LANE) tile; tile i reads scales[i] via the grid index map."""
    rows, lane = x.shape
    assert lane == LANE and rows % block_rows == 0
    n_blocks = rows // block_rows
    assert scales.shape == (n_blocks,), (scales.shape, n_blocks)
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _quant_kernel,
        grid=(n_blocks,),
        in_specs=[blk, pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int8),
        interpret=interpret,
    )(x, scales.reshape(n_blocks, 1).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dequant_accumulate_2d_blockwise(q: jax.Array, scale_c: jax.Array,
                                    acc: jax.Array, *,
                                    block_rows: int = DEFAULT_BLOCK_ROWS,
                                    interpret: bool = False) -> jax.Array:
    """Per-row-block fused dequant-accumulate: ``scale_c`` is (n_blocks, 2)
    rows of (scale_b, c) or (n_blocks, 3) rows of (scale_b, c, alive weight) —
    tile i reads its own row, same kernel body as the per-buffer variant."""
    rows, lane = q.shape
    assert lane == LANE and rows % block_rows == 0
    n_blocks = rows // block_rows
    n_scalars = scale_c.shape[-1]
    assert scale_c.shape == (n_blocks, n_scalars) and n_scalars in (2, 3), \
        scale_c.shape
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        _dequant_acc_kernel,
        grid=(n_blocks,),
        in_specs=[blk, pl.BlockSpec((1, n_scalars), lambda i: (i, 0)), blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), acc.dtype),
        interpret=interpret,
    )(q, scale_c.astype(jnp.float32), acc)
