"""Pure-jnp oracle for the quant_gossip kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequant_accumulate(q: jax.Array, scale: jax.Array, c: jax.Array,
                       acc: jax.Array) -> jax.Array:
    return (acc.astype(jnp.float32)
            + c.astype(jnp.float32) * scale.astype(jnp.float32)
            * q.astype(jnp.float32)).astype(acc.dtype)


def _per_row(scales: jax.Array, rows: int, block_rows: int) -> jax.Array:
    """(n_blocks,) per-block scales -> (rows, 1) per-row broadcast."""
    return jnp.repeat(scales.astype(jnp.float32), block_rows)[:rows, None]


def quantize_blockwise(x: jax.Array, scales: jax.Array,
                       block_rows: int) -> jax.Array:
    """Per-row-block oracle: row r uses scales[r // block_rows]."""
    s = _per_row(scales, x.shape[0], block_rows)
    q = jnp.round(x.astype(jnp.float32) / s)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequant_accumulate_blockwise(q: jax.Array, scales: jax.Array,
                                 c: jax.Array, acc: jax.Array,
                                 block_rows: int) -> jax.Array:
    s = _per_row(scales, q.shape[0], block_rows)
    return (acc.astype(jnp.float32)
            + c.astype(jnp.float32) * s * q.astype(jnp.float32)
            ).astype(acc.dtype)


def scatter_accumulate(vals: jax.Array, idx: jax.Array, c: jax.Array,
                       acc: jax.Array) -> jax.Array:
    """acc + c * scatter(vals at flat idx): the sparse top-k accumulation.

    ``idx`` indexes the flattened ``acc``. Top-k indices are unique; padded
    entries carry val = 0 (conventionally at idx 0), so they are no-ops.
    """
    flat = acc.astype(jnp.float32).reshape(-1)
    upd = jnp.asarray(c, jnp.float32) * vals.astype(jnp.float32).reshape(-1)
    flat = flat.at[idx.reshape(-1)].add(upd)
    return flat.reshape(acc.shape).astype(acc.dtype)
