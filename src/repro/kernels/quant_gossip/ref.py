"""Pure-jnp oracle for the quant_gossip kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.round(x.astype(jnp.float32) / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequant_accumulate(q: jax.Array, scale: jax.Array, c: jax.Array,
                       acc: jax.Array) -> jax.Array:
    return (acc.astype(jnp.float32)
            + c.astype(jnp.float32) * scale.astype(jnp.float32)
            * q.astype(jnp.float32)).astype(acc.dtype)
