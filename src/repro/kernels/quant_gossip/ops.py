"""Jitted public wrappers for quant_gossip (any shape/dtype payloads)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.quant_gossip import kernel as _k
from repro.kernels.quant_gossip import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl",))
def quantize_int8(x: jax.Array, impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q:int8 same shape, scale:f32 scalar)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.quantize(x, scale), scale
    shape = x.shape
    t = x.size
    tile = _k.DEFAULT_BLOCK_ROWS * _k.LANE
    pad = (-t) % tile
    xf = x.reshape(-1)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    q = _k.quantize_2d(xf.reshape(-1, _k.LANE), scale,
                       interpret=(impl == "pallas_interpret"))
    return q.reshape(-1)[:t].reshape(shape), scale


@functools.partial(jax.jit, static_argnames=("dtype", "impl"))
def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32,
                    impl: str = "auto") -> jax.Array:
    """Plain dequantize (no accumulate)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


@functools.partial(jax.jit, static_argnames=("impl",))
def dequant_accumulate(q: jax.Array, scale: jax.Array, c, acc: jax.Array,
                       impl: str = "auto") -> jax.Array:
    """acc + c * dequant(q): the fused per-neighbor gossip accumulation."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.dequant_accumulate(q, scale, jnp.asarray(c), acc)
    shape = acc.shape
    t = acc.size
    tile = _k.DEFAULT_BLOCK_ROWS * _k.LANE
    pad = (-t) % tile
    def prep(x):
        xf = x.reshape(-1)
        if pad:
            xf = jnp.pad(xf, (0, pad))
        return xf.reshape(-1, _k.LANE)
    sc = jnp.stack([scale.astype(jnp.float32),
                    jnp.asarray(c, jnp.float32)]).reshape(1, 2)
    out = _k.dequant_accumulate_2d(prep(q), sc, prep(acc),
                                   interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)[:t].reshape(shape)


# ---------------------------------------------------- wire format (one
# collective per schedule): the 4-byte f32 scale rides inside the int8
# buffer as one trailing lane row, so the gossip round ships d single
# ppermutes instead of d (payload, scale) pairs. The extra row is 128
# bytes against a >= 32 KiB tile-aligned payload (<0.4% wire overhead),
# and split_wire's static slice restores the kernel-ready (rows, LANE)
# layout without copies the compiler can't elide.
def fold_scale_into_wire(q: jax.Array, scale: jax.Array) -> jax.Array:
    """(rows, LANE) int8 + f32 scalar -> (rows+1, LANE) int8 wire buffer."""
    sbytes = jax.lax.bitcast_convert_type(
        scale.astype(jnp.float32).reshape(1), jnp.int8).reshape(4)
    row = jnp.zeros((1, q.shape[1]), jnp.int8).at[0, :4].set(sbytes)
    return jnp.concatenate([q, row], axis=0)


def split_wire(wire: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Invert :func:`fold_scale_into_wire`: (payload, f32 scale scalar)."""
    scale = jax.lax.bitcast_convert_type(wire[-1, :4].reshape(1, 4),
                                         jnp.float32).reshape(())
    return wire[:-1], scale


# ------------------------------------- per-row-block wire format: one f32
# scale per (block_rows, LANE) kernel tile instead of per buffer, so a tile
# of small-magnitude parameters (a norm, a bias run) no longer inherits the
# quantization step of the buffer-wide amax (the PR-1 follow-up). All
# n_blocks scales ride inside the shipped int8 buffer as lane-folded
# trailing rows (4 bytes each, 32 scales per row — the PR-3 fold
# generalized), so the gossip round still ships exactly d collectives.
def fold_scales_into_wire(q: jax.Array, scales: jax.Array) -> jax.Array:
    """(rows, LANE) int8 + (n_blocks,) f32 -> (rows + scale_rows, LANE) int8
    wire buffer (see :func:`repro.core.packing.scale_rows`)."""
    from repro.core import packing
    n_blocks = scales.shape[0]
    tail_rows = packing.scale_rows(n_blocks)
    sbytes = jax.lax.bitcast_convert_type(
        scales.astype(jnp.float32), jnp.int8).reshape(-1)
    tail = jnp.zeros((tail_rows * q.shape[1],), jnp.int8)
    tail = tail.at[:sbytes.shape[0]].set(sbytes)
    return jnp.concatenate([q, tail.reshape(tail_rows, q.shape[1])], axis=0)


def split_wire_blockwise(wire: jax.Array,
                         n_blocks: int) -> tuple[jax.Array, jax.Array]:
    """Invert :func:`fold_scales_into_wire`: (payload, (n_blocks,) f32
    scales). All slices are static given ``n_blocks`` (baked from the
    PackSpec), so this is jit-friendly like PR-3's :func:`split_wire`."""
    from repro.core import packing
    tail_rows = packing.scale_rows(n_blocks)
    sbytes = wire[-tail_rows:].reshape(-1)[:packing.SCALE_BYTES * n_blocks]
    scales = jax.lax.bitcast_convert_type(
        sbytes.reshape(n_blocks, packing.SCALE_BYTES), jnp.float32)
    return wire[:-tail_rows], scales.reshape(n_blocks)


# ------------------------------------------- sparse top-k wire format (one
# collective per schedule): k f32 values and their k int32 flat indices both
# bitcast into int8 lane rows of ONE shipped buffer — the same fold that
# carries quant scales, taken to its limit: the whole payload is 8k bytes
# (vs 4 bytes/element dense), so k_fraction = 0.01 ships ~2% of the f32
# wire. Sections are padded to whole rows independently (see
# repro.core.packing.topk_wire_rows) so every slice below is static.
def fold_topk_into_wire(vals: jax.Array, idx: jax.Array) -> jax.Array:
    """(k,) f32 values + (k,) int32 flat indices -> (topk_wire_rows(k), LANE)
    int8 wire buffer (values section first, indices section after)."""
    from repro.core import packing
    half = packing.topk_wire_rows(vals.shape[0]) // 2

    def section(x):
        b = jax.lax.bitcast_convert_type(x, jnp.int8).reshape(-1)
        out = jnp.zeros((half * packing.LANE,), jnp.int8)
        return out.at[:b.shape[0]].set(b).reshape(half, packing.LANE)

    return jnp.concatenate([section(vals.astype(jnp.float32)),
                            section(idx.astype(jnp.int32))], axis=0)


def split_topk_wire(wire: jax.Array, k: int
                    ) -> tuple[jax.Array, jax.Array]:
    """Invert :func:`fold_topk_into_wire`: ((k,) f32 values, (k,) int32 flat
    indices). All slices are static given ``k`` (baked from the codec's
    k_fraction and the PackSpec rows)."""
    from repro.core import packing
    half = wire.shape[0] // 2

    def section(rows, dtype):
        b = rows.reshape(-1)[:packing.SCALE_BYTES * k]
        return jax.lax.bitcast_convert_type(
            b.reshape(k, packing.SCALE_BYTES), dtype).reshape(k)

    return section(wire[:half], jnp.float32), section(wire[half:], jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def scatter_accumulate_packed(vals: jax.Array, idx: jax.Array, c,
                              acc: jax.Array, alive=None, *,
                              block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                              impl: str = "auto") -> jax.Array:
    """Fused acc + alive * c * scatter(vals at flat idx) for pre-packed
    (rows, LANE) buffers — the sparse top-k analogue of
    :func:`dequant_accumulate_packed`: the dense accumulator is read and
    written exactly once while the k sparse entries land in place.

    ``vals`` / ``idx`` are the flat (k,) arrays off the wire
    (:func:`split_topk_wire`); ``alive`` (traced scalar) is the
    failure-aware per-sender weight, folded into the same fused pass.
    """
    rows, lane = acc.shape
    assert lane == _k.LANE and rows % block_rows == 0, (acc.shape, block_rows)
    assert vals.shape == idx.shape and vals.ndim == 1, (vals.shape, idx.shape)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        eff_c = jnp.asarray(c, jnp.float32)
        if alive is not None:
            eff_c = eff_c * jnp.asarray(alive, jnp.float32)
        return _ref.scatter_accumulate(vals, idx, eff_c, acc)
    k = vals.shape[0]
    pad = (-k) % _k.LANE

    def fold(x, fill):
        xf = x.reshape(-1)
        if pad:
            xf = jnp.pad(xf, (0, pad), constant_values=fill)
        return xf.reshape(-1, _k.LANE)

    scalars = [jnp.asarray(c, jnp.float32)]
    if alive is not None:
        scalars.append(jnp.asarray(alive, jnp.float32))
    sc = jnp.stack(scalars).reshape(1, len(scalars))
    return _k.scatter_accumulate_2d(
        fold(vals.astype(jnp.float32), 0.0), fold(idx.astype(jnp.int32), 0),
        sc, acc, block_rows=block_rows,
        interpret=(impl == "pallas_interpret"))


def dequantize_packed(q: jax.Array, scale: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Plain dequantize of a per-buffer-scaled packed payload (the stacked
    engine substrate's gather source; the shard_map substrate uses the fused
    dequant-accumulate kernels instead)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def dequantize_packed_blockwise(q: jax.Array, scales: jax.Array,
                                dtype=jnp.float32, *,
                                block_rows: int = _k.DEFAULT_BLOCK_ROWS
                                ) -> jax.Array:
    """Plain dequantize with per-row-block scales (one f32 per
    ``(block_rows, LANE)`` tile)."""
    deq = q.astype(jnp.float32) * jnp.repeat(scales, block_rows)[:, None]
    return deq.astype(dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def quantize_packed_blockwise(buf: jax.Array, *,
                              block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                              impl: str = "auto"
                              ) -> tuple[jax.Array, jax.Array]:
    """Per-row-block int8 quantize of a pre-packed (rows, LANE) buffer:
    returns (q, (n_blocks,) f32 scales), scale b = block-b amax / 127."""
    rows, lane = buf.shape
    assert lane == _k.LANE and rows % block_rows == 0, (buf.shape, block_rows)
    n_blocks = rows // block_rows
    amax = jnp.max(jnp.abs(buf.astype(jnp.float32)
                           .reshape(n_blocks, block_rows * lane)), axis=1)
    scales = jnp.maximum(amax, 1e-12) / 127.0
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.quantize_blockwise(buf, scales, block_rows), scales
    q = _k.quantize_2d_blockwise(buf, scales, block_rows=block_rows,
                                 interpret=(impl == "pallas_interpret"))
    return q, scales


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def dequant_accumulate_packed_blockwise(q: jax.Array, scales: jax.Array,
                                        c, acc: jax.Array, alive=None, *,
                                        block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                                        impl: str = "auto") -> jax.Array:
    """Fused acc + alive * c * dequant(q) with per-row-block scales — same
    single HBM pass as :func:`dequant_accumulate_packed`; only the scalar
    operand grows to one (scale_b, c[, alive]) row per tile."""
    rows, lane = q.shape
    assert lane == _k.LANE and rows % block_rows == 0, (q.shape, block_rows)
    assert acc.shape == q.shape, (acc.shape, q.shape)
    n_blocks = rows // block_rows
    assert scales.shape == (n_blocks,), (scales.shape, n_blocks)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        eff_c = jnp.asarray(c, jnp.float32)
        if alive is not None:
            eff_c = eff_c * jnp.asarray(alive, jnp.float32)
        return _ref.dequant_accumulate_blockwise(q, scales, eff_c, acc,
                                                 block_rows)
    cols = [scales.astype(jnp.float32),
            jnp.broadcast_to(jnp.asarray(c, jnp.float32), (n_blocks,))]
    if alive is not None:
        cols.append(jnp.broadcast_to(jnp.asarray(alive, jnp.float32),
                                     (n_blocks,)))
    sc = jnp.stack(cols, axis=1)
    return _k.dequant_accumulate_2d_blockwise(
        q, sc, acc, block_rows=block_rows,
        interpret=(impl == "pallas_interpret"))


# ------------------------------------------------- packed (rows, LANE) fast path
@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def quantize_packed(buf: jax.Array, *, block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                    impl: str = "auto") -> tuple[jax.Array, jax.Array]:
    """quantize_int8 for a pre-packed (rows, LANE) buffer (PackSpec layout):
    rows is already a tile multiple, so the kernel runs with no reshape/pad."""
    rows, lane = buf.shape
    assert lane == _k.LANE and rows % block_rows == 0, (buf.shape, block_rows)
    amax = jnp.max(jnp.abs(buf.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        return _ref.quantize(buf, scale), scale
    q = _k.quantize_2d(buf, scale, block_rows=block_rows,
                       interpret=(impl == "pallas_interpret"))
    return q, scale


@functools.partial(jax.jit, static_argnames=("block_rows", "impl"))
def dequant_accumulate_packed(q: jax.Array, scale: jax.Array, c,
                              acc: jax.Array, alive=None, *,
                              block_rows: int = _k.DEFAULT_BLOCK_ROWS,
                              impl: str = "auto") -> jax.Array:
    """dequant_accumulate for pre-packed (rows, LANE) buffers: acc + c*scale*q
    fused in one HBM pass, no reshape/pad in the jitted step.

    ``alive`` (traced scalar) is the failure-aware gossip path's per-sender
    weight (receiver-alive x sender-alive, pre-renormalized); it folds into
    the same fused pass, so masking dead senders costs zero extra HBM traffic.
    """
    rows, lane = q.shape
    assert lane == _k.LANE and rows % block_rows == 0, (q.shape, block_rows)
    assert acc.shape == q.shape, (acc.shape, q.shape)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"
    if impl == "ref":
        eff_c = jnp.asarray(c, jnp.float32)
        if alive is not None:
            eff_c = eff_c * jnp.asarray(alive, jnp.float32)
        return _ref.dequant_accumulate(q, scale, eff_c, acc)
    scalars = [scale.astype(jnp.float32), jnp.asarray(c, jnp.float32)]
    if alive is not None:
        scalars.append(jnp.asarray(alive, jnp.float32))
    sc = jnp.stack(scalars).reshape(1, len(scalars))
    return _k.dequant_accumulate_2d(q, sc, acc, block_rows=block_rows,
                                    interpret=(impl == "pallas_interpret"))
