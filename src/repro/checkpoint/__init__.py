"""Checkpoint substrate: atomic sharded npz store + rotation/elastic manager."""
from repro.checkpoint.manager import CheckpointManager, reshard_clients  # noqa: F401
from repro.checkpoint.store import available_steps, load, save  # noqa: F401
