"""Sharded npz pytree checkpoint store: atomic, manifest-based, resumable.

Layout of one checkpoint:

    <dir>/step_000123/
        manifest.json       # treedef, leaf paths/shapes/dtypes, metadata
        shard_000.npz ...   # leaves, grouped into ~`shard_bytes` files

Writes go to `step_<n>.tmp/` and are renamed into place (atomic on POSIX), so
a crash mid-write can never corrupt the latest checkpoint — the core
requirement for fault-tolerant restarts.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_MANIFEST = "manifest.json"

# dtypes numpy can't serialize natively: stored as same-width integer views
_EXOTIC = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name])
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _leaf_paths(tree: PyTree) -> tuple[list[str], list[Any]]:
    # jax.tree_util spelling: jax.tree.leaves_with_path is absent in this jax
    flat = jax.tree_util.tree_leaves_with_path(tree)
    names = [jax.tree_util.keystr(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    return names, leaves


def save(directory: str, step: int, tree: PyTree, metadata: dict | None = None,
         shard_bytes: int = 1 << 28) -> str:
    """Write a checkpoint; returns the final path."""
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    names, leaves = _leaf_paths(tree)
    arrays = [np.asarray(l) for l in leaves]

    shards: list[list[int]] = [[]]
    acc = 0
    for i, a in enumerate(arrays):
        if acc > 0 and acc + a.nbytes > shard_bytes:
            shards.append([])
            acc = 0
        shards[-1].append(i)
        acc += a.nbytes

    entries = []
    for s_idx, idxs in enumerate(shards):
        fname = f"shard_{s_idx:03d}.npz"
        np.savez(os.path.join(tmp, fname),
                 **{f"leaf_{i}": _to_storable(arrays[i]) for i in idxs})
        for i in idxs:
            entries.append({
                "name": names[i], "index": i, "shard": fname,
                "shape": list(arrays[i].shape), "dtype": str(arrays[i].dtype),
            })

    manifest = {
        "step": step,
        "n_leaves": len(arrays),
        "entries": entries,
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, _MANIFEST)):
                out.append(int(name.split("_")[1]))
    return sorted(out)


def load(directory: str, tree_like: PyTree, step: int | None = None
         ) -> tuple[PyTree, dict]:
    """Restore into the structure of `tree_like`; returns (tree, metadata)."""
    steps = available_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)

    by_index: dict[int, np.ndarray] = {}
    by_shard: dict[str, list[dict]] = {}
    for e in manifest["entries"]:
        by_shard.setdefault(e["shard"], []).append(e)
    for fname, ents in by_shard.items():
        with np.load(os.path.join(path, fname)) as z:
            for e in ents:
                by_index[e["index"]] = _from_storable(z[f"leaf_{e['index']}"],
                                                      e["dtype"])

    names, leaves = _leaf_paths(tree_like)
    if len(leaves) != manifest["n_leaves"]:
        raise ValueError(
            f"structure mismatch: have {len(leaves)} leaves, checkpoint has "
            f"{manifest['n_leaves']}")
    restored = []
    for i, (name, like) in enumerate(zip(names, leaves)):
        arr = by_index[i]
        want = tuple(getattr(like, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {name}: shape {arr.shape} != expected {want}")
        restored.append(arr)
    treedef = jax.tree.structure(tree_like)
    return jax.tree.unflatten(treedef, restored), manifest["metadata"]
