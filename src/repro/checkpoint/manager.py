"""Checkpoint manager: rotation, resume, and elastic client-set resharding."""
from __future__ import annotations

import dataclasses
import shutil
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store

PyTree = Any


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    save_every: int = 10   # rounds

    def maybe_save(self, rnd: int, tree: PyTree, metadata: dict | None = None
                   ) -> str | None:
        if rnd % self.save_every != 0:
            return None
        path = store.save(self.directory, rnd, tree, metadata)
        self._rotate()
        return path

    def _rotate(self) -> None:
        steps = store.available_steps(self.directory)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def restore(self, tree_like: PyTree, step: int | None = None
                ) -> tuple[PyTree, dict] | None:
        try:
            return store.load(self.directory, tree_like, step)
        except FileNotFoundError:
            return None

    def latest_step(self) -> int | None:
        steps = store.available_steps(self.directory)
        return steps[-1] if steps else None


def reshard_clients(stacked: PyTree, old2new: np.ndarray) -> PyTree:
    """Elastic restart: drop dead clients' rows from a client-stacked state.

    old2new[old_client] = new index or -1 (dead) — produced by the overlay's
    splice repair. Used when resuming a checkpoint written before a failure.
    """
    alive = np.asarray([i for i, m in enumerate(old2new) if m >= 0])
    return jax.tree.map(lambda x: jnp.take(jnp.asarray(x), alive, axis=0), stacked)
