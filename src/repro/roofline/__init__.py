"""Roofline analysis: hw constants + scan-aware compiled-HLO cost extraction."""
from repro.roofline import analysis, hlo_cost, hw  # noqa: F401
