"""Scan-aware HLO cost analysis.

`compiled.cost_analysis()` counts a `while` body ONCE regardless of trip
count (verified empirically on this JAX/XLA build), which silently
undercounts every scan-over-layers model by ~n_layers x. This module walks
the compiled HLO *text* instead:

  * builds the computation call graph (fusion `calls=`, `while` body /
    condition, `call`, `conditional`),
  * recovers `while` trip counts from the loop-condition computation (the
    largest integer constant compared against the induction variable — exact
    for `lax.scan`/`fori_loop` lowerings, which is all this codebase emits),
  * accumulates, with trip-count multipliers:
      - dot FLOPs        2 * prod(result_dims) * prod(contracting_dims)
      - collective wire bytes  (same per-op formulas as `analysis.py`)
      - HBM traffic estimate   sum of (result + operand) bytes of every
        non-trivial op at fusion granularity (ops inside fused computations
        don't touch HBM).

The text is post-SPMD-partitioning, so everything is per-device.
Validated against cost_analysis() on scan-free graphs (see tests).
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_NO_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    rest: str   # operands + attrs (raw remainder of the line)


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list[_Op]
    symbols: dict[str, str]  # %name -> result type string


def _parse_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    current: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HEADER_RE.match(line.strip())
        if m and line.strip().endswith("{"):
            current = _Computation(name=m.group(1), ops=[], symbols={})
            comps[current.name] = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        om = _OP_RE.match(line)
        if om:
            op = _Op(name=om.group(1), result_type=om.group(2),
                     opcode=om.group(3), rest=om.group(4))
            current.ops.append(op)
            current.symbols[op.name] = op.result_type
    return comps


def _called_comps(op: _Op) -> list[str]:
    names: list[str] = []
    for attr in ("calls", "body", "to_apply"):
        m = re.search(rf"{attr}=%?([\w.\-]+)", op.rest)
        if m:
            names.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        names.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return names


def _operand_names(op: _Op) -> list[str]:
    # operands are %refs before the closing paren of the op call
    depth = 0
    end = 0
    for i, ch in enumerate(op.rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    operand_str = op.rest[:end]
    return re.findall(r"%([\w.\-]+)", operand_str)


def _trip_count(cond: _Computation) -> int:
    """Largest integer constant in the loop condition computation."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.name + "(" + op.rest)
            m2 = re.search(r"\((-?\d+)\)", "(" + op.rest)
            val = None
            if m2:
                try:
                    val = int(m2.group(1))
                except ValueError:
                    val = None
            if val is not None and val > best:
                best = val
    return best


def _dot_flops(op: _Op, comp: _Computation) -> float:
    result_elems = 1
    for _, dims in _shape_dims(op.result_type):
        for d in dims:
            result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    contract = 1
    if m:
        operands = _operand_names(op)
        if operands:
            lhs_type = comp.symbols.get(operands[0], "")
            dims_list = _shape_dims(lhs_type)
            if dims_list:
                lhs_dims = dims_list[0][1]
                for idx in (int(x) for x in m.group(1).split(",") if x):
                    if idx < len(lhs_dims):
                        contract *= lhs_dims[idx]
    return 2.0 * result_elems * contract


def _collective_wire_bytes(op: _Op, comp: _Computation, world: int) -> int:
    kind = op.opcode.replace("-start", "")
    if kind not in _COLLECTIVES:
        return 0
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", op.rest)
    if m:
        n = max(int(m.group(2)), 1)
    else:
        m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", op.rest)
        n = max(len(m.group(1).split(",")), 1) if m else world
    if kind == "all-gather":
        size = _shape_bytes(op.result_type)
        return size * (n - 1) // max(n, 1)
    if kind == "reduce-scatter":
        size = _shape_bytes(op.result_type)  # scattered (small) result
        return size * (n - 1)
    if kind == "all-reduce":
        size = _shape_bytes(op.result_type)
        return 2 * size * (n - 1) // max(n, 1)
    if kind == "all-to-all":
        size = _shape_bytes(op.result_type)
        return size * (n - 1) // max(n, 1)
    size = _shape_bytes(op.result_type)  # collective-permute
    return size


@dataclasses.dataclass
class HloCost:
    flops: float
    wire_bytes: float
    hbm_bytes: float
    collective_bytes: dict[str, float]
    collective_counts: dict[str, float]
    while_trip_counts: list[int]


def analyze_hlo(text: str, world: int) -> HloCost:
    comps = _parse_computations(text)
    fused = {n for n in comps if n.startswith("fused_") or ".fused" in n
             or n.startswith("wide.") or "fused_computation" in n}
    memo: dict[str, tuple] = {}
    trips: list[int] = []

    colls = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0.0 for k in _COLLECTIVES}

    def cost_of(name: str, stack: frozenset = frozenset(), mult: float = 1.0):
        """Returns (flops, wire, hbm) of one execution of computation `name`;
        collective tallies are accumulated with `mult` applied."""
        if name in stack or name not in comps:
            return (0.0, 0.0, 0.0)
        comp = comps[name]
        flops = wire = hbm = 0.0
        in_fused = name in fused
        for op in comp.ops:
            if op.opcode == "dot":
                flops += _dot_flops(op, comp)
            kind = op.opcode.replace("-start", "")
            if kind in _COLLECTIVES and not op.opcode.endswith("-done"):
                wb = _collective_wire_bytes(op, comp, world)
                wire += wb
                colls[kind] += wb * mult
                coll_counts[kind] += mult
            if (not in_fused and op.opcode not in _NO_MEM_OPS
                    and not op.opcode.endswith("-done")):
                hbm += _shape_bytes(op.result_type)
                for o in _operand_names(op):
                    if o in comp.symbols:
                        hbm += _shape_bytes(comp.symbols[o])
            called = _called_comps(op)
            if op.opcode == "while":
                body = next((c for c in called), None)
                mtc = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                if mtc:  # exact count from the XLA backend config
                    tc = int(mtc.group(1))
                else:  # fall back to the loop-condition constant heuristic
                    mcond = re.search(r"condition=%?([\w.\-]+)", op.rest)
                    tc = 1
                    if mcond and mcond.group(1) in comps:
                        tc = _trip_count(comps[mcond.group(1)])
                trips.append(tc)
                if body:
                    f, w, h = cost_of(body, stack | {name}, mult * tc)
                    flops += f * tc
                    wire += w * tc
                    hbm += h * tc
            elif op.opcode in ("fusion", "call", "conditional", "async-start"):
                for c in called:
                    f, w, h = cost_of(c, stack | {name}, mult)
                    flops += f
                    wire += w
                    hbm += h
            # reduce/sort/scatter to_apply bodies: scalar ops, negligible
        return (flops, wire, hbm)

    entry = None
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: computation with most ops
        entry = max(comps, key=lambda n: len(comps[n].ops))
    flops, wire, hbm = cost_of(entry)
    return HloCost(flops=flops, wire_bytes=wire, hbm_bytes=hbm,
                   collective_bytes=colls, collective_counts=coll_counts,
                   while_trip_counts=trips)
