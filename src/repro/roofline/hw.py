"""TPU v5e hardware constants (the TARGET platform of this framework)."""
from __future__ import annotations

PEAK_FLOPS_BF16 = 197e12     # per chip, bf16
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_PER_LINK = 50e9       # bytes/s per link (~50 GB/s)
HBM_BYTES = 16 * 1024**3     # 16 GiB per chip

CHIPS_SINGLE_POD = 256       # 16 x 16
CHIPS_MULTI_POD = 512        # 2 pods
