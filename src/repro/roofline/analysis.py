"""Roofline terms from a compiled XLA artifact (no hardware required).

Sources:
  * `compiled.cost_analysis()` — HLO FLOPs + bytes accessed. Verified to be
    **per-device** (post-SPMD-partitioning) on this JAX version, so the terms
    below are per-chip without further division.
  * `compiled.as_text()`     — per-device HLO; collective bytes are parsed by
    summing operand sizes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute with per-op wire-byte formulas.

Terms (seconds, per chip):
    compute    = flops / PEAK_FLOPS_BF16
    memory     = bytes_accessed / HBM_BW
    collective = wire_bytes / ICI_BW_PER_LINK
"""
from __future__ import annotations

import dataclasses
import re

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of all array shapes in an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    """Parse replica_groups= in either explicit or iota form."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", line)
    if m:  # iota form [G,S]<=[...]: G groups of size S
        return max(int(m.group(2)), 1)
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str, world: int) -> CollectiveStats:
    """Per-device wire bytes for every collective in (post-SPMD) HLO text.

    Formulas (ring algorithms, per device):
      all-gather      (n-1)/n * output_bytes
      reduce-scatter  (n-1)/n * input_bytes
      all-reduce      2 (n-1)/n * input_bytes
      all-to-all      (n-1)/n * input_bytes
      collective-permute  input_bytes
    `*-start` ops are counted, their `*-done` twins skipped.
    """
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for raw in hlo_text.splitlines():
        line = raw.strip()
        # match "<shape> opname(" occurrences, skip -done ops
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+([a-z\-]+)(?:-start)?\(",
                     line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        kind = next((k for k in _COLLECTIVES if op == k), None)
        if kind is None:
            continue
        n = _group_size(line, world)
        size = _shape_bytes(shape_str)
        if kind == "all-gather":
            wire = size * (n - 1) // max(n, 1)
        elif kind == "reduce-scatter":
            # result shape is the scattered (small) shape; wire ~ result*(n-1)
            wire = size * (n - 1)
        elif kind == "all-reduce":
            wire = 2 * size * (n - 1) // max(n, 1)
        elif kind == "all-to-all":
            wire = size * (n - 1) // max(n, 1)
        else:  # collective-permute
            wire = size
        bytes_by[kind] += wire
        count_by[kind] += 1
    return CollectiveStats(bytes_by_kind=bytes_by, count_by_kind=count_by)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device (scan-aware)
    hbm_bytes: float             # per device (scan-aware estimate)
    wire_bytes: float            # per device (scan-aware)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collectives: dict[str, float]
    collective_counts: dict[str, float]
    xla_flops: float             # raw cost_analysis (undercounts while loops)
    xla_bytes: float
    while_trips: list[int]

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def cost_dict(cost) -> dict:
    """Normalize `compiled.cost_analysis()` across jax versions: some return
    the properties dict directly, others a one-element list of it."""
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return cost


def cost_bytes(cost: dict) -> float:
    cost = cost_dict(cost)
    if "bytes accessed" in cost:
        return float(cost["bytes accessed"])
    return float(sum(v for k, v in cost.items() if k.startswith("bytes accessed")))


def roofline(cost: dict, hlo_text: str, world: int) -> Roofline:
    """Three-term roofline. FLOPs/bytes come from the scan-aware HLO walker
    (`hlo_cost`) because `cost_analysis()` counts while bodies once; the raw
    cost_analysis numbers are kept as a cross-check."""
    from repro.roofline import hlo_cost

    cost = cost_dict(cost)

    hc = hlo_cost.analyze_hlo(hlo_text, world)
    flops = hc.flops
    mem = hc.hbm_bytes
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = mem / hw.HBM_BW
    collective_s = hc.wire_bytes / hw.ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    return Roofline(
        flops=flops, hbm_bytes=mem, wire_bytes=hc.wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, collectives=hc.collective_bytes,
        collective_counts=hc.collective_counts,
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=cost_bytes(cost),
        while_trips=hc.while_trip_counts)


def packing_report(pack_spec) -> dict:
    """Padding overhead of a packed-gossip layout (`core.packing.PackSpec`).

    The packed engine pads each per-dtype flat buffer up to a
    (block_rows x 128)-element tile multiple; every padded byte is shipped
    over ICI d times per round and read by every fused reduction pass, so
    the overhead fraction is a direct multiplier on the gossip roofline
    terms. Smoke-sized models pad heavily (a tile is 128 KiB of f32); real
    architectures should sit well under 1%.
    """
    payload = int(pack_spec.payload_bytes)
    padded = int(pack_spec.padded_bytes)
    return {
        "n_leaves": pack_spec.n_leaves,
        "n_buffers": pack_spec.n_buffers,
        "payload_bytes": payload,
        "padded_bytes": padded,
        "pad_overhead": (padded / payload - 1.0) if payload else 0.0,
    }


def model_flops_train(n_active_params: int, n_tokens: int) -> float:
    """6 N D — fwd (2ND) + bwd (4ND)."""
    return 6.0 * n_active_params * n_tokens


def model_flops_decode(n_active_params: int, batch: int) -> float:
    return 2.0 * n_active_params * batch


def model_flops_prefill(n_active_params: int, n_tokens: int) -> float:
    return 2.0 * n_active_params * n_tokens
