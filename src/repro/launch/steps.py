"""Step builders: DFL train rounds and serving steps with full shardings.

This is where the paper's technique meets the device grid:

* **train round** = vmap over the client axis of (K local momentum steps)
  followed by the *gossip island*: a **fully-manual** `shard_map` over all
  mesh axes (in/out specs = the real parameter partition specs — mixing is
  elementwise, so mixing corresponding local shards is exact and each
  ppermute ships only shard-sized payloads). The executor is ONE engine
  cell (`repro.core.engine`: substrate x codec x timing), parsed from the
  legacy `gossip_impl` string + `gossip_delay` + `gossip_codec`:
  `"ppermute_packed"` (default) = shard_map x f32 x sync — d ppermutes per
  round total (one per schedule, independent of leaf count) + one fused
  Pallas reduction pass; `"ppermute_packed_quant"` = shard_map x
  int8_block x sync — int8 wire payloads with the fold-scales-into-wire
  format (still d collectives); `"ppermute"` / `"ppermute_quant"` are the
  per-leaf baseline substrate (d x n_leaves collectives); `"dense"` is the
  paper-naive dense mixing einsum (the §Perf baseline);
  `"ppermute_packed_async"` + `gossip_delay=1` is the **pipelined** engine:
  the step carries last round's snapshot *in the codec's wire format* as
  donated state, so the d ppermutes read a step input and overlap with the
  local-step scan (one-round-delayed mixing, `gossip.mix_dense_delayed`
  semantics); with `gossip_delay=0` it is bit-identical to
  `"ppermute_packed"`. Pipelined + quantized is the free composition:
  `gossip_impl="ppermute_packed_async"`, `gossip_delay=1`,
  `gossip_codec="int8_block"` ships d int8 wire collectives per round and
  carries a 4x smaller snapshot.

  The train step takes a per-client ``alive`` 0/1 vector as its **fourth,
  donated argument** and a per-schedule ``gates`` float vector (the
  time-varying round plan, `repro.overlay.plan`) as its **fifth, donated
  argument** — replicated f32 arrays threaded into the gossip island as
  plain data. On the packed paths (and the dense reference) dead senders
  and gated-off schedules are masked out of the reduction and survivors
  renormalize over their gated live in-degree (`mix_dense_gated`
  semantics), so transient stragglers AND round-plan changes (one-peer
  rotation, schedule subsets, throttling) cost **zero recompiles**: the
  round's liveness and topology-of-the-round are step arguments, never
  baked into the traced graph. Only membership *changes* (splice repair
  rebuilding the overlay) re-jit. The per-leaf ppermute baselines ignore
  both — the packed engine is the only failure/plan-handling path (see
  `core/failures.py`, `repro.overlay`).
* **serve steps** (prefill / decode) run on the raw production mesh with
  TP ("model") x batch-DP ("data"/"pod") and sequence-sharded KV caches.

Every builder returns (jitted_fn, input_specs_dict) so the dry-run can
`.lower(**specs).compile()` without touching device memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import DFLConfig, ModelConfig, ParallelConfig, ShapeConfig
from repro.core import dfedavg, engine as engine_lib, gossip as gossip_lib, \
    packing as packing_lib, topology
from repro.launch import mesh as mesh_lib
from repro.models import params as params_lib
from repro.models.api import ModelAPI
from repro.models.params import Leaf
from repro.models.sharding_ctx import activation_sharding
from repro.telemetry.metrics import TelemetryConfig

PyTree = Any


# ---------------------------------------------------------------- helpers
def local_shard_structs(struct: PyTree, pspecs: PyTree, mesh: Mesh) -> PyTree:
    """Per-device shard shapes inside the fully-manual gossip island, with the
    (fully-sharded, local size 1) leading client dim stripped. This is what a
    PackSpec for the packed gossip executors must be built from."""

    def one(leaf: Leaf, spec) -> jax.ShapeDtypeStruct:
        parts = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        dims = tuple(size // params_lib._mesh_axis_size(mesh, axis)
                     for size, axis in zip(leaf.shape[1:], parts[1:]))
        return jax.ShapeDtypeStruct(dims, jnp.dtype(leaf.dtype))

    return jax.tree.map(one, struct, pspecs,
                        is_leaf=lambda x: isinstance(x, Leaf))


def add_client_axis(struct: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda l: Leaf((n,) + l.shape, ("clients",) + l.axes, l.dtype, l.init,
                       l.scale),
        struct, is_leaf=lambda x: isinstance(x, Leaf))


def build_overlay(n: int, dfl: DFLConfig) -> topology.Overlay | None:
    """Overlay for `n` clients from the graph-family registry
    (:mod:`repro.overlay.registry`); degenerate sizes handled explicitly."""
    from repro.overlay import registry as overlay_registry

    if n < 2:
        return None
    if n == 2:
        return topology.Overlay(
            n=2, schedules=[np.array([1, 0])], name="pair")
    if dfl.topology == "ring" or n == 3:
        return topology.ring_overlay(n)
    d = min(dfl.degree, n - 1)
    if dfl.topology == "expander" and d % 2 == 1 and n % 2 == 1:
        d = max(2, d - 1)  # odd degree needs a perfect matching (even n)
    overlay, _meta = overlay_registry.build(dfl.topology, n, degree=d,
                                            seed=dfl.seed)
    return overlay


# ------------------------------------------------------------ train round
@dataclasses.dataclass(frozen=True)
class TrainSetup:
    # jitted (params, batch, lr, alive, gates, *extra) -> (params, metrics
    # [, inflight]); params, the (n_clients,) f32 alive vector, the
    # (n_schedules,) f32 gate vector, and every extra operand are DONATED —
    # ship fresh vectors per round. The extra operands appear in this fixed
    # order, each gated by its config knob (absent knob = absent argument;
    # a default config keeps the historical 5-argument signature and HLO):
    #   active      (n_clients,) f32   DFLConfig.active_set != "full" —
    #               round-level participation vector (repro.overlay.plan
    #               active-set plans); multiplies the alive mask
    #   attack      (2, n_clients) f32 DFLConfig.byzantine —
    #               failures.AttackPlan.round_vector operand
    #   attack_key  (2,) uint32        DFLConfig.byzantine — PRNG key
    #   inflight    wire-state tuple   gossip_delay=1 (pipelined) — last
    #               round's in-flight snapshot; the step also RETURNS the
    #               new snapshot as a third output. Prime it once with
    #               init_inflight(params) (round 0 then mixes the initial
    #               params as its delayed snapshot).
    #   codec_state state tuple        stateful codec (e.g. "topk_ef") —
    #               the per-client codec state (the EF residual), in the
    #               codec's state_struct layout; the step RETURNS the
    #               updated state as its LAST output. Prime it once with
    #               init_codec_state(params).
    #   cheby       (sub_rounds,) f32  gossip_sub_rounds > 1 (Chebyshev
    #               multi-round gossip) — the per-sub-round coefficient
    #               vector (``cheby_coeffs`` holds the host value for the
    #               current overlay; refresh it after a splice repair)
    # input_specs holds a ShapeDtypeStruct per present operand, in call
    # order, so callers can assemble the argument list generically.
    step_fn: Any
    param_specs: PyTree            # PartitionSpecs (client-stacked)
    param_struct: PyTree           # Leaf pytree (client-stacked)
    input_specs: dict              # ShapeDtypeStructs: batch, lr, alive,
    #                                gates (+ inflight in pipelined mode)
    in_shardings: Any
    overlay: topology.Overlay | None
    gossip_spec: gossip_lib.GossipSpec | None
    dfl_mesh: Mesh
    n_clients: int
    pack_spec: packing_lib.PackSpec | None = None  # packed-gossip layout
    gossip_delay: int = 0          # 1 = pipelined (one-round-delayed) gossip
    init_inflight: Any = None      # jitted params -> in-flight snapshot
    init_codec_state: Any = None   # jitted params -> codec state (stateful
    #                                codecs only; None otherwise)
    # the parsed engine cell (substrate x codec x timing) the step runs on
    engine_config: engine_lib.GossipEngineConfig | None = None
    # exact per-client wire bytes one round ships (0 when untelemetered /
    # no overlay) — the static fact behind metrics["telemetry"]["wire_bytes"]
    wire_bytes_per_round: int = 0
    # host-side (sub_rounds,) f32 Chebyshev coefficients for the baked
    # overlay (None unless gossip_sub_rounds > 1) — ship as the "cheby"
    # operand; same shape forever, so refreshed values never retrace
    cheby_coeffs: Any = None


def _train_rules(caxes: tuple[str, ...], zero3: bool = True) -> dict:
    return {
        "clients": caxes if len(caxes) > 1 else caxes[0],
        # zero3: shard the non-TP dim of every weight over the within-client
        # DP axes (ZeRO-3: weights gathered per use). zero3=False replicates
        # weights over fsdp/dp — more HBM, no per-layer weight all-gathers.
        "embed": ("fsdp", "dp") if zero3 else None,
        "vocab": "tp", "vocab_in": "tp", "ffn": "tp", "heads": "tp",
        "kv_heads": "tp",
        # experts shard on the EP ("tp") axis when divisible; few-expert
        # MoEs (grok: 8 experts, 16-way EP axis) leave E unsharded and rely on
        # the "ffn" tag to shard the per-expert hidden dim instead
        "experts": "tp",
        "layers": None,
    }


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, base_mesh: Mesh,
                     par: ParallelConfig, dfl: DFLConfig,
                     gossip_spec_override: gossip_lib.GossipSpec | None = None
                     ) -> TrainSetup:
    api = ModelAPI(cfg)
    dmesh = mesh_lib.derive_dfl_mesh(base_mesh, par.clients_per_pod, par.tp)
    caxes = mesh_lib.client_axes(dmesh)
    n_cl = mesh_lib.n_clients(dmesh)
    if shape.global_batch % n_cl:
        raise ValueError(f"global_batch {shape.global_batch} % clients {n_cl}")
    local_b = shape.global_batch // n_cl

    overlay = build_overlay(n_cl, dfl)
    gspec = gossip_spec_override
    if gspec is None and overlay is not None:
        gspec = gossip_lib.make_gossip_spec(overlay)
    n_sched = gspec.degree if gspec is not None else 0

    # ---- parameter structure + sharding
    struct1 = api.param_struct()
    struct = add_client_axis(struct1, n_cl)
    rules = _train_rules(caxes, zero3=par.zero3)
    # expert placement: EP ("model") axis when divisible; otherwise E stays
    # unsharded and the per-expert hidden dim carries the TP split ("ffn"
    # tag). (Sharding E over fsdp was measured and REFUTED: mismatched
    # buffer/weight layouts made XLA reshard the big buffers — see
    # EXPERIMENTS.md §Perf.)
    expert_axis = None
    if cfg.moe is not None:
        if cfg.moe.n_experts % dmesh.shape["tp"] == 0:
            expert_axis = "tp"
        rules = dict(rules, experts=expert_axis)
    pspecs = params_lib.partition_specs(struct, rules, dmesh)
    client_spec = rules["clients"]

    # ---- batch specs
    bshape = (n_cl, par.local_steps)
    if par.grad_accum > 1:
        if local_b % par.grad_accum:
            raise ValueError(f"local batch {local_b} % grad_accum {par.grad_accum}")
    batch_specs = {
        "tokens": jax.ShapeDtypeStruct(bshape + (local_b, shape.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct(bshape + (local_b, shape.seq_len), jnp.int32),
    }
    batch_pspec = {
        "tokens": P(client_spec, None, ("fsdp", "dp"), None),
        "labels": P(client_spec, None, ("fsdp", "dp"), None),
    }
    if cfg.stub_prefix:
        batch_specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            bshape + (local_b, cfg.stub_prefix, cfg.d_model), jnp.dtype(cfg.dtype))
        batch_pspec["prefix_embeds"] = P(client_spec, None, ("fsdp", "dp"), None, None)

    dcfg = dfedavg.DFedAvgMConfig(
        local_steps=par.local_steps, lr=dfl.lr, momentum=dfl.momentum,
        reset_momentum=True, grad_accum=par.grad_accum)

    remat = par.remat == "block"
    update_fn = None
    if par.use_fused_sgdm:
        from repro.kernels.fused_sgdm.ops import sgdm_update
        update_fn = sgdm_update

    def loss_fn(p, b):
        return api.loss_fn(p, b, remat=remat)

    def client_round(p, b, lr):
        v = jax.tree.map(jnp.zeros_like, p)  # paper: momentum resets per round
        p, _v, loss = dfedavg.local_round(p, v, b, loss_fn, dcfg, lr=lr,
                                          update_fn=update_fn)
        return p, loss

    # ---- gossip island (fully-manual shard_map over the real param specs:
    # mixing is elementwise, so each device mixes its local shard in place —
    # no resharding, and every ppermute ships only shard-sized payloads).
    # The legacy gossip_impl string (+ gossip_delay / gossip_codec) parses
    # into ONE engine cell — substrate x codec x timing — and the executor
    # is assembled by repro.core.engine; there is no per-variant dispatch
    # below this point.
    if par.gossip_delay not in (0, 1):
        raise ValueError(f"gossip_delay must be 0 or 1, got {par.gossip_delay}")
    ecfg = engine_lib.parse_gossip_impl(par.gossip_impl, par.gossip_delay,
                                        par.gossip_codec, par.gossip_screen,
                                        par.gossip_clip_tau,
                                        par.gossip_trim_f,
                                        sub_rounds=par.gossip_sub_rounds,
                                        telemetry=(TelemetryConfig()
                                                   if par.gossip_telemetry
                                                   else None))
    pack_spec = None
    if ecfg.substrate == "shard_map":
        pack_spec = packing_lib.make_pack_spec(
            local_shard_structs(struct, pspecs, dmesh))

    # pipelined gossip: delay=1 is only meaningful (and only legal) on the
    # async packed impl; the async impl with delay=0 degrades to the exact
    # synchronous packed path (bit-identical — the regression anchor)
    use_delay = (ecfg.delay == 1 and gspec is not None and overlay is not None)
    run_cfg = ecfg if use_delay else dataclasses.replace(ecfg, delay=0)
    axis = caxes if len(caxes) > 1 else caxes[0]
    executor = None
    if gspec is not None and overlay is not None:
        executor = engine_lib.build_gossip_executor(
            run_cfg, gspec,
            axis_names=(axis if run_cfg.substrate in ("shard_map", "per_leaf")
                        else None),
            pack_spec=pack_spec)

    # build-time decision: the gate pathway only engages when the config
    # names a real round plan. A static run keeps the exact (possibly
    # negative-w0) Chow weights of the plain engine — gating with all-ones
    # would clamp those rows to the lazy variant and silently change
    # numerics (the gates argument is still accepted and simply unused).
    # The name is validated so a typo errors instead of silently flipping
    # the gate semantics; this rule must agree with plan_lib.is_active
    # (see launch/elastic.py's StepBuilder note).
    from repro.overlay import plan as plan_lib
    if dfl.round_plan not in plan_lib.PLAN_NAMES:
        raise ValueError(f"unknown round_plan {dfl.round_plan!r}; "
                         f"available: {', '.join(plan_lib.PLAN_NAMES)}")
    use_gates = dfl.round_plan != "static"
    # round-level client subsampling (active-set plans): same build-time
    # rule as gates — "full" keeps the historical 5-argument signature (and
    # its exact HLO), any real plan appends one donated (n,) vector. The
    # active set multiplies the alive mask OUTSIDE the gossip island, so
    # inactive clients get identity rows exactly like stragglers — but the
    # product never feeds the health tracker (see repro.overlay.plan).
    if dfl.active_set not in plan_lib.ACTIVE_SET_NAMES:
        raise ValueError(
            f"unknown active_set {dfl.active_set!r}; "
            f"available: {', '.join(plan_lib.ACTIVE_SET_NAMES)}")
    use_active = dfl.active_set != "full"

    # in-graph telemetry (build-time branch, same discipline as gates /
    # active / delay: config decides at trace time, off lowers to the exact
    # untelemetered HLO). The island additionally returns the executor's
    # RoundMetrics as per-DEVICE partials — each metric leaf gains one
    # leading dim per mesh axis with out_spec P(*axis_names), so NO
    # collective aggregates them in-graph; the host sums the device partials
    # (repro.telemetry.summarize_metrics — a per-shard proxy for leaves
    # replicated over fsdp/tp, which count once per copy).
    use_tel = run_cfg.telemetry is not None and executor is not None
    wire_bytes = executor.wire_bytes_per_round() if use_tel else 0
    axis_names = tuple(dmesh.axis_names)
    axis_sizes = tuple(int(dmesh.shape[a]) for a in axis_names)
    lead = (1,) * len(axis_sizes)
    tel_spec = P(*axis_names)
    # Chebyshev multi-round gossip (sub_rounds > 1): the (k,) coefficient
    # vector rides as one more donated replicated operand next to
    # alive/gates — plain data, zero retraces across coefficient refreshes
    # (a splice repair recomputes it from the rebuilt spec's lambda). The
    # engine-config validation guarantees the cheby cell is sync (delay=0),
    # screenless and stateless, so only the plain gossip_fn carries it; a
    # sub_rounds=1 build keeps the exact historical signature and HLO.
    use_cheby = executor is not None and run_cfg.sub_rounds > 1

    def gossip_fn(params, alive, gates, *maybe_cheby):
        if executor is None:
            return params
        if run_cfg.substrate == "dense":
            # paper-naive dense baseline, on the gated+masked effective
            # matrix (gates/alive are traced data here too)
            return executor(params, alive=alive,
                            gates=gates if use_gates else None)

        def body(p, alive_vec, gate_vec, *rest):
            local = jax.tree.map(lambda x: x[0], p)       # client-local shard
            # alive + round-plan gates ride into the island replicated; only
            # the packed engine is failure/plan-aware (the per-leaf
            # baseline substrate ignores both, and a static config drops
            # the gate pathway at trace time)
            kw = dict(alive=alive_vec,
                      gates=gate_vec if use_gates else None)
            if use_cheby:
                kw["cheby"] = rest[0]
            if use_tel:
                mixed, met = executor(local, **kw)
                return (jax.tree.map(lambda x: x[None], mixed),
                        jax.tree.map(lambda x: x.reshape(lead + x.shape),
                                     met))
            mixed = (executor(local)
                     if run_cfg.substrate == "per_leaf"
                     else executor(local, **kw))
            return jax.tree.map(lambda x: x[None], mixed)

        in_specs = (pspecs, P(), P()) + ((P(),) if use_cheby else ())
        args = (params, alive, gates) + tuple(maybe_cheby)
        if use_tel:
            return mesh_lib.shard_map(
                body, dmesh, in_specs=in_specs,
                out_specs=(pspecs, tel_spec))(*args)
        return mesh_lib.shard_map(body, dmesh, in_specs=in_specs,
                                  out_specs=pspecs)(*args)

    # ---- pipelined gossip state (delay=1): the in-flight snapshot is the
    # per-device *codec wire* of last round's post-local-step shards (the
    # packed f32 buffer for codec="f32", the folded int8 wire buffer for the
    # quantized codecs — so pipelined+quantized carries and ships int8
    # bytes). Its global representation carries one leading dim per mesh
    # axis (each sharded over that axis), so the fully-manual island sees
    # exactly one (rows, LANE) block per device — the state never reshards.
    inflight_structs = inflight_pspecs = None
    if use_delay:
        local_state_structs = executor.state_structs()
        inflight_pspecs = tuple(P(*axis_names, None, None)
                                for _ in local_state_structs)
        inflight_structs = tuple(
            jax.ShapeDtypeStruct(axis_sizes + s.shape, s.dtype)
            for s in local_state_structs)

        def gossip_fn_delayed(params, alive, gates, inflight):
            def body(p, alive_vec, gate_vec, state):
                local = jax.tree.map(lambda x: x[0], p)
                state_local = tuple(s.reshape(s.shape[-2:]) for s in state)
                if use_tel:
                    mixed, new_state, met = executor(
                        local, state=state_local, alive=alive_vec,
                        gates=gate_vec if use_gates else None)
                    return (jax.tree.map(lambda x: x[None], mixed),
                            tuple(s.reshape(lead + s.shape)
                                  for s in new_state),
                            jax.tree.map(lambda x: x.reshape(lead + x.shape),
                                         met))
                mixed, new_state = executor(
                    local, state=state_local, alive=alive_vec,
                    gates=gate_vec if use_gates else None)
                return (jax.tree.map(lambda x: x[None], mixed),
                        tuple(s.reshape(lead + s.shape) for s in new_state))

            out_specs = ((pspecs, inflight_pspecs, tel_spec) if use_tel
                         else (pspecs, inflight_pspecs))
            return mesh_lib.shard_map(
                body, dmesh, in_specs=(pspecs, P(), P(), inflight_pspecs),
                out_specs=out_specs)(params, alive, gates, inflight)

        def snapshot_fn(params):
            """Prime the pipeline: encode the current post-mix params into
            the in-flight wire layout (round 0 then mixes the initial params
            as its delayed snapshot — the mix_dense_delayed convention)."""
            def body(p):
                local = jax.tree.map(lambda x: x[0], p)
                bufs = executor.init_state(local)
                return tuple(b.reshape(lead + b.shape) for b in bufs)

            return mesh_lib.shard_map(body, dmesh, in_specs=(pspecs,),
                                      out_specs=inflight_pspecs)(params)

    # ---- stateful codec (e.g. "topk_ef"): the per-client codec state (the
    # error-feedback residual) is a SECOND threaded state channel, parallel
    # to the delay snapshot: one f32 (rows, LANE) buffer per packed buffer
    # per device, carried as a donated step operand and returned as the
    # step's LAST output. Same sharding discipline as the in-flight
    # snapshot — one leading dim per mesh axis, so the island sees exactly
    # its own (rows, LANE) block and the state never reshards.
    use_cstate = (executor is not None and executor.stateful
                  and run_cfg.substrate == "shard_map")
    cstate_structs = cstate_pspecs = None
    if use_cstate:
        local_cstate_structs = executor.codec_state_structs()
        cstate_pspecs = tuple(P(*axis_names, None, None)
                              for _ in local_cstate_structs)
        cstate_structs = tuple(
            jax.ShapeDtypeStruct(axis_sizes + s.shape, s.dtype)
            for s in local_cstate_structs)

        def gossip_fn_stateful(params, alive, gates, cstate, inflight=None):
            def body(p, alive_vec, gate_vec, cst, *maybe_state):
                local = jax.tree.map(lambda x: x[0], p)
                kw = dict(codec_state=tuple(s.reshape(s.shape[-2:])
                                            for s in cst),
                          alive=alive_vec,
                          gates=gate_vec if use_gates else None)
                if use_delay:
                    kw["state"] = tuple(s.reshape(s.shape[-2:])
                                        for s in maybe_state[0])
                out = executor(local, **kw)
                rest = list(out[1:])
                res = [jax.tree.map(lambda x: x[None], out[0])]
                if use_delay:
                    res.append(tuple(s.reshape(lead + s.shape)
                                     for s in rest.pop(0)))
                res.append(tuple(s.reshape(lead + s.shape)
                                 for s in rest.pop(0)))
                if use_tel:
                    res.append(jax.tree.map(
                        lambda x: x.reshape(lead + x.shape), rest.pop(0)))
                return tuple(res)

            in_specs = (pspecs, P(), P(), cstate_pspecs) \
                + ((inflight_pspecs,) if use_delay else ())
            out_specs = (pspecs,) \
                + ((inflight_pspecs,) if use_delay else ()) \
                + (cstate_pspecs,) + ((tel_spec,) if use_tel else ())
            args = (params, alive, gates, cstate) \
                + ((inflight,) if use_delay else ())
            return mesh_lib.shard_map(body, dmesh, in_specs=in_specs,
                                      out_specs=out_specs)(*args)

        def cstate_init_fn(params):
            """Prime the codec state (the topk_ef EF residual starts at
            zeros: nothing has been dropped yet)."""
            def body(p):
                local = jax.tree.map(lambda x: x[0], p)
                bufs = executor.init_codec_state(local)
                return tuple(b.reshape(lead + b.shape) for b in bufs)

            return mesh_lib.shard_map(body, dmesh, in_specs=(pspecs,),
                                      out_specs=cstate_pspecs)(params)

    # activation constraints visible inside the vmapped client round
    act_rules = {}
    if par.seq_parallel:
        # Megatron-SP: residual stream sequence-sharded over the TP axis —
        # GSPMD then lowers each TP boundary to reduce-scatter + all-gather
        # (half the wire bytes of the all-reduce it replaces). Measured: on
        # this XLA it *adds* seq all-gathers instead; kept off by default.
        act_rules["residual"] = NamedSharding(dmesh, P(None, "tp", None))
        act_rules["activation"] = NamedSharding(dmesh, P(None, "tp", None))
    if cfg.moe is not None:
        # buffers: E on the EP axis when sharded, capacity over fsdp so no
        # fsdp row computes a redundant expert matmul
        buf_spec = P(expert_axis, ("fsdp", "dp"), None)
        act_rules["moe_buffer"] = NamedSharding(dmesh, buf_spec)
        if expert_axis is None:
            # E-unsharded experts (grok): gather d from fsdp in bf16 here
            # (not a f32 copy), keep f on the TP axis. NOT applied to
            # EP-sharded experts (kimi) — measured: gathering d for 1T params
            # per microbatch regressed collective 456 -> 770 s.
            act_rules["expert_weights"] = NamedSharding(dmesh, P(None, None, "tp"))
            act_rules["expert_weights_t"] = NamedSharding(dmesh, P(None, "tp", None))

    # Byzantine attacker harness (dfl.byzantine): the step additionally
    # takes the (2, n) AttackPlan.round_vector operand and a (2,) uint32
    # PRNG key as traced DATA (donated, like alive/gates), perturbing the
    # post-local-step client-stacked params before they hit the wire —
    # attacker churn and attack-free rounds share the single trace
    use_attack = dfl.byzantine
    if use_attack:
        from repro.core import failures as failures_lib

    def _local_phase(params, batch, lr):
        # spmd_axis_name threads the client mesh axes through every
        # sharding constraint inside the vmapped round
        return jax.vmap(client_round, in_axes=(0, 0, None),
                        spmd_axis_name=caxes)(params, batch, lr)

    # ---- the ONE step function. Optional data operands (active-set vector,
    # attack operand + key, in-flight snapshot) ride as *extra positional
    # arguments in the fixed order below; a default config has an empty
    # extra list and lowers to the exact historical 5-argument HLO.
    extra_names = (["active"] if use_active else []) \
        + (["attack", "attack_key"] if use_attack else []) \
        + (["inflight"] if use_delay else []) \
        + (["codec_state"] if use_cstate else []) \
        + (["cheby"] if use_cheby else [])

    def train_step(params, batch, lr, alive, gates, *extra):
        kw = dict(zip(extra_names, extra))
        # active-set subsampling composes by masking: an inactive client is
        # mixed like a straggler (identity row, neighbors drop it and
        # renormalize) — the multiply happens outside the gossip island so
        # the island's trace is independent of whether a plan is on
        eff_alive = alive * kw["active"] if use_active else alive
        out_state = out_cstate = tel_met = None
        with activation_sharding(act_rules):
            params, loss = _local_phase(params, batch, lr)
            if use_attack:
                params = failures_lib.apply_attack(params, kw["attack"],
                                                   kw["attack_key"])
            if use_cstate:
                island = list(gossip_fn_stateful(
                    params, eff_alive, gates, kw["codec_state"],
                    kw.get("inflight")))
                params = island.pop(0)
                if use_delay:
                    out_state = island.pop(0)
                out_cstate = island.pop(0)
                if use_tel:
                    tel_met = island.pop(0)
            elif use_delay:
                # the d ppermutes inside gossip_fn_delayed read only the
                # snapshot (a step input), so the scheduler overlaps them
                # with the local-step scan
                island = gossip_fn_delayed(params, eff_alive, gates,
                                           kw["inflight"])
                if use_tel:
                    params, out_state, tel_met = island
                else:
                    params, out_state = island
            elif use_tel:
                params, tel_met = gossip_fn(
                    params, eff_alive, gates,
                    *((kw["cheby"],) if use_cheby else ()))
            else:
                params = gossip_fn(
                    params, eff_alive, gates,
                    *((kw["cheby"],) if use_cheby else ()))
        metrics = {"loss": jnp.mean(loss)}
        if use_tel:
            tel_met = dict(tel_met)
            # exact per-codec wire bytes (a static wire_struct fact) and the
            # attack-vector energy (zero on all-honest rounds) ride as
            # replicated scalars next to the island's per-device partials
            tel_met["wire_bytes"] = jnp.float32(wire_bytes)
            if use_attack:
                atk = kw["attack"]
                tel_met["attack_energy"] = (jnp.sum((atk[0] - 1.0) ** 2)
                                            + jnp.sum(atk[1] ** 2))
            metrics["telemetry"] = tel_met
        out = (params, metrics)
        if use_delay:
            out = out + (out_state,)
        if use_cstate:
            out = out + (out_cstate,)
        return out

    param_shardings = jax.tree.map(lambda s: NamedSharding(dmesh, s), pspecs)
    repl = NamedSharding(dmesh, P())
    in_shardings = [
        param_shardings,
        jax.tree.map(lambda s: NamedSharding(dmesh, s), batch_pspec),
        repl,
        repl,
        repl,
    ]
    metrics_shardings = NamedSharding(dmesh, P())
    if use_tel:
        # the telemetry subtree keeps the island's per-device layout (one
        # leading dim per mesh axis) — forcing it replicated here would
        # make jit insert the very all-gather telemetry promises not to add
        tel_shardings = {k: NamedSharding(dmesh, tel_spec)
                         for k in executor.metrics_structs()}
        tel_shardings["wire_bytes"] = NamedSharding(dmesh, P())
        if use_attack:
            tel_shardings["attack_energy"] = NamedSharding(dmesh, P())
        metrics_shardings = {"loss": NamedSharding(dmesh, P()),
                             "telemetry": tel_shardings}
    out_shardings = (
        param_shardings,
        metrics_shardings,
    )
    input_specs = {"batch": batch_specs,
                   "lr": jax.ShapeDtypeStruct((), jnp.float32),
                   "alive": jax.ShapeDtypeStruct((n_cl,), jnp.float32),
                   "gates": jax.ShapeDtypeStruct((n_sched,), jnp.float32)}
    # alive (argnum 3), the round-plan gates (argnum 4), and every extra
    # operand are donated with the params: each round ships fresh vectors
    # and the previous ones are dead weight. Consequence: callers must NOT
    # reuse a cached device array across rounds (it is consumed); build the
    # mask/gates/active per round (ElasticTrainer does)
    donate = [0, 3, 4]
    extra_specs = {
        "active": jax.ShapeDtypeStruct((n_cl,), jnp.float32),
        "attack": jax.ShapeDtypeStruct((2, n_cl), jnp.float32),
        "attack_key": jax.ShapeDtypeStruct((2,), jnp.uint32),
        "cheby": jax.ShapeDtypeStruct((run_cfg.sub_rounds,), jnp.float32),
    }
    inflight_shardings = cstate_shardings = None
    for name in extra_names:
        donate.append(len(in_shardings))
        if name == "inflight":
            # the snapshot is donated too: the step consumes last round's
            # in-flight buffers and emits this round's
            inflight_shardings = tuple(NamedSharding(dmesh, s)
                                       for s in inflight_pspecs)
            in_shardings.append(inflight_shardings)
            out_shardings = out_shardings + (inflight_shardings,)
            input_specs["inflight"] = inflight_structs
        elif name == "codec_state":
            # per-client codec state (the EF residual): donated in, updated
            # state is the step's LAST output
            cstate_shardings = tuple(NamedSharding(dmesh, s)
                                     for s in cstate_pspecs)
            in_shardings.append(cstate_shardings)
            out_shardings = out_shardings + (cstate_shardings,)
            input_specs["codec_state"] = cstate_structs
        else:
            in_shardings.append(repl)
            input_specs[name] = extra_specs[name]
    in_shardings = tuple(in_shardings)
    step = jax.jit(train_step, in_shardings=in_shardings,
                   out_shardings=out_shardings, donate_argnums=tuple(donate))
    init_inflight = None
    if use_delay:
        init_inflight = jax.jit(snapshot_fn, in_shardings=(param_shardings,),
                                out_shardings=inflight_shardings)
    init_codec_state = None
    if use_cstate:
        init_codec_state = jax.jit(cstate_init_fn,
                                   in_shardings=(param_shardings,),
                                   out_shardings=cstate_shardings)
    return TrainSetup(
        step_fn=step, param_specs=pspecs, param_struct=struct,
        input_specs=input_specs,
        in_shardings=in_shardings, overlay=overlay, gossip_spec=gspec,
        dfl_mesh=dmesh, n_clients=n_cl, pack_spec=pack_spec,
        gossip_delay=par.gossip_delay if use_delay else 0,
        init_inflight=init_inflight, init_codec_state=init_codec_state,
        engine_config=run_cfg, wire_bytes_per_round=wire_bytes,
        cheby_coeffs=executor.cheby_coeffs() if use_cheby else None)


# ------------------------------------------------------------- serve steps
@dataclasses.dataclass(frozen=True)
class ServeSetup:
    step_fn: Any
    param_specs: PyTree
    param_struct: PyTree
    input_specs: dict
    in_shardings: Any
    mesh: Mesh


def _serve_rules(cfg: ModelConfig, baxes: tuple[str, ...]) -> dict:
    # giant checkpoints also shard the non-TP dim over the batch axes
    # (weight-gathered / ZeRO-inference); threshold: >4 GiB per model shard
    per_model_shard = cfg.param_count() * 2 / 16
    big = per_model_shard > 4 * 1024**3
    b = baxes if len(baxes) > 1 else baxes[0]
    return {
        "embed": b if big else None,
        "vocab": "model", "vocab_in": "model", "ffn": "model", "heads": "model",
        "kv_heads": "model", "experts": "model", "layers": None,
        "act_batch": b, "act_seq": "model",
    }


def _serve_act_rules(mesh: Mesh, baxes: tuple[str, ...],
                     act_batch=None) -> dict:
    b = act_batch
    return {
        "activation": NamedSharding(mesh, P(b)),
        "residual": NamedSharding(mesh, P(b)),
        "logits": NamedSharding(mesh, P(b, None, "model")),
        "attn_q": NamedSharding(mesh, P(b, None, "model", None)),
        "attn_kv": NamedSharding(mesh, P(b, None, "model", None)),
        "cache": NamedSharding(mesh, P(b, "model", None, None)),
    }


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                     ) -> ServeSetup:
    """Prefill or decode step on the production mesh (no client axis)."""
    api = ModelAPI(cfg)
    baxes = mesh_lib.batch_axes(mesh)
    struct = api.param_struct()
    rules = _serve_rules(cfg, baxes)
    # tiny batches (long_500k has global_batch=1) can't shard the batch axis;
    # the idle batch axes then join the cache's sequence sharding instead
    # (500k decode: the per-step cache read is the memory wall — spreading it
    # over data x model cuts per-device bytes by the data-axis width)
    n_batch_devices = int(np.prod([mesh.shape[a] for a in baxes]))
    if shape.global_batch % n_batch_devices != 0:
        act_batch = None
        rules = dict(rules, act_batch=None,
                     act_seq=tuple(baxes) + ("model",))
    else:
        act_batch = rules["act_batch"]
    pspecs = params_lib.partition_specs(struct, rules, mesh)
    act_rules = _serve_act_rules(mesh, baxes, act_batch)

    inputs = api.input_specs(shape)
    if shape.kind == "prefill":
        in_pspec = {"tokens": P(act_batch, None)}
        if "prefix_embeds" in inputs:
            in_pspec["prefix_embeds"] = P(act_batch, None, None)

        def step(params, **inp):
            with activation_sharding(act_rules):
                return api.prefill(params, inp["tokens"],
                                   prefix_embeds=inp.get("prefix_embeds"))
    else:  # decode
        cache_struct = api.cache_struct(shape.global_batch, shape.seq_len)
        cache_pspec = params_lib.partition_specs(cache_struct, rules, mesh)
        in_pspec = {"tokens": P(act_batch),
                    "pos": P(), "cache": cache_pspec}

        def step(params, **inp):
            with activation_sharding(act_rules):
                return api.decode_step(params, inp["cache"], inp["tokens"],
                                       inp["pos"])

    p_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    kw_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), in_pspec)

    def positional(params, inp):
        return step(params, **inp)

    jitted = jax.jit(positional, in_shardings=(p_shardings, kw_shardings))
    return ServeSetup(step_fn=jitted, param_specs=pspecs, param_struct=struct,
                      input_specs=inputs,
                      in_shardings=(p_shardings, kw_shardings), mesh=mesh)
