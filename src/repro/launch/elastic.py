"""Elastic runtime: ties health tracking, overlay repair, and checkpointing
into a resilient training loop (the fault-tolerance story, end to end).

Protocol (mirrors paper §4.1 on a cluster):
  1. every round, each client group posts a heartbeat (simulated here by a
     FailurePlan);
  2. a client missing `straggler_rounds` heartbeats is *dropped for the
     round*: gossip weights renormalize over the alive in-neighborhood
     (no re-jit needed — the adjusted GossipSpec recompiles once per
     membership change, not per round);
  3. a client missing `failure_rounds` heartbeats is declared DEAD: the
     two-hop splice repairs each virtual ring, the client-stacked state is
     remapped to the survivors, the step re-jits, and — if the process
     itself died — training resumes from the latest checkpoint.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import dfedavg, failures as failures_lib, gossip as gossip_lib
from repro.core.topology import Overlay

PyTree = Any


@dataclasses.dataclass
class ElasticTrainer:
    overlay: Overlay
    loss_fn: Callable
    dcfg: dfedavg.DFedAvgMConfig
    ckpt: CheckpointManager | None = None
    straggler_rounds: int = 1
    failure_rounds: int = 3

    def __post_init__(self):
        self.health = failures_lib.HealthTracker(
            self.overlay.n, self.straggler_rounds, self.failure_rounds)
        self.spec = gossip_lib.make_gossip_spec(self.overlay)
        self._round = self._build(self.spec)
        self.repairs: list[dict] = []

    def _build(self, spec: gossip_lib.GossipSpec):
        @jax.jit
        def round_fn(params, batches, lr):
            def client(p, b):
                v = jax.tree.map(jnp.zeros_like, p)
                p, _, loss = dfedavg.local_round(p, v, b, self.loss_fn,
                                                 self.dcfg, lr=lr)
                return p, loss
            params, losses = jax.vmap(client)(params, batches)
            return gossip_lib.mix_schedules(params, spec), losses
        return round_fn

    @property
    def n_clients(self) -> int:
        return self.overlay.n

    def observe_heartbeats(self, alive: np.ndarray, params: PyTree
                           ) -> tuple[PyTree, np.ndarray]:
        """Process one round of heartbeats; returns (params, old2new or None).

        Straggler set changes rebuild the (weight-renormalized) spec; deaths
        trigger splice repair + client-state remap.
        """
        self.health.observe(alive)
        dead = self.health.dead()
        old2new = None
        if len(dead):
            self.overlay, self.spec, params = failures_lib.repair_and_remap(
                self.overlay, list(dead), params)
            self.repairs.append({"dead": [int(d) for d in dead],
                                 "n_after": self.overlay.n})
            # survivors get a fresh tracker (indices shifted)
            self.health = failures_lib.HealthTracker(
                self.overlay.n, self.straggler_rounds, self.failure_rounds)
            self._round = self._build(self.spec)
            old2new = np.asarray([i for i in range(len(alive))])
        else:
            stragglers = self.health.stragglers()
            mask = np.ones(self.overlay.n, dtype=np.float32)
            mask[stragglers] = 0.0
            spec = (failures_lib.alive_adjusted_spec(self.spec, mask)
                    if len(stragglers) else self.spec)
            self._round = self._build(spec)
        return params, old2new

    def step(self, params: PyTree, batches: PyTree, lr: float):
        return self._round(params, batches, jnp.asarray(lr, jnp.float32))

    def checkpoint(self, rnd: int, params: PyTree) -> None:
        if self.ckpt is not None:
            self.ckpt.maybe_save(rnd, params, {"round": rnd,
                                               "n_clients": self.overlay.n})
