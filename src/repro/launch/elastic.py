"""Elastic runtime: ties health tracking, overlay repair, and checkpointing
into a resilient training loop (the fault-tolerance story, end to end).

Built on the **packed gossip engine** — the only failure-handling path:

  * every round, each client group posts a heartbeat (simulated here by a
    FailurePlan / an explicit alive mask);
  * a client missing `straggler_rounds` heartbeats is *dropped for the
    round*: its 0/1 entry in the alive vector flips, and the packed mixing
    reduction renormalizes over the alive in-neighborhood *inside the fused
    kernel pass*. The alive vector is a **traced step argument**, so
    straggler churn — any pattern of drops and recoveries — causes **zero
    recompiles** of the jitted round (`n_traces` counts them; assert on it);
  * a client missing `failure_rounds` heartbeats is declared DEAD: the
    two-hop splice (`Overlay.remove_nodes`) repairs each virtual ring, the
    GossipSpec is re-derived, the client-stacked state (params + any caller
    state such as optimizer slots) is remapped to the compacted survivor
    indices with the *real* ``old2new`` permutation, surviving clients'
    in-flight heartbeat counters are carried through the remap, and the step
    re-jits **exactly once per membership change**;
  * if the process itself died, training resumes from the latest checkpoint.

Why alive-as-argument: baking the straggler set into the GossipSpec (the
removed PR-2-era design) made liveness part of the traced graph — a
fresh `jax.jit` trace per straggler-set change, i.e. potentially per round.
Passing the mask as data moves the renormalization into the (already fused)
mixing reduction, whose cost is a handful of scalar ops per tile.

Time-varying overlays ride the identical mechanism: an optional
:class:`repro.overlay.plan.RoundPlan` supplies a per-schedule gate vector
each round (one-peer rotation, random subsets, throttling), shipped as a
second data argument next to ``alive`` and folded into the same fused
renormalization — so the *topology of the round* changes every round with
zero recompiles, and gates compose transparently with straggler masking and
splice repair (plans are stateless in the round index, so a repair that
changes the schedule count needs no plan surgery).

Pipelined gossip (``gossip_delay=1``) is the third rider on the design: the
round mixes the **previous** round's packed snapshot
(`gossip.mix_packed_stacked_delayed`, `mix_dense_delayed` semantics) and the
snapshot is carried as trainer state — primed from the initial params at the
first step, threaded through every round, and **remapped through splice
repair together with the params** (its layout depends only on the parameter
structure, so `old2new` row compaction is exact; the spec/degree change from
the repair only alters who gathers from it). Delay composes with alive masks
and round-plan gates unchanged, and keeps the same retrace accounting: churn
and plans are data, membership changes re-jit once. With
``gossip_codec="int8"``/``"int8_block"`` the round is the pipelined +
quantized engine composition: the carried snapshot IS the int8 wire buffer
(4x smaller state, same remap), and the same accounting holds.

Round-level **active-set subsampling** (``active_plan``, an
:class:`repro.overlay.plan.ActiveSetPlan`) rides the same alive-as-data
mechanism from the other side: each round the plan's 0/1 participation
vector multiplies the health mask *before* it ships, so an inactive client
is mixed exactly like a straggler (identity row, neighbors renormalize) —
but the product never feeds the :class:`HealthTracker`. Resting is not
failing: a client outside the cohort must not accumulate missed heartbeats,
start counting toward eviction, or perturb quarantine telemetry. Cohort
rotation over any number of rounds reuses the one executable (the vector is
data), and composes with straggler churn, gates, attacks, and splice repair
unchanged.

The **blocked substrate** (``gossip_block=B > 0``) decouples the simulated
client count from the device count: each of the n/B devices holds a
(B, ...) stacked slice of the client axis, intra-device overlay edges are
plain stacked gathers, and the cross-device part of each schedule ships as
whole-block ``ppermute`` collectives (see `repro.core.gossip.BlockedSpec`).
Splice repair under a blocked layout only fires when the survivor count
stays a multiple of B (the layout invariant); otherwise the dead are
**permanently masked** instead — identity rows forever, zero re-jits —
and the splice retries at the next death that restores divisibility
(``repairs`` records which path ran via its ``spliced`` flag).

The default step builder runs the stacked simulator round
(`gossip.mix_packed_stacked`: vmapped local DFedAvgM + packed gather-mix on
one device); pass ``step_builder`` to drop in the production shard_map step
(`launch.steps.build_train_step` has the same ``(params, batches, lr,
alive, gates)`` calling convention — its pipelined variant additionally
threads the in-flight snapshot, see `launch.steps.TrainSetup`).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.core import dfedavg, engine as engine_lib, failures as failures_lib, \
    gossip as gossip_lib
from repro.core.topology import Overlay
from repro.launch import mesh as mesh_lib
from repro.overlay import plan as plan_lib
from repro.overlay.plan import ActiveSetPlan, RoundPlan
from repro.telemetry import TelemetryLogger, TraceCounter
from repro.telemetry import metrics as telemetry_metrics

PyTree = Any

# (spec, trainer) -> round_fn(params, batches, lr, alive, gates)
#                       -> (params, losses)
# NOTE for production wrappers around launch.steps.build_train_step: that
# builder decides gate engagement from DFLConfig.round_plan at trace time,
# so the config's round_plan must name the same plan family as
# ``trainer.plan`` — a "static" config silently ignores the shipped gates.
StepBuilder = Callable[[gossip_lib.GossipSpec, "ElasticTrainer"], Callable]


@dataclasses.dataclass
class ElasticTrainer:
    overlay: Overlay
    loss_fn: Callable
    dcfg: dfedavg.DFedAvgMConfig
    ckpt: CheckpointManager | None = None
    straggler_rounds: int = 1
    failure_rounds: int = 3
    step_builder: StepBuilder | None = None
    # THE engine front door: pass the whole gossip cell as one
    # repro.core.engine.GossipEngineConfig (substrate "stacked" or
    # "blocked" + codec x delay x screen x telemetry). The per-knob
    # gossip_* arguments below are a deprecated shim over this — they
    # mirror into the same config (engine_lib.resolve_trainer_engine), so
    # either spelling builds the bitwise-identical round. Stateful codecs
    # (engine.CODECS entry "topk_ef": sparse top-k wire + per-client EF
    # residual) thread their codec state as trainer-carried rows, remapped
    # through splice repair like params and the in-flight snapshot.
    engine: engine_lib.GossipEngineConfig | None = None
    plan: RoundPlan | None = None  # time-varying round plan (gate source)
    # round-level client subsampling (repro.overlay.plan active-set plans):
    # the plan's 0/1 participation vector multiplies the health mask each
    # round — an inactive client is mixed like a straggler but NEVER feeds
    # the HealthTracker (resting is not failing). None/"full" = everyone.
    active_plan: ActiveSetPlan | None = None
    # B > 0 = blocked substrate: n/B devices each hold a (B, ...) stacked
    # client slice; intra-device edges are stacked gathers, cross-device
    # schedule parts ship as whole-block ppermutes (gossip.BlockedSpec).
    # 0 = single-device stacked round (unchanged path).
    gossip_block: int = 0
    # 1 = pipelined gossip: each round mixes the PREVIOUS round's packed
    # snapshot (mix_dense_delayed semantics) and the snapshot is carried as
    # trainer state — see _inflight. 0 = synchronous (unchanged path).
    gossip_delay: int = 0
    # k >= 2 = Chebyshev multi-round gossip: each round runs k gossip
    # sub-rounds with Chebyshev polynomial weights over the mixing matrix
    # (engine sub_rounds axis; coefficients from the overlay's lambda via
    # executor.cheby_coeffs(), shipped as traced data each round — zero
    # retraces, and a splice repair refreshes them with the rebuilt spec).
    # 1 = the sync engine round, bit-identical (unchanged path). Stacked
    # substrate only here; does not compose with delay / screens / stateful
    # codecs (the engine config rejects those cells).
    gossip_sub_rounds: int = 1
    # wire codec of the stacked engine round (repro.core.engine): "f32"
    # (default, the exact pre-engine numerics), "int8" / "int8_block"
    # simulate the quantized wire — with gossip_delay=1 this is the
    # pipelined+quantized composition, and the carried _inflight snapshot
    # is the int8 wire itself (remapped through splice repair like any
    # other per-client row state).
    gossip_codec: str = "f32"
    # Byzantine screen of the engine round (repro.core.engine SCREENS):
    # "none" | "norm_clip" (rescale received buffers whose norm exceeds
    # screen_tau x the receiver's own; per-sender clip telemetry feeds the
    # HealthTracker suspicion counters) | "trimmed_mean" (coordinate-wise
    # trimmed mean, screen_trim dropped per side).
    gossip_screen: str = "none"
    screen_tau: float = 3.0
    screen_trim: int = 1
    # scripted attackers (failures.AttackPlan): the round perturbs the
    # post-local-step params with the plan's (2, n) round_vector — traced
    # DATA, so attacker churn retraces nothing. Plan indices refer to the
    # INITIAL membership; splice repairs remap them with the survivors.
    attack_plan: failures_lib.AttackPlan | None = None
    attack_seed: int = 0
    # quarantine: a client clipped by >= 1 receiver on this many rounds
    # (norm_clip telemetry) is evicted through the SAME splice repair as a
    # heartbeat-dead client. 0 disables.
    quarantine_rounds: int = 0
    # opt-in in-graph round metrics (repro.telemetry.TelemetryConfig): the
    # stacked engine round additionally returns a RoundMetrics dict of
    # traced scalars (consensus residual, live in-degree, gate mass, clip
    # counts) with ZERO extra retraces — metrics of the latest round are
    # kept on ``last_metrics``. None = engine round lowers exactly as
    # before (norm_clip quarantine still works: the screen's clip counters
    # ride an internal clip-only config).
    telemetry: telemetry_metrics.TelemetryConfig | None = None
    # optional structured event stream (repro.telemetry.TelemetryLogger):
    # round records with metric summaries, compile/retrace events (via the
    # shared TraceCounter), splice/mask repair records, suspicion counts,
    # and scripted-attack activations all land in one JSONL log.
    logger: TelemetryLogger | None = None

    def __post_init__(self):
        # engine= front door first: mirrors the config onto the legacy
        # knobs (or warns on deprecated per-knob use), so every check and
        # builder below reads one source of truth
        engine_lib.resolve_trainer_engine(self)
        if self.gossip_delay not in (0, 1):
            raise ValueError(f"gossip_delay must be 0 or 1, "
                             f"got {self.gossip_delay}")
        if self.gossip_codec not in engine_lib.CODECS:
            raise ValueError(f"unknown gossip_codec {self.gossip_codec!r}; "
                             f"available: {', '.join(engine_lib.CODECS)}")
        if self.gossip_screen not in engine_lib.SCREENS:
            raise ValueError(f"unknown gossip_screen {self.gossip_screen!r}; "
                             f"available: {', '.join(engine_lib.SCREENS)}")
        if self.quarantine_rounds and self.gossip_screen != "norm_clip":
            raise ValueError("quarantine_rounds needs the norm_clip screen "
                             "(its clip telemetry is the suspicion signal)")
        if (self.attack_plan is not None
                and self.attack_plan.n_clients != self.overlay.n):
            raise ValueError(f"attack_plan is for "
                             f"{self.attack_plan.n_clients} clients, overlay "
                             f"has {self.overlay.n}")
        if (self.step_builder is not None
                and (self.gossip_screen != "none"
                     or self.attack_plan is not None)):
            raise ValueError("screens/attacks compose with the built-in "
                             "stacked round; a custom step_builder must "
                             "thread them itself (launch.steps supports "
                             "gossip_screen via ParallelConfig and attacks "
                             "via DFLConfig.byzantine)")
        if self.gossip_block:
            if self.gossip_block < 0 or self.overlay.n % self.gossip_block:
                raise ValueError(
                    f"gossip_block={self.gossip_block} must be a positive "
                    f"divisor of the client count {self.overlay.n}")
            n_dev = self.overlay.n // self.gossip_block
            if n_dev > len(jax.devices()):
                raise ValueError(
                    f"blocked layout needs {n_dev} devices (= n/block), "
                    f"only {len(jax.devices())} visible")
            if self.step_builder is not None:
                raise ValueError("gossip_block composes with the built-in "
                                 "round only; a custom step_builder owns "
                                 "its own substrate")
        if self.telemetry is not None:
            if not isinstance(self.telemetry,
                              telemetry_metrics.TelemetryConfig):
                raise TypeError("telemetry must be a telemetry.TelemetryConfig"
                                f" (got {type(self.telemetry).__name__})")
            if self.step_builder is not None:
                raise ValueError("telemetry composes with the built-in "
                                 "stacked round; a production step_builder "
                                 "carries its own metrics via "
                                 "ParallelConfig.gossip_telemetry")
        if self.gossip_delay and self.step_builder is not None:
            # the production pipelined step threads its own in-flight state
            # (mesh-leading-dims layout, primed via TrainSetup.init_inflight)
            # with a different argument order than this trainer's stacked
            # round — wrapping it here would silently mis-thread the state,
            # so the combination is rejected until a production wrapper
            # protocol exists. Use the stacked delayed round (step_builder
            # =None) or drive launch.steps.build_train_step directly.
            raise ValueError("gossip_delay=1 is not supported together with "
                             "a custom step_builder; the pipelined "
                             "production step manages its own in-flight "
                             "state (launch.steps.TrainSetup)")
        self.health = failures_lib.HealthTracker(
            self.overlay.n, self.straggler_rounds, self.failure_rounds,
            self.quarantine_rounds)
        self.spec = gossip_lib.make_gossip_spec(self.overlay)
        # jit traces of the round fn, via the shared telemetry counter: a
        # hit per trace, surviving repairs (n_traces == 1 + #splices), and
        # emitting "compile" events when a logger is attached
        self.tracer = TraceCounter("elastic_round", logger=self.logger)
        self.round_no = 0          # round index feeding the plan's gates
        self.last_metrics: dict | None = None  # latest round's RoundMetrics
        self.repairs: list[dict] = []
        # current-index -> original-attack-plan-column map, compacted on
        # every splice repair so attackers keep their script across repairs
        self._attack_cols = np.arange(self.overlay.n)
        # blocked layout: dead clients that could not be spliced out without
        # stranding a partial device block — gossip-masked forever instead
        self._masked: set[int] = set()
        # delayed mode's in-flight snapshot (pack_state_stacked of last
        # round's post-local-step params); primed lazily at the first step
        # so round 0 mixes the caller's initial params
        self._inflight = None
        # stateful codec's per-client codec state (the topk_ef EF
        # residual) — primed lazily like the snapshot, remapped through
        # splice repair by the same old2new row compaction
        self._codec_state = None
        self._round = self._build(self.spec)

    def _build(self, spec: gossip_lib.GossipSpec):
        """One jitted round: vmapped local DFedAvgM + packed masked gossip.

        Called exactly once per membership (the spec is baked in as a
        static closure); the alive mask and the round plan's gates are
        traced arguments, so every straggler pattern and every per-round
        topology (one-peer rotation, subsets, throttling) reuses the same
        executable.
        """
        if self.step_builder is not None:
            return self.step_builder(spec, self)
        # build-time decision: without an active plan (None or static) the
        # gate pathway is OFF so a plain run keeps the exact (possibly
        # negative-w0) Chow weights of the PR-1/PR-2 engine; with a real
        # plan, gates are traced data. plan_lib.is_active is the one shared
        # predicate — it matches steps.py's `round_plan != "static"` rule
        use_plan = plan_lib.is_active(self.plan)
        # attack + telemetry are build-time decisions like the plan: the
        # operands themselves (attack vector, PRNG key) are traced data.
        # norm_clip quarantine needs the per-sender clip counters, so the
        # screen forces at least a clip-only telemetry config even when the
        # caller asked for none — same lowering the old with_stats path had.
        use_attack = self.attack_plan is not None
        tel = self.telemetry
        if self.gossip_screen == "norm_clip":
            tel = (dataclasses.replace(tel, clip=True) if tel is not None
                   else telemetry_metrics.clip_only())
        use_tel = tel is not None

        def client(p, b, lr):
            v = jax.tree.map(jnp.zeros_like, p)
            p, _, loss = dfedavg.local_round(p, v, b, self.loss_fn,
                                             self.dcfg, lr=lr)
            return p, loss

        if self.gossip_block:
            # blocked substrate: the gossip island is a fully-manual
            # shard_map over a 1-D client-device mesh (n/B devices, each
            # holding a (B, ...) stacked slice). The local phase + attack
            # run on the GSPMD-sharded full stack; only the mixing round is
            # manual. delay=1 / screens on blocked are rejected by the
            # engine config itself (the satellite error messages).
            b_sz = self.gossip_block
            mesh = Mesh(np.asarray(jax.devices()[:spec.n_clients // b_sz]),
                        ("clients",))
            self._gossip_mesh = mesh  # repair re-places state onto this
            self._executor = engine_lib.build_gossip_executor(
                engine_lib.GossipEngineConfig(
                    substrate="blocked", codec=self.gossip_codec,
                    delay=self.gossip_delay,
                    sub_rounds=self.gossip_sub_rounds,
                    screen=self.gossip_screen,
                    clip_tau=self.screen_tau, trim_f=self.screen_trim,
                    block=b_sz, telemetry=tel), spec, axis_names="clients")
            executor = self._executor

            def round_fn(params, batches, lr, alive, gates, attack, akey):
                self.tracer.hit()  # python side effect: runs only on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)

                def island(p, alive_vec, gate_vec):
                    return executor(p, alive=alive_vec,
                                    gates=gate_vec if use_plan else None)

                # telemetry metrics come out of the island device-local
                # ((block,)-leading rows); the P("clients") out_spec
                # concatenates them back to the (n,)-stacked layout — no
                # collective, same permutes as the metrics-off build
                if use_tel:
                    params, metrics = mesh_lib.shard_map(
                        island, mesh, in_specs=(P("clients"), P(), P()),
                        out_specs=(P("clients"), P("clients")))(
                        params, alive, gates)
                else:
                    params = mesh_lib.shard_map(
                        island, mesh, in_specs=(P("clients"), P(), P()),
                        out_specs=P("clients"))(params, alive, gates)
                    metrics = None
                return params, losses, metrics
            return jax.jit(round_fn)

        self._executor = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="stacked",
                                          codec=self.gossip_codec,
                                          delay=self.gossip_delay,
                                          sub_rounds=self.gossip_sub_rounds,
                                          screen=self.gossip_screen,
                                          clip_tau=self.screen_tau,
                                          trim_f=self.screen_trim,
                                          telemetry=tel), spec)
        executor = self._executor

        if self.gossip_sub_rounds > 1:
            # Chebyshev multi-round round: the (k,) coefficient vector is
            # one more traced data argument next to alive/gates (the engine
            # config has already rejected delay / screens / stateful codecs
            # for this cell, so this is the only cheby-carrying round_fn)
            def round_fn(params, batches, lr, alive, gates, attack, akey,
                         cheby):
                self.tracer.hit()  # python side effect: runs only on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)
                out = executor(params, alive=alive,
                               gates=gates if use_plan else None,
                               cheby=cheby)
                if use_tel:
                    mixed, metrics = out
                else:
                    mixed, metrics = out, None
                return mixed, losses, metrics
            return jax.jit(round_fn)

        if executor.stateful:
            # stateful codec (topk_ef): the per-client codec state rides
            # as a second threaded state channel next to the optional
            # delay snapshot — returned right after it, threaded back in
            # by step(). inflight stays None (an empty pytree) at delay=0.
            def round_fn(params, inflight, cstate, batches, lr, alive,
                         gates, attack, akey):
                self.tracer.hit()  # python side effect: only runs on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)
                kw = dict(codec_state=cstate, alive=alive,
                          gates=gates if use_plan else None)
                if self.gossip_delay:
                    kw["state"] = inflight
                out = list(executor(params, **kw))
                mixed = out.pop(0)
                inflight = out.pop(0) if self.gossip_delay else None
                cstate = out.pop(0)
                metrics = out.pop(0) if use_tel else None
                return mixed, losses, inflight, cstate, metrics
            return jax.jit(round_fn)

        if self.gossip_delay:
            def round_fn(params, inflight, batches, lr, alive, gates,
                         attack, akey):
                self.tracer.hit()  # python side effect: only runs on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)
                out = executor(params, state=inflight, alive=alive,
                               gates=gates if use_plan else None)
                if use_tel:
                    mixed, inflight, metrics = out
                else:
                    mixed, inflight = out
                    metrics = None
                return mixed, losses, inflight, metrics
            return jax.jit(round_fn)

        def round_fn(params, batches, lr, alive, gates, attack, akey):
            self.tracer.hit()  # python side effect: runs only when tracing
            params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                params, batches, lr)
            if use_attack:
                params = failures_lib.apply_attack(params, attack, akey)
            out = executor(params, alive=alive,
                           gates=gates if use_plan else None)
            if use_tel:
                mixed, metrics = out
            else:
                mixed, metrics = out, None
            return mixed, losses, metrics
        return jax.jit(round_fn)

    def gates_for_round(self, rnd: int | None = None) -> jax.Array:
        """This round's per-schedule gate vector (all-ones without a plan)."""
        rnd = self.round_no if rnd is None else rnd
        return jnp.asarray(plan_lib.gates_for(self.plan, rnd,
                                              self.spec.degree))

    def active_for_round(self, rnd: int | None = None) -> np.ndarray:
        """This round's 0/1 participation vector (all-ones without a plan)."""
        rnd = self.round_no if rnd is None else rnd
        return plan_lib.active_for(self.active_plan, rnd, self.overlay.n)

    @property
    def n_clients(self) -> int:
        return self.overlay.n

    @property
    def n_traces(self) -> int:
        """Jit traces of the round fn so far (TraceCounter-backed)."""
        return self.tracer.count

    def observe_heartbeats(self, alive: np.ndarray, params: PyTree,
                           client_state: PyTree | None = None
                           ) -> tuple[PyTree, PyTree | None, np.ndarray | None]:
        """Process one round of heartbeats.

        Args:
          alive: this round's 0/1 heartbeat vector (length n_clients).
          params: client-stacked model state.
          client_state: optional extra per-client pytree (optimizer slots,
            shard assignments, ...) remapped together with ``params`` on
            permanent failures.

        Returns ``(params, client_state, old2new)``. ``old2new`` is ``None``
        for rounds without a membership change; after a splice repair it is
        the real survivor permutation from :func:`Overlay.remove_nodes`
        (``old2new[old] = new`` or ``-1`` for the dead) — apply it to any
        per-client state you keep outside ``client_state``.

        Straggler-only changes touch *no* compiled state: the next
        :meth:`step` simply ships a different alive vector.
        """
        self.health.observe(alive)
        dead = [int(d) for d in self.health.dead()
                if int(d) not in self._masked]
        if not dead:
            return params, client_state, None

        evict = sorted(self._masked | set(dead))
        if self.gossip_block and (self.overlay.n - len(evict)) \
                % self.gossip_block:
            # blocked layout invariant: the survivor count must stay a
            # multiple of block, or the splice would strand a partial
            # device slice. Mask the dead permanently instead (identity
            # rows forever, no re-jit) and retry the splice at the next
            # death that restores divisibility.
            self._masked.update(dead)
            self.repairs.append({"dead": dead, "spliced": False,
                                 "masked": sorted(self._masked),
                                 "n_after": self.overlay.n})
            if self.logger is not None:
                self.logger.repair(self.repairs[-1])
            return params, client_state, None

        # the in-flight snapshot and the codec state ride the same remap as
        # params: their layouts depend only on the parameter structure
        # (never on the topology), so dropping the dead rows keeps the
        # delayed semantics — and the survivors' EF residuals — exact
        bundle = (params, client_state, self._inflight, self._codec_state)
        self.overlay, self.spec, bundle, old2new = failures_lib.repair_and_remap(
            self.overlay, evict, bundle)
        params, client_state, self._inflight, self._codec_state = bundle
        suspects = set(int(s) for s in self.health.suspects())
        self.repairs.append({"dead": evict, "spliced": True,
                             "quarantined": sorted(suspects & set(evict)),
                             "n_after": self.overlay.n})
        if self.logger is not None:
            self.logger.repair(self.repairs[-1])
        self._masked.clear()
        # attackers keep their plan column across compaction: survivors'
        # current indices shift, their original-plan identity must not
        self._attack_cols = self._attack_cols[np.asarray(old2new) >= 0]
        # survivors carry their in-flight heartbeat counters to the
        # compacted indices (a straggling survivor stays a straggler)
        self.health = self.health.remap(old2new)
        self._round = self._build(self.spec)  # the one re-jit per repair
        if self.gossip_block:
            # a splice can shrink the blocked mesh (fewer client-devices);
            # the remapped rows are still committed to the OLD device set,
            # so re-place them onto the new mesh before the next round
            sh = NamedSharding(self._gossip_mesh, P("clients"))
            params = jax.device_put(params, sh)
            if client_state is not None:
                client_state = jax.device_put(client_state, sh)
        return params, client_state, old2new

    def step(self, params: PyTree, batches: PyTree, lr: float):
        """Run one round under the current health mask, the active-set
        plan's participation vector, and the round plan's gates (no rebuilds
        here — all three are data arguments). In delayed mode the in-flight
        snapshot is threaded through as trainer state."""
        alive = self.health.alive_mask()
        if self._masked:
            # blocked-layout permanent masking: dead-but-unspliceable
            # clients stay gossip-masked (identity rows) forever
            alive = alive.copy()
            alive[sorted(self._masked)] = 0.0
        if plan_lib.is_subsampling(self.active_plan):
            # the active set multiplies the GOSSIP mask only — it is
            # computed here, after the heartbeats were observed, precisely
            # so it can never feed the HealthTracker (resting != failing)
            alive = alive * plan_lib.active_for(self.active_plan,
                                                self.round_no,
                                                self.overlay.n)
        alive = jnp.asarray(alive)
        gates = self.gates_for_round()
        attack = akey = None
        if self.attack_plan is not None:
            # plan columns are in ORIGINAL indices; gather the survivors'
            # rows so a repaired run keeps each attacker's script
            vec = self.attack_plan.round_vector(self.round_no)
            attack = jnp.asarray(vec[:, self._attack_cols])
            akey = jnp.asarray(
                np.array([self.attack_seed, self.round_no], np.uint32))
            if self.logger is not None:
                for r, ids, mode, mag in self.attack_plan.events:
                    if r == self.round_no:  # script activates this round
                        self.logger.event(
                            "attack", round=self.round_no, mode=mode,
                            clients=[int(c) for c in ids],
                            magnitude=float(mag))
        rnd = self.round_no
        self.round_no += 1
        lr = jnp.asarray(lr, jnp.float32)
        if self.step_builder is not None:
            # custom builders keep the documented 5-arg StepBuilder contract
            # (screens/attacks with a builder are rejected in __post_init__)
            return self._round(params, batches, lr, alive, gates)
        phase = (self.logger.phase("round") if self.logger is not None
                 else contextlib.nullcontext())
        with phase:
            if self._executor.stateful:
                if self._codec_state is None:  # prime: EF residual zeros
                    self._codec_state = self._executor.init_codec_state(
                        params)
                if self.gossip_delay and self._inflight is None:
                    self._inflight = self._executor.init_state(params)
                (params, losses, self._inflight, self._codec_state,
                 metrics) = self._round(
                    params, self._inflight, self._codec_state, batches, lr,
                    alive, gates, attack, akey)
            elif self.gossip_delay:
                if self._inflight is None:  # prime: round 0 mixes the
                    # initial snapshot in the codec's wire format (packed
                    # f32 buffers, or the folded int8 wire when quantized)
                    self._inflight = self._executor.init_state(params)
                params, losses, self._inflight, metrics = self._round(
                    params, self._inflight, batches, lr, alive, gates,
                    attack, akey)
            elif not self.gossip_block and self.gossip_sub_rounds > 1:
                # coefficients recomputed from the live executor each round:
                # a splice repair rebuilt it with the new spec's lambda, and
                # the (k,) shape is fixed so the refresh never retraces
                cheby = jnp.asarray(self._executor.cheby_coeffs())
                params, losses, metrics = self._round(params, batches, lr,
                                                      alive, gates, attack,
                                                      akey, cheby)
            else:
                params, losses, metrics = self._round(params, batches, lr,
                                                      alive, gates, attack,
                                                      akey)
        self.last_metrics = metrics
        if metrics is not None and "clipped" in metrics:
            # per-sender count of receivers that clipped them this round
            counts = np.asarray(metrics["clipped"])
            self.health.observe_suspicion(counts)
            if self.logger is not None and counts.sum() > 0:
                self.logger.event("suspicion", round=rnd,
                                  clipped=[int(c) for c in counts])
        if self.logger is not None and self.logger.wants_round(rnd):
            # peeked BEFORE building the record: the loss/metrics floats
            # are the round's only deliberate device->host sync, and the
            # sampled logger (round_every > 1) skips it on off-rounds
            self.logger.round(
                rnd, loss=float(jnp.mean(losses)),
                alive=int(np.asarray(alive).sum()),
                **telemetry_metrics.summarize_metrics(
                    metrics, n_clients=self.overlay.n))
        return params, losses

    def checkpoint(self, rnd: int, params: PyTree) -> None:
        if self.ckpt is not None:
            self.ckpt.maybe_save(rnd, params, {"round": rnd,
                                               "n_clients": self.overlay.n})
