import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# (Tests may shrink the placeholder world via REPRO_DRYRUN_DEVICES.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (no mismatched specs, no unsupported
    collectives) on the 16x16 single-pod AND 2x16x16 multi-pod meshes;
  * the per-device memory footprint (memory_analysis);
  * the roofline inputs (cost_analysis FLOPs/bytes + parsed collective bytes).

Results are cached as JSON per cell under --out (default
experiments/dryrun/), so re-runs after a perf change only recompile the
affected cells (--force to override).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import registry
from repro.configs.base import DFLConfig
from repro.launch import mesh as mesh_lib
from repro.launch import steps
from repro.models import params as params_lib
from repro.roofline import analysis, hw


def _mesh(kind: str):
    return mesh_lib.make_production_mesh(multi_pod=(kind == "multi"))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             par=None, dfl=None, label: str = "") -> dict:
    """Lower+compile one cell; returns the record (raises on failure)."""
    cfg = registry.get(arch)
    shape = next(s for s in registry.shapes_for(arch) if s.name == shape_name)
    par = par or registry.parallel_for(arch)
    dfl = dfl or DFLConfig()
    mesh = _mesh(mesh_kind)
    world = int(len(jax.devices()))

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            setup = steps.build_train_step(cfg, shape, mesh, par, dfl)
            step_args = [
                params_lib.shape_structs(setup.param_struct),
                setup.input_specs["batch"], setup.input_specs["lr"],
                setup.input_specs["alive"], setup.input_specs["gates"]]
            # optional operands, in the step's fixed extra order
            for name in ("active", "attack", "attack_key", "inflight"):
                if name in setup.input_specs:
                    step_args.append(setup.input_specs[name])
            lowered = setup.step_fn.lower(*step_args)
            extra = {
                "n_clients": setup.n_clients,
                "overlay": setup.overlay.name if setup.overlay else None,
                "gossip_degree": (setup.gossip_spec.degree
                                  if setup.gossip_spec else 0),
                "gossip_lambda": (setup.gossip_spec.lam
                                  if setup.gossip_spec else None),
                "gossip_impl": par.gossip_impl,
                "gossip_delay": setup.gossip_delay,
                # the parsed engine cell (repro.core.engine) the step
                # actually lowered with — substrate x codec x timing
                "gossip_engine": (dataclasses.asdict(setup.engine_config)
                                  if setup.engine_config else None),
            }
            if setup.pack_spec is not None:
                # per-device gossip-buffer padding, measured per cell via
                # roofline/analysis.packing_report (and across every arch by
                # bench_comm.padding_by_arch: full-size trees pad <= 0.003%,
                # smoke 17-38% — a smoke-model artifact, not a wire cost)
                extra["packing"] = analysis.packing_report(setup.pack_spec)
        else:
            setup = steps.build_serve_step(cfg, shape, mesh)
            lowered = setup.step_fn.lower(
                params_lib.shape_structs(setup.param_struct),
                setup.input_specs)
            extra = {"gossip_impl": None}
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    roof = analysis.roofline(cost, hlo, world)

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        # tokens processed per lowered round = K local steps x global batch x seq
        tokens = par.local_steps * shape.global_batch * shape.seq_len
        model_flops = analysis.model_flops_train(n_active, tokens)
    elif shape.kind == "prefill":
        model_flops = analysis.model_flops_prefill(
            n_active, shape.global_batch * shape.seq_len)
    else:
        model_flops = analysis.model_flops_decode(n_active, shape.global_batch)
    model_flops_per_chip = model_flops / world

    args_b = int(mem.argument_size_in_bytes)
    temp_b = int(mem.temp_size_in_bytes)
    out_b = int(mem.output_size_in_bytes)
    alias_b = int(mem.alias_size_in_bytes)
    peak = args_b + temp_b + out_b - alias_b

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "label": label,
        "world": world,
        "clients_per_pod": par.clients_per_pod,
        "grad_accum": par.grad_accum,
        "remat": par.remat,
        "seconds_lower": round(t_lower, 2),
        "seconds_compile": round(t_compile, 2),
        "memory": {
            "argument_bytes": args_b, "output_bytes": out_b,
            "temp_bytes": temp_b, "alias_bytes": alias_b,
            "peak_bytes": peak,
            "fits_16g": bool(peak <= hw.HBM_BYTES),
        },
        "roofline": roof.as_dict(),
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flop_ratio": (model_flops_per_chip / roof.flops
                              if roof.flops else None),
        **extra,
    }
    return record


def cell_path(out_dir: str, arch: str, shape: str, mesh_kind: str,
              label: str = "") -> str:
    suffix = f"_{label}" if label else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--label", default="", help="config-variant tag (perf runs)")
    ap.add_argument("--gossip", default=None,
                    choices=["dense", "ppermute", "ppermute_quant",
                             "ppermute_packed", "ppermute_packed_quant",
                             "ppermute_packed_async"])
    ap.add_argument("--codec", default=None,
                    choices=["auto", "f32", "int8", "int8_block"],
                    help="wire-codec override (repro.core.engine); "
                         "--gossip ppermute_packed_async --codec int8_block "
                         "lowers the pipelined+quantized composition")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = registry.ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    failures = []
    for arch in archs:
        shapes = ([s for s in registry.shapes_for(arch) if s.name == args.shape]
                  if args.shape else registry.shapes_for(arch))
        for shape in shapes:
            for mk in meshes:
                path = cell_path(args.out, arch, shape.name, mk, args.label)
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {arch} {shape.name} {mk}")
                    continue
                par = registry.parallel_for(arch)
                if args.gossip:
                    # the async impl is only interesting pipelined; delay=0
                    # would lower to HLO identical to ppermute_packed
                    delay = 1 if args.gossip == "ppermute_packed_async" else 0
                    par = dataclasses.replace(par, gossip_impl=args.gossip,
                                              gossip_delay=delay)
                if args.codec:
                    par = dataclasses.replace(par, gossip_codec=args.codec)
                try:
                    rec = run_cell(arch, shape.name, mk, par=par,
                                   label=args.label)
                except Exception as e:  # record failures; dry-run must be green
                    failures.append((arch, shape.name, mk, repr(e)))
                    print(f"[FAIL] {arch} {shape.name} {mk}: {e}")
                    traceback.print_exc()
                    continue
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"[ok] {arch:18s} {shape.name:12s} {mk:6s} "
                      f"compile={rec['seconds_compile']:6.1f}s "
                      f"peak={rec['memory']['peak_bytes']/2**30:7.2f}GiB "
                      f"comp={r['compute_s']*1e3:9.3f}ms "
                      f"mem={r['memory_s']*1e3:9.3f}ms "
                      f"coll={r['collective_s']*1e3:9.3f}ms "
                      f"dom={r['dominant']}", flush=True)

    # skipped long_500k rows (full-attention archs) recorded for the table
    for arch in archs:
        for sname in registry.skipped_shapes(arch):
            for mk in meshes:
                path = cell_path(args.out, arch, sname, mk, args.label)
                if not os.path.exists(path):
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": sname, "mesh": mk,
                                   "skipped": "full-attention arch: 500k decode "
                                              "needs sub-quadratic attention"},
                                  f, indent=1)

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f4 in failures:
            print("  ", f4)
        raise SystemExit(1)
    print("\nDRY-RUN GREEN")


if __name__ == "__main__":
    main()
