"""Batched serving driver (CPU-runnable with reduced configs).

Implements the standard two-phase serving loop on top of the model API:
prefill a batch of prompts, then step the decoder with a shared KV cache,
greedy or temperature sampling. On the production mesh the same functions
lower with TP x batch-DP shardings (see `steps.build_serve_step`); here they
run on local devices for the end-to-end example.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models.api import ModelAPI
from repro.models import params as params_lib


def generate(api: ModelAPI, params, prompts: jax.Array, gen_tokens: int,
             temperature: float = 0.0, seed: int = 0
             ) -> tuple[np.ndarray, dict]:
    """prompts: (B, S) int32. Returns (B, gen_tokens) int32 + timing stats."""
    b, s = prompts.shape
    max_seq = s + gen_tokens

    t0 = time.time()
    logits, cache = jax.jit(api.prefill)(params, prompts)
    # grow caches to max_seq (kv caches have the seq axis at dim 2)
    def grow(path_leaf):
        k, x = path_leaf
        if k in ("k", "v") and x.ndim >= 3 and x.shape[2] == s:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, gen_tokens)
            return jnp.pad(x, pad)
        return x
    cache = {k: grow((k, v)) for k, v in cache.items()}
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    decode = jax.jit(api.decode_step)
    rng = jax.random.key(seed)
    out = []
    tok = (jnp.argmax(logits, -1) if temperature == 0.0 else
           jax.random.categorical(rng, logits / temperature)).astype(jnp.int32)
    t0 = time.time()
    for i in range(gen_tokens):
        out.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok, jnp.asarray(s + i, jnp.int32))
        if temperature == 0.0:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits / temperature).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    stats = {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": b * gen_tokens / max(t_decode, 1e-9),
    }
    return np.stack(out, axis=1), stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = registry.reduced(args.arch) if args.reduced else registry.get(args.arch)
    api = ModelAPI(cfg)
    params = api.init_params(jax.random.key(0))
    n = params_lib.count_params(api.param_struct())
    print(f"serving {cfg.name}: {n/1e6:.1f}M params")

    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    toks, stats = generate(api, params, prompts, args.gen,
                           temperature=args.temperature)
    print("generated shape:", toks.shape)
    print({k: round(v, 4) for k, v in stats.items()})


if __name__ == "__main__":
    main()
