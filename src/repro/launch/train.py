"""End-to-end DFL training driver (CPU-runnable simulator path).

Runs the *same algorithm* as the production multi-pod step (DFedAvgM local
rounds + overlay gossip), with the client axis realized as a stacked/vmapped
array on the local device(s) instead of a 512-chip mesh. Includes the full
fault-tolerance loop: checkpoint/rotate/resume, straggler weight
renormalization, permanent-failure splice repair + re-jit.

Usage (example: char-LM over the bundled Shakespeare, 16 clients, d=4):
    PYTHONPATH=src python -m repro.launch.train --clients 16 --rounds 40 \
        --topology expander --degree 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import DFLConfig
from repro.core import dfedavg, engine as engine_lib, failures as failures_lib, \
    gossip as gossip_lib
from repro.core.topology import Overlay
from repro.launch.steps import build_overlay
from repro.models import lstm as lstm_model
from repro.models import params as params_lib
from repro.overlay import plan as overlay_plan
from repro.telemetry import TelemetryLogger, TraceCounter
from repro.telemetry import metrics as telemetry_metrics

PyTree = Any


@dataclasses.dataclass
class SimTrainer:
    """DFL simulator: stacked clients + schedule gossip (vmap path)."""

    overlay: Overlay
    loss_fn: Callable
    dcfg: dfedavg.DFedAvgMConfig
    ckpt: CheckpointManager | None = None
    # THE engine front door: the whole gossip cell as one
    # repro.core.engine.GossipEngineConfig (substrate "stacked" or
    # "blocked" + codec x delay x screen x telemetry). The per-knob
    # gossip_* arguments below are a deprecated shim that mirrors into the
    # same config (engine_lib.resolve_trainer_engine) — either spelling
    # builds the bitwise-identical round.
    engine: engine_lib.GossipEngineConfig | None = None
    plan: overlay_plan.RoundPlan | None = None  # time-varying gates source
    # round-level client subsampling (active-set plans): the 0/1
    # participation vector multiplies the alive mask each round — inactive
    # clients keep their params (identity rows); cohort rotation is data,
    # never a retrace. None (or the "full" plan) = everyone participates.
    active_plan: overlay_plan.ActiveSetPlan | None = None
    # B > 0 = blocked substrate (massive-client simulation): n/B devices
    # each hold a (B, ...) stacked client slice; cross-device schedule
    # parts ship as whole-block ppermutes (repro.core.gossip.BlockedSpec).
    # 0 = single-device stacked round (unchanged path).
    gossip_block: int = 0
    # 1 = pipelined gossip (mix the previous round's packed snapshot,
    # mix_dense_delayed semantics); 0 = synchronous (unchanged)
    gossip_delay: int = 0
    # k >= 2 = Chebyshev multi-round gossip (engine sub_rounds axis): k
    # gossip sub-rounds per round with Chebyshev polynomial weights over
    # the mixing matrix, coefficients shipped as traced data from
    # executor.cheby_coeffs() — zero retraces, refreshed after repairs.
    # 1 = the sync engine round, bit-identical (unchanged path).
    gossip_sub_rounds: int = 1
    # wire codec of the stacked engine round ("f32" | "int8" | "int8_block")
    gossip_codec: str = "f32"
    # Byzantine screen ("none" | "norm_clip" | "trimmed_mean") + its knobs;
    # composes with every codec x delay cell through the engine config alone
    gossip_screen: str = "none"
    screen_tau: float = 3.0
    screen_trim: int = 1
    # scripted attackers: the (2, n) round_vector + PRNG key are traced
    # data, so attacker churn never retraces the round
    attack_plan: failures_lib.AttackPlan | None = None
    attack_seed: int = 0
    # opt-in in-graph round metrics (repro.telemetry.TelemetryConfig):
    # when set, the stacked engine round additionally returns a traced
    # RoundMetrics dict and run()'s history records carry its host summary
    # (consensus residual, in-degree, gate mass, clip counts). None (the
    # default) lowers the round exactly as before.
    telemetry: telemetry_metrics.TelemetryConfig | None = None
    # optional structured JSONL event stream (round records, compiles,
    # repairs) — see repro.telemetry.TelemetryLogger
    logger: TelemetryLogger | None = None

    def __post_init__(self):
        # engine= front door first: mirrors the config onto the legacy
        # knobs (or warns on deprecated per-knob use), so every check and
        # builder below reads one source of truth
        engine_lib.resolve_trainer_engine(self)
        if self.gossip_delay not in (0, 1):
            raise ValueError(f"gossip_delay must be 0 or 1, "
                             f"got {self.gossip_delay}")
        if self.gossip_screen not in engine_lib.SCREENS:
            raise ValueError(f"unknown gossip_screen {self.gossip_screen!r}; "
                             f"available: {', '.join(engine_lib.SCREENS)}")
        if (self.attack_plan is not None
                and self.attack_plan.n_clients != self.overlay.n):
            raise ValueError(f"attack_plan is for "
                             f"{self.attack_plan.n_clients} clients, overlay "
                             f"has {self.overlay.n}")
        if self.gossip_block:
            if self.gossip_block < 0 or self.overlay.n % self.gossip_block:
                raise ValueError(
                    f"gossip_block={self.gossip_block} must be a positive "
                    f"divisor of the client count {self.overlay.n}")
            if self.overlay.n // self.gossip_block > len(jax.devices()):
                raise ValueError(
                    f"blocked layout needs "
                    f"{self.overlay.n // self.gossip_block} devices "
                    f"(= n/block), only {len(jax.devices())} visible")
        self.spec = gossip_lib.make_gossip_spec(self.overlay)
        # shared retrace accounting (emits "compile" events when logging)
        self.tracer = TraceCounter("sim_round", logger=self.logger)
        self.last_metrics: dict | None = None
        self._alive = np.ones(self.overlay.n, dtype=np.float32)
        self._inflight = None  # delayed mode's carried snapshot
        # stateful codec's per-client codec state (topk_ef EF residual);
        # primed lazily, remapped through repair like the snapshot
        self._codec_state = None
        # current-index -> original-plan-column map (compacted on repair)
        self._attack_cols = np.arange(self.overlay.n)
        self._round_fn = self._build(self.spec)

    def _build(self, spec):
        # no active plan (None or static) => gate pathway off at build time
        # (exact Chow weights; shared predicate with ElasticTrainer/steps.py)
        use_plan = overlay_plan.is_active(self.plan)
        use_attack = self.attack_plan is not None
        use_tel = self.telemetry is not None

        def client(p, b, lr):
            v = jax.tree.map(jnp.zeros_like, p)
            p, _, loss = dfedavg.local_round(p, v, b, self.loss_fn,
                                             self.dcfg, lr=lr)
            return p, loss

        if self.gossip_block:
            # blocked substrate: shard_map gossip island over a 1-D
            # client-device mesh; the local phase runs on the GSPMD-sharded
            # full stack (see launch/elastic.py for the full design note)
            from jax.sharding import Mesh, PartitionSpec as P
            from repro.launch import mesh as mesh_lib
            b_sz = self.gossip_block
            mesh = Mesh(np.asarray(jax.devices()[:spec.n_clients // b_sz]),
                        ("clients",))
            self._gossip_mesh = mesh  # repair re-places state onto this
            self._executor = engine_lib.build_gossip_executor(
                engine_lib.GossipEngineConfig(
                    substrate="blocked", codec=self.gossip_codec,
                    delay=self.gossip_delay,
                    sub_rounds=self.gossip_sub_rounds,
                    screen=self.gossip_screen,
                    clip_tau=self.screen_tau, trim_f=self.screen_trim,
                    block=b_sz, telemetry=self.telemetry),
                spec, axis_names="clients")
            executor = self._executor

            @partial(jax.jit, static_argnames=())
            def round_fn(params, batches, lr, alive, gates, attack, akey):
                self.tracer.hit()  # python side effect: runs only on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)

                def island(p, alive_vec, gate_vec):
                    return executor(p, alive=alive_vec,
                                    gates=gate_vec if use_plan else None)

                # blocked telemetry: the island returns device-local
                # (block,)-leading metric rows; the P("clients") out_spec
                # concatenates them back to the (n,)-stacked layout with
                # zero extra collectives
                if use_tel:
                    params, metrics = mesh_lib.shard_map(
                        island, mesh, in_specs=(P("clients"), P(), P()),
                        out_specs=(P("clients"), P("clients")))(
                        params, alive, gates)
                else:
                    params = mesh_lib.shard_map(
                        island, mesh, in_specs=(P("clients"), P(), P()),
                        out_specs=P("clients"))(params, alive, gates)
                    metrics = None
                return params, losses, metrics
            return round_fn

        self._executor = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="stacked",
                                          codec=self.gossip_codec,
                                          delay=self.gossip_delay,
                                          sub_rounds=self.gossip_sub_rounds,
                                          screen=self.gossip_screen,
                                          clip_tau=self.screen_tau,
                                          trim_f=self.screen_trim,
                                          telemetry=self.telemetry), spec)
        executor = self._executor

        if self.gossip_sub_rounds > 1:
            # Chebyshev multi-round round: the (k,) coefficient vector is
            # one more traced data argument (the engine config has already
            # rejected delay / screens / stateful codecs for this cell)
            @partial(jax.jit, static_argnames=())
            def round_fn(params, batches, lr, alive, gates, attack, akey,
                         cheby):
                self.tracer.hit()  # python side effect: runs only on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)
                out = executor(params, alive=alive,
                               gates=gates if use_plan else None,
                               cheby=cheby)
                if use_tel:
                    params, metrics = out
                else:
                    params, metrics = out, None
                return params, losses, metrics
            return round_fn

        if executor.stateful:
            # stateful codec (topk_ef): the per-client codec state rides as
            # a second threaded state channel next to the optional delay
            # snapshot (inflight stays None — an empty pytree — at delay=0)
            @partial(jax.jit, static_argnames=())
            def round_fn(params, inflight, cstate, batches, lr, alive,
                         gates, attack, akey):
                self.tracer.hit()  # python side effect: only runs on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)
                kw = dict(codec_state=cstate, alive=alive,
                          gates=gates if use_plan else None)
                if self.gossip_delay:
                    kw["state"] = inflight
                out = list(executor(params, **kw))
                mixed = out.pop(0)
                inflight = out.pop(0) if self.gossip_delay else None
                cstate = out.pop(0)
                metrics = out.pop(0) if use_tel else None
                return mixed, losses, inflight, cstate, metrics
            return round_fn

        if self.gossip_delay:
            @partial(jax.jit, static_argnames=())
            def round_fn(params, inflight, batches, lr, alive, gates,
                         attack, akey):
                self.tracer.hit()  # python side effect: only runs on trace
                params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                    params, batches, lr)
                if use_attack:
                    params = failures_lib.apply_attack(params, attack, akey)
                out = executor(params, state=inflight, alive=alive,
                               gates=gates if use_plan else None)
                if use_tel:
                    params, inflight, metrics = out
                else:
                    (params, inflight), metrics = out, None
                return params, losses, inflight, metrics
            return round_fn

        @partial(jax.jit, static_argnames=())
        def round_fn(params, batches, lr, alive, gates, attack, akey):
            self.tracer.hit()  # python side effect: runs only when tracing
            params, losses = jax.vmap(client, in_axes=(0, 0, None))(
                params, batches, lr)
            if use_attack:
                params = failures_lib.apply_attack(params, attack, akey)
            out = executor(params, alive=alive,
                           gates=gates if use_plan else None)
            if use_tel:
                params, metrics = out
            else:
                params, metrics = out, None
            return params, losses, metrics
        return round_fn

    def _attack_operands(self, rnd: int):
        if self.attack_plan is None:
            return None, None
        vec = self.attack_plan.round_vector(rnd)
        return (jnp.asarray(vec[:, self._attack_cols]),
                jnp.asarray(np.array([self.attack_seed, rnd], np.uint32)))

    def _gates(self, rnd: int) -> jnp.ndarray:
        return jnp.asarray(overlay_plan.gates_for(self.plan, rnd,
                                                  self.spec.degree))

    # ---------------------------------------------------------- failures
    def set_stragglers(self, alive_mask: np.ndarray) -> None:
        """Transient failures: renormalized gossip for the coming rounds.

        The mask is a traced argument of the packed round (no rebuild here,
        zero recompiles — see launch/elastic.py for the full design note).
        """
        self._alive = np.asarray(alive_mask, dtype=np.float32)

    def repair(self, dead: list[int], params: PyTree) -> PyTree:
        """Permanent failures: splice repair, state remap, re-jit. The
        delayed-mode in-flight snapshot rides the same row compaction."""
        if self.gossip_block and \
                (self.overlay.n - len(dead)) % self.gossip_block:
            # the blocked layout needs the survivor count to stay a
            # multiple of block; mask the dead via set_stragglers instead
            # (ElasticTrainer automates this masking-vs-splice decision)
            raise ValueError(
                f"splicing {len(dead)} of {self.overlay.n} clients leaves a "
                f"partial device block (block={self.gossip_block}); keep the "
                "dead masked or evict a block-multiple")
        bundle = (params, self._inflight, self._codec_state)
        self.overlay, self.spec, bundle, old2new = failures_lib.repair_and_remap(
            self.overlay, dead, bundle)
        params, self._inflight, self._codec_state = bundle
        # surviving stragglers keep their mask through the index compaction
        survivors = old2new >= 0
        new_alive = np.ones(self.overlay.n, dtype=np.float32)
        new_alive[old2new[survivors]] = self._alive[survivors]
        self._alive = new_alive
        # attackers keep their original plan column across compaction
        self._attack_cols = self._attack_cols[survivors]
        if self.logger is not None:
            self.logger.repair({"dead": [int(d) for d in dead],
                                "spliced": True,
                                "n_after": self.overlay.n})
        self._round_fn = self._build(self.spec)
        if self.gossip_block:
            # a splice can shrink the blocked mesh; the remapped rows are
            # still committed to the old device set — re-place them
            from jax.sharding import NamedSharding, PartitionSpec as P
            params = jax.device_put(
                params, NamedSharding(self._gossip_mesh, P("clients")))
        return params

    # ------------------------------------------------------------- train
    def run(self, params: PyTree, batch_fn: Callable[[int], PyTree],
            rounds: int, lr_fn: Callable[[int], float],
            start_round: int = 0, log_every: int = 1,
            eval_fn: Callable[[PyTree], dict] | None = None,
            failure_plan: failures_lib.FailurePlan | None = None
            ) -> tuple[PyTree, list[dict]]:
        history: list[dict] = []
        for rnd in range(start_round, rounds):
            if failure_plan is not None:
                mask = failure_plan.alive_mask(rnd)
                if not np.array_equal(mask, self._alive):
                    self.set_stragglers(mask)
            t0 = time.time()
            batches = batch_fn(rnd)
            lr_t = jnp.asarray(lr_fn(rnd), jnp.float32)
            attack, akey = self._attack_operands(rnd)
            alive_t = self._alive
            if overlay_plan.is_subsampling(self.active_plan):
                # inactive clients are mixed like stragglers (identity
                # rows) but are only resting — the plan never touches the
                # persistent straggler mask itself
                alive_t = alive_t * overlay_plan.active_for(
                    self.active_plan, rnd, self.overlay.n)
            if self._executor.stateful:
                if self._codec_state is None:  # prime: EF residual zeros
                    self._codec_state = self._executor.init_codec_state(
                        params)
                if self.gossip_delay and self._inflight is None:
                    self._inflight = self._executor.init_state(params)
                (params, losses, self._inflight, self._codec_state,
                 metrics) = self._round_fn(
                    params, self._inflight, self._codec_state, batches,
                    lr_t, jnp.asarray(alive_t), self._gates(rnd),
                    attack, akey)
            elif self.gossip_delay:
                if self._inflight is None:  # prime with the initial params
                    self._inflight = self._executor.init_state(params)
                params, losses, self._inflight, metrics = self._round_fn(
                    params, self._inflight, batches, lr_t,
                    jnp.asarray(alive_t), self._gates(rnd),
                    attack, akey)
            elif not self.gossip_block and self.gossip_sub_rounds > 1:
                # coefficients recomputed from the live executor: a repair
                # rebuilt it with the new spec's lambda, and the fixed (k,)
                # shape means the refresh never retraces
                params, losses, metrics = self._round_fn(
                    params, batches, lr_t, jnp.asarray(alive_t),
                    self._gates(rnd), attack, akey,
                    jnp.asarray(self._executor.cheby_coeffs()))
            else:
                params, losses, metrics = self._round_fn(
                    params, batches, lr_t, jnp.asarray(alive_t),
                    self._gates(rnd), attack, akey)
            self.last_metrics = metrics
            rec = {"round": rnd,
                   "train_loss": float(jnp.mean(losses)),
                   "seconds": round(time.time() - t0, 3)}
            rec.update(telemetry_metrics.summarize_metrics(
                metrics, n_clients=self.overlay.n))
            if eval_fn is not None and rnd % log_every == 0:
                rec.update(eval_fn(params))
            history.append(rec)
            if self.logger is not None and self.logger.wants_round(rnd):
                self.logger.round(rnd, **{k: v for k, v in rec.items()
                                          if k != "round"})
            if self.ckpt is not None:
                self.ckpt.maybe_save(rnd, params, {"round": rnd})
        return params, history


# --------------------------------------------------------------- char-LM app
def run_char_lm(n_clients=16, rounds=30, topology="expander", degree=4,
                local_steps=3, batch=8, seq=64, lr=0.5, momentum=0.9,
                ckpt_dir=None, seed=0, drop_fraction=0.0, drop_round=10,
                round_plan="static", gossip_delay=0, gossip_sub_rounds=1,
                gossip_codec="f32", gossip_screen="none",
                attackers=0, attack_mode="sign_flip",
                attack_magnitude=1.0, active_set="full", active_k=1,
                active_shards=2, gossip_block=0,
                telemetry=False, telemetry_log=None) -> list[dict]:
    from repro.data import federated, pipeline, shakespeare

    toks, vocab = shakespeare.corpus()
    spans = federated.span_split(len(toks), n_clients, seed=seed)
    batcher = pipeline.TokenBatcher(tokens=toks, spans=spans, batch_size=batch,
                                    seq_len=seq, local_steps=local_steps,
                                    seed=seed)
    struct = lstm_model.param_struct(vocab=len(vocab))
    rng = jax.random.key(seed)
    one = params_lib.init_params(struct, rng)
    params = jax.vmap(lambda i: params_lib.init_params(struct, rng))(
        jnp.arange(n_clients))
    del one

    dfl = DFLConfig(topology=topology, degree=degree, seed=seed,
                    round_plan=round_plan)
    overlay = build_overlay(n_clients, dfl)
    dcfg = dfedavg.DFedAvgMConfig(local_steps=local_steps, lr=lr,
                                  momentum=momentum)
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    # a "static" plan is inert (is_active: gate pathway stays off)
    plan = overlay_plan.make_plan(dfl.round_plan, k=dfl.plan_k,
                                  fraction=dfl.plan_fraction, seed=seed)
    # a "full" active set is likewise inert (is_subsampling)
    active = overlay_plan.make_active_set(active_set, k=active_k,
                                          n_shards=active_shards, seed=seed)
    attack = None
    if attackers > 0:
        attack = failures_lib.sample_attackers(n_clients, attackers,
                                               mode=attack_mode,
                                               magnitude=attack_magnitude,
                                               seed=seed)
    logger = None
    if telemetry_log is not None:
        logger = TelemetryLogger(telemetry_log, run="char_lm",
                                 n_clients=n_clients, topology=topology,
                                 degree=degree, codec=gossip_codec)
    # the one engine-config front door: substrate x codec x delay x screen
    # (x telemetry) as a single cell instead of five loose knobs
    engine = engine_lib.GossipEngineConfig(
        substrate="blocked" if gossip_block else "stacked",
        codec=gossip_codec, delay=gossip_delay,
        sub_rounds=gossip_sub_rounds, screen=gossip_screen,
        block=gossip_block,
        telemetry=(telemetry_metrics.TelemetryConfig()
                   if telemetry or telemetry_log else None))
    trainer = SimTrainer(overlay=overlay, loss_fn=lstm_model.loss_fn,
                         dcfg=dcfg, ckpt=ckpt, plan=plan,
                         active_plan=active, engine=engine,
                         attack_plan=attack, attack_seed=seed,
                         logger=logger)

    # held-out evaluation: last 10% of the corpus
    ev = pipeline.TokenBatcher(tokens=toks, spans=[(int(len(toks) * .9),
                                                    len(toks))],
                               batch_size=32, seq_len=seq, local_steps=1,
                               seed=seed + 1)

    def eval_fn(params):
        b = ev.round_batches(0)
        p0 = jax.tree.map(lambda x: x[0], params)  # client-0 model
        loss, aux = lstm_model.loss_fn(p0, {"tokens": jnp.asarray(b["tokens"][0, 0]),
                                            "labels": jnp.asarray(b["labels"][0, 0])})
        return {"test_loss": float(loss), "test_acc": float(aux["acc"])}

    plan = None
    if drop_fraction > 0:
        plan = failures_lib.sample_failures(n_clients, drop_fraction,
                                            drop_round, seed=seed)

    def batch_fn(rnd):
        b = batcher.round_batches(rnd)
        return {"tokens": jnp.asarray(b["tokens"]),
                "labels": jnp.asarray(b["labels"])}

    start = 0
    if ckpt is not None and ckpt.latest_step() is not None:
        restored = ckpt.restore(params)
        if restored is not None:
            params, meta = restored
            start = int(meta.get("round", 0)) + 1
            print(f"[resume] from round {start}")

    params, history = trainer.run(params, batch_fn, rounds,
                                  lr_fn=lambda r: lr, eval_fn=eval_fn,
                                  failure_plan=plan, start_round=start)
    if logger is not None:
        logger.close()
    return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--topology", default="expander",
                    help="any family in repro.overlay.registry "
                         "(expander, ring, complete, torus, hypercube, "
                         "random_regular, onepeer_exp, erdos_renyi)")
    ap.add_argument("--degree", type=int, default=4)
    ap.add_argument("--plan", default="static",
                    choices=["static", "one_peer", "random_subset",
                             "throttle"],
                    help="time-varying round plan (gates-as-data)")
    ap.add_argument("--gossip-delay", type=int, default=0, choices=[0, 1],
                    help="1 = pipelined (one-round-delayed) gossip")
    ap.add_argument("--gossip-sub-rounds", type=int, default=1,
                    help="k >= 2: Chebyshev multi-round gossip — k gossip "
                         "sub-rounds per round with Chebyshev polynomial "
                         "weights over the mixing matrix (1 = sync engine)")
    ap.add_argument("--gossip-codec", default="f32",
                    choices=list(engine_lib.CODECS),
                    help="wire codec of the engine round (int8_block + "
                         "--gossip-delay 1 = pipelined+quantized; topk_ef "
                         "= sparse top-k wire with error feedback)")
    ap.add_argument("--gossip-screen", default="none",
                    choices=["none", "norm_clip", "trimmed_mean"],
                    help="Byzantine screen over received gossip payloads")
    ap.add_argument("--active-set", default="full",
                    choices=["full", "random_k", "shards", "stratified"],
                    help="round-level client subsampling plan "
                         "(participation-as-data, zero retraces)")
    ap.add_argument("--active-k", type=int, default=1,
                    help="active clients per round (random_k/stratified)")
    ap.add_argument("--active-shards", type=int, default=2,
                    help="cohort count (shards) / strata (stratified)")
    ap.add_argument("--gossip-block", type=int, default=0,
                    help="B > 0: blocked substrate, B simulated clients "
                         "per device (n/B devices; 0 = stacked)")
    ap.add_argument("--attackers", type=int, default=0,
                    help="number of scripted Byzantine clients")
    ap.add_argument("--attack-mode", default="sign_flip",
                    choices=["sign_flip", "scale", "noise"])
    ap.add_argument("--telemetry", action="store_true",
                    help="emit in-graph round metrics into the history "
                         "records (consensus residual, in-degree, gate "
                         "mass, clip counts)")
    ap.add_argument("--telemetry-log", default=None,
                    help="write the structured JSONL event stream here "
                         "(implies --telemetry)")
    ap.add_argument("--local-steps", type=int, default=3)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--drop-fraction", type=float, default=0.0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    hist = run_char_lm(n_clients=args.clients, rounds=args.rounds,
                       topology=args.topology, degree=args.degree,
                       local_steps=args.local_steps, lr=args.lr,
                       ckpt_dir=args.ckpt_dir,
                       drop_fraction=args.drop_fraction,
                       round_plan=args.plan, gossip_delay=args.gossip_delay,
                       gossip_sub_rounds=args.gossip_sub_rounds,
                       gossip_codec=args.gossip_codec,
                       gossip_screen=args.gossip_screen,
                       attackers=args.attackers,
                       attack_mode=args.attack_mode,
                       active_set=args.active_set, active_k=args.active_k,
                       active_shards=args.active_shards,
                       gossip_block=args.gossip_block,
                       telemetry=args.telemetry,
                       telemetry_log=args.telemetry_log)
    for rec in hist:
        print(json.dumps(rec))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
