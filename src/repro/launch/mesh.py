"""Production meshes and the DFL device-grid factorization.

`make_production_mesh` is the prescribed entry point:
    single-pod: (16, 16)       axes ("data", "model")     = 256 chips
    multi-pod:  (2, 16, 16)    axes ("pod", "data", "model") = 512 chips

`derive_dfl_mesh` refactors the same device grid for the DFL train step:
the "data" axis splits into (client, fsdp) — `clients_per_pod` DFL clients
per pod, each internally ZeRO/data-parallel over fsdp = 16/clients_per_pod
rows — while "model" stays the TP/EP axis. This is a pure reshape of the
device array (no re-placement); serving uses the production mesh directly.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def shard_map(f, mesh: Mesh, *, in_specs, out_specs):
    """Version-compat *full-manual* shard_map.

    Newer jax exposes ``jax.shard_map``; this jax build only has the
    experimental API (and its SPMD partitioner hard-crashes on partial-auto
    manual regions — ``IsManualSubgroup`` check — so every shard_map in this
    repo is fully manual over all mesh axes, with real per-leaf specs).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def derive_dfl_mesh(mesh: Mesh, clients_per_pod: int, tp: int | None = None) -> Mesh:
    """(pod?, data, model) -> (pod?, client, fsdp, dp, tp).

    `tp` right-sizes tensor parallelism: the model axis splits into
    (dp = model//tp, tp); the freed `dp` factor becomes extra within-client
    data parallelism (small models drown in TP activation all-reduces at
    width 16 — per-device AR bytes scale with per-device batch).
    """
    data = mesh.shape["data"]
    model = mesh.shape["model"]
    tp = model if tp is None else tp
    if data % clients_per_pod != 0:
        raise ValueError(f"clients_per_pod={clients_per_pod} must divide {data}")
    if model % tp != 0:
        raise ValueError(f"tp={tp} must divide {model}")
    fsdp = data // clients_per_pod
    dp = model // tp
    devices = np.asarray(mesh.devices)
    if devices.ndim == 3:  # multi-pod
        pods = devices.shape[0]
        grid = devices.reshape(pods, clients_per_pod, fsdp, dp, tp)
        return Mesh(grid, ("pod", "client", "fsdp", "dp", "tp"))
    grid = devices.reshape(clients_per_pod, fsdp, dp, tp)
    return Mesh(grid, ("client", "fsdp", "dp", "tp"))


def client_axes(dfl_mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes that jointly form the DFL client (gossip) axis."""
    return ("pod", "client") if "pod" in dfl_mesh.axis_names else ("client",)


def n_clients(dfl_mesh: Mesh) -> int:
    return int(np.prod([dfl_mesh.shape[a] for a in client_axes(dfl_mesh)]))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Serving batch axes on the production mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
