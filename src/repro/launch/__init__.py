"""Launchers: production meshes, step builders, dry-run, train/serve drivers."""
