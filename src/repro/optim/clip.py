"""Gradient clipping utilities."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm
