"""Optimizers and schedules (pure JAX, no external deps)."""
from repro.optim.sgdm import SGDMState, sgdm_init, sgdm_step  # noqa: F401
from repro.optim.adamw import AdamWState, adamw_init, adamw_step  # noqa: F401
from repro.optim.schedules import constant, cosine, inverse_time, warmup_cosine  # noqa: F401
from repro.optim.clip import global_norm, clip_by_global_norm  # noqa: F401
