"""Learning-rate schedules (callables: step -> lr)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def inverse_time(c: float):
    """eta_t = c / t — the schedule of the paper's stability Theorem 2.5."""
    return lambda step: jnp.asarray(c, jnp.float32) / jnp.maximum(
        jnp.asarray(step, jnp.float32), 1.0)


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        frac = jnp.clip(jnp.asarray(step, jnp.float32) / max(total_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(math.pi * frac))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac=0.1):
    base = cosine(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        return jnp.where(step < warmup, warm, base(step - warmup))
    return fn
