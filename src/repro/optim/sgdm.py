"""SGD with (heavy-ball) momentum — the paper's local solver, eq. 2.1.

Kept optimizer-shaped (init/step over pytrees) so the production trainer and
the simulator share it; `core.dfedavg.local_round` uses the same update via
`momentum_update` / the fused Pallas kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass
class SGDMState:
    velocity: PyTree
    step: jax.Array

    def tree_flatten(self):
        return (self.velocity, self.step), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    SGDMState, SGDMState.tree_flatten, SGDMState.tree_unflatten)


def sgdm_init(params: PyTree, dtype=None) -> SGDMState:
    vel = jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)
    return SGDMState(velocity=vel, step=jnp.zeros((), jnp.int32))


def sgdm_step(params: PyTree, grads: PyTree, state: SGDMState, lr, beta=0.9,
              weight_decay: float = 0.0, nesterov: bool = False
              ) -> tuple[PyTree, SGDMState]:
    if weight_decay:
        grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
    new_v = jax.tree.map(
        lambda v, g: (beta * v.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(v.dtype),
        state.velocity, grads)
    if nesterov:
        upd = jax.tree.map(lambda v, g: beta * v.astype(jnp.float32)
                           - lr * g.astype(jnp.float32), new_v, grads)
    else:
        upd = new_v
    new_p = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, upd)
    return new_p, SGDMState(velocity=new_v, step=state.step + 1)
