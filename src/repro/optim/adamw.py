"""AdamW (decoupled weight decay) — used by the LM examples."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any
F32 = jnp.float32


@dataclasses.dataclass
class AdamWState:
    mu: PyTree
    nu: PyTree
    step: jax.Array

    def tree_flatten(self):
        return (self.mu, self.nu, self.step), None

    @classmethod
    def tree_unflatten(cls, _, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    AdamWState, AdamWState.tree_flatten, AdamWState.tree_unflatten)


def adamw_init(params: PyTree) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, F32)
    return AdamWState(mu=jax.tree.map(z, params), nu=jax.tree.map(z, params),
                      step=jnp.zeros((), jnp.int32))


def adamw_step(params: PyTree, grads: PyTree, state: AdamWState, lr,
               b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0
               ) -> tuple[PyTree, AdamWState]:
    step = state.step + 1
    t = step.astype(F32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(F32),
                      state.mu, grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g.astype(F32)),
                      state.nu, grads)

    def upd(p, m, n):
        mh, nh = m / c1, n / c2
        step_ = lr * (mh / (jnp.sqrt(nh) + eps) + weight_decay * p.astype(F32))
        return (p.astype(F32) - step_).astype(p.dtype)

    new_p = jax.tree.map(upd, params, mu, nu)
    return new_p, AdamWState(mu=mu, nu=nu, step=step)
