"""Overlay lab: graph families, graph -> overlay conversion, round plans.

The paper's contribution is the overlay itself; this package makes it a
first-class, sweepable subsystem on top of the packed gossip engine:

* :mod:`repro.overlay.registry` — named graph families (ring, torus,
  hypercube, random d-regular, one-peer exponential, Erdos-Renyi, complete,
  the paper's §4 expander), each returning an
  :class:`~repro.core.topology.Overlay` plus a comparison metadata record.
* :mod:`repro.overlay.convert` — the §4 "arbitrary given graph" pathway:
  Misra-Gries edge coloring (+ Euler-tour splitting for high degrees) turns
  any connected adjacency matrix into <= Delta+1 permutation schedules the
  packed engine executes directly.
* :mod:`repro.overlay.plan` — time-varying round plans: per-schedule gate
  vectors shipped as donated step data (one-peer rotation, random subsets,
  bandwidth throttling) with zero retraces across rounds, plus active-set
  plans — per-CLIENT participation vectors (random-k, round-robin shards,
  stratified cohorts) that decouple the enrolled population from the
  per-round cohort through the same data-not-structure pathway.
"""
from repro.overlay.convert import overlay_from_adjacency  # noqa: F401
from repro.overlay.plan import (  # noqa: F401
    ActiveSetPlan,
    FullActiveSet,
    OnePeerPlan,
    RandomKActiveSet,
    RandomSubsetPlan,
    RoundPlan,
    ShardActiveSet,
    StaticPlan,
    StratifiedActiveSet,
    ThrottlePlan,
    make_active_set,
    make_plan,
)
from repro.overlay.registry import (  # noqa: F401
    blocked_profile,
    build,
    names,
    overlay_meta,
)
