"""Time-varying round plans: per-schedule gates shipped as step *data*.

A :class:`RoundPlan` maps a round index to a float gate vector over the
overlay's schedules. The gates ride into the jitted train step as a donated
``(n_schedules,)`` f32 argument — exactly the PR-2 alive-as-data design —
and fold into the packed mixing reduction's existing per-sender weight
operands (`gossip.ppermute_mix_packed(..., gates=...)`), which renormalize
over the *gated* in-degree inside the same fused HBM pass. Consequences:

* **zero retraces**: one-peer rotation, randomized schedule subsets, and
  bandwidth-throttled rounds all reuse ONE executable — the gate values are
  data, never trace structure. Only membership changes (splice repair)
  re-jit, exactly as before.
* the full d-schedule pool stays compiled in: a gated-off schedule still
  issues its (cheap, fully overlappable) ppermute and contributes weight
  zero. That trades wire bytes for compile stability; if a deployment needs
  the bytes back, precompile one executable per gate *support* from a small
  pool — the plan's supports are few (see the ROADMAP design record).

Plans are stateless in the round index (``gates(rnd, n_schedules)``), so a
splice repair that changes the schedule count mid-run needs no plan surgery.

The same design scales to the CLIENT axis: an :class:`ActiveSetPlan` maps the
round index to a per-client participation vector over ``n_clients`` — the
cross-device regime enrolls far more clients than gossip in any one round, so
round cohorts must be round *data*, not membership. The active vector
multiplies into the straggler ``alive`` mask before the engine's shared
weight-table path (`gossip.alive_weight_table`): an inactive client keeps its
params (identity row) and contributes nothing to its neighbors — exactly the
dead-client mixing semantics — but, unlike `alive`, the active set never feeds
``HealthTracker``: sitting a round out is scheduled, not suspicious. Cohort
rotations (random-k, round-robin shards, stratified) therefore reuse ONE
executable with zero retraces and compose with gates, screens, attacks, and
splice repair unchanged. Like round plans, active-set plans are stateless in
``(rnd, n_clients)``, so repair needs no plan surgery.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "RoundPlan",
    "StaticPlan",
    "OnePeerPlan",
    "RandomSubsetPlan",
    "ThrottlePlan",
    "make_plan",
    "gates_for",
    "is_active",
    "PLAN_NAMES",
    "ActiveSetPlan",
    "FullActiveSet",
    "RandomKActiveSet",
    "ShardActiveSet",
    "StratifiedActiveSet",
    "make_active_set",
    "active_for",
    "is_subsampling",
    "ACTIVE_SET_NAMES",
]

# every name make_plan accepts; config validation (launch.steps) checks
# against this so a typo'd DFLConfig.round_plan errors instead of silently
# flipping the gate pathway on
PLAN_NAMES = ("static", "one_peer", "random_subset", "throttle")


def is_active(plan: "RoundPlan | None") -> bool:
    """Whether a plan engages the gate pathway. THE single predicate both
    trainers use, and it must agree with the production step builder's
    config-side rule (``DFLConfig.round_plan != "static"``): a static plan
    is equivalent to no plan, so it keeps the gate pathway OFF — gating
    with all-ones is NOT a no-op on overlays whose Chow self-weight is
    negative (the gated branch clamps them to the lazy variant)."""
    return plan is not None and plan.name != "static"


def gates_for(plan: "RoundPlan | None", rnd: int,
              n_schedules: int) -> np.ndarray:
    """The round's gate vector: all-ones when no plan is configured (the
    shared helper both trainers ship into the jitted step)."""
    if plan is None:
        return np.ones(n_schedules, dtype=np.float32)
    return plan.gates(rnd, n_schedules)


class RoundPlan:
    """Base: all schedules on every round (same as no plan)."""

    name = "static"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        return np.ones(n_schedules, dtype=np.float32)


class StaticPlan(RoundPlan):
    pass


@dataclasses.dataclass
class OnePeerPlan(RoundPlan):
    """One-peer rotation: round r exchanges only over schedule r mod S.

    Over the ``onepeer_exp`` family this is the one-peer exponential
    rotation; over a matching-union family it is a deterministic
    time-varying matching sequence. Per-round mixing degree is 1, and S
    consecutive rounds cover the whole pool.
    """

    offset: int = 0
    name: str = "one_peer"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        g = np.zeros(n_schedules, dtype=np.float32)
        if n_schedules:
            g[(rnd + self.offset) % n_schedules] = 1.0
        return g


@dataclasses.dataclass
class RandomSubsetPlan(RoundPlan):
    """Randomized matching subsets: k schedules drawn per round (stateless:
    the draw is seeded by (seed, rnd), so replay/resume sees the same plan)."""

    k: int = 1
    seed: int = 0
    name: str = "random_subset"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        g = np.zeros(n_schedules, dtype=np.float32)
        if n_schedules:
            rng = np.random.default_rng((self.seed, rnd))
            k = min(max(int(self.k), 1), n_schedules)
            g[rng.choice(n_schedules, size=k, replace=False)] = 1.0
        return g


@dataclasses.dataclass
class ThrottlePlan(RoundPlan):
    """Bandwidth throttle: only ceil(fraction * S) schedules gossip per
    round, rotating through the pool so coverage stays uniform over time."""

    fraction: float = 0.5
    name: str = "throttle"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        g = np.zeros(n_schedules, dtype=np.float32)
        if n_schedules:
            m = min(n_schedules,
                    max(1, int(np.ceil(self.fraction * n_schedules))))
            start = (rnd * m) % n_schedules
            g[(start + np.arange(m)) % n_schedules] = 1.0
        return g


def make_plan(name: str, *, k: int = 1, fraction: float = 0.5,
              seed: int = 0) -> RoundPlan:
    """Config-level factory (`DFLConfig.round_plan`)."""
    if name == "static":
        return StaticPlan()
    if name == "one_peer":
        return OnePeerPlan()
    if name == "random_subset":
        return RandomSubsetPlan(k=k, seed=seed)
    if name == "throttle":
        return ThrottlePlan(fraction=fraction)
    raise ValueError(f"unknown round plan {name!r}; available: "
                     f"{', '.join(PLAN_NAMES)}")


# ---------------------------------------------------------------------------
# Active-set plans: round-level client subsampling, shipped as step data.
# ---------------------------------------------------------------------------

# every name make_active_set accepts; config validation (launch.steps) checks
# against this so a typo'd DFLConfig.active_set errors instead of silently
# disabling subsampling
ACTIVE_SET_NAMES = ("full", "random_k", "shards", "stratified")


def is_subsampling(plan: "ActiveSetPlan | None") -> bool:
    """Whether a plan engages the active-set pathway. Mirrors
    :func:`is_active` for round plans and must agree with the production step
    builder's config-side rule (``DFLConfig.active_set != "full"``): the full
    plan is equivalent to no plan, so the step signature stays unchanged and
    the default-config HLO anchors (delay-0 identity) keep holding."""
    return plan is not None and plan.name != "full"


def active_for(plan: "ActiveSetPlan | None", rnd: int,
               n_clients: int) -> np.ndarray:
    """The round's participation vector: all-ones when no plan is configured
    (the shared helper both trainers ship into the jitted step)."""
    if plan is None:
        return np.ones(n_clients, dtype=np.float32)
    return plan.active(rnd, n_clients)


class ActiveSetPlan:
    """Base: every client participates every round (same as no plan)."""

    name = "full"

    def active(self, rnd: int, n_clients: int) -> np.ndarray:
        return np.ones(n_clients, dtype=np.float32)


class FullActiveSet(ActiveSetPlan):
    pass


@dataclasses.dataclass
class RandomKActiveSet(ActiveSetPlan):
    """Uniform random cohorts: k clients drawn per round (stateless: the
    draw is seeded by (seed, rnd), so replay/resume sees the same cohorts)."""

    k: int = 1
    seed: int = 0
    name: str = "random_k"

    def active(self, rnd: int, n_clients: int) -> np.ndarray:
        a = np.zeros(n_clients, dtype=np.float32)
        if n_clients:
            rng = np.random.default_rng((self.seed, rnd))
            k = min(max(int(self.k), 1), n_clients)
            a[rng.choice(n_clients, size=k, replace=False)] = 1.0
        return a


@dataclasses.dataclass
class ShardActiveSet(ActiveSetPlan):
    """Round-robin shards: round r activates cohort ``i % n_shards ==
    r % n_shards``. Deterministic, disjoint, and n_shards consecutive rounds
    cover every client exactly once."""

    n_shards: int = 2
    name: str = "shards"

    def active(self, rnd: int, n_clients: int) -> np.ndarray:
        a = np.zeros(n_clients, dtype=np.float32)
        if n_clients:
            s = min(max(int(self.n_shards), 1), n_clients)
            a[np.arange(n_clients) % s == rnd % s] = 1.0
        return a


@dataclasses.dataclass
class StratifiedActiveSet(ActiveSetPlan):
    """Stratified cohorts: clients split into ``n_strata`` contiguous strata
    (a stand-in for any grouping key — region, hardware class), and each
    round draws ~k/n_strata participants per stratum, so every stratum stays
    represented in every round's cohort."""

    k: int = 2
    n_strata: int = 2
    seed: int = 0
    name: str = "stratified"

    def active(self, rnd: int, n_clients: int) -> np.ndarray:
        a = np.zeros(n_clients, dtype=np.float32)
        if not n_clients:
            return a
        s = min(max(int(self.n_strata), 1), n_clients)
        per = max(1, int(round(self.k / s)))
        bounds = np.linspace(0, n_clients, s + 1).astype(int)
        for j in range(s):
            lo, hi = int(bounds[j]), int(bounds[j + 1])
            if hi <= lo:
                continue
            rng = np.random.default_rng((self.seed, rnd, j))
            take = min(per, hi - lo)
            a[lo + rng.choice(hi - lo, size=take, replace=False)] = 1.0
        return a


def make_active_set(name: str, *, k: int = 1, n_shards: int = 2,
                    seed: int = 0) -> ActiveSetPlan:
    """Config-level factory (`DFLConfig.active_set`)."""
    if name == "full":
        return FullActiveSet()
    if name == "random_k":
        return RandomKActiveSet(k=k, seed=seed)
    if name == "shards":
        return ShardActiveSet(n_shards=n_shards)
    if name == "stratified":
        return StratifiedActiveSet(k=k, n_strata=n_shards, seed=seed)
    raise ValueError(f"unknown active-set plan {name!r}; available: "
                     f"{', '.join(ACTIVE_SET_NAMES)}")
