"""Time-varying round plans: per-schedule gates shipped as step *data*.

A :class:`RoundPlan` maps a round index to a float gate vector over the
overlay's schedules. The gates ride into the jitted train step as a donated
``(n_schedules,)`` f32 argument — exactly the PR-2 alive-as-data design —
and fold into the packed mixing reduction's existing per-sender weight
operands (`gossip.ppermute_mix_packed(..., gates=...)`), which renormalize
over the *gated* in-degree inside the same fused HBM pass. Consequences:

* **zero retraces**: one-peer rotation, randomized schedule subsets, and
  bandwidth-throttled rounds all reuse ONE executable — the gate values are
  data, never trace structure. Only membership changes (splice repair)
  re-jit, exactly as before.
* the full d-schedule pool stays compiled in: a gated-off schedule still
  issues its (cheap, fully overlappable) ppermute and contributes weight
  zero. That trades wire bytes for compile stability; if a deployment needs
  the bytes back, precompile one executable per gate *support* from a small
  pool — the plan's supports are few (see the ROADMAP design record).

Plans are stateless in the round index (``gates(rnd, n_schedules)``), so a
splice repair that changes the schedule count mid-run needs no plan surgery.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "RoundPlan",
    "StaticPlan",
    "OnePeerPlan",
    "RandomSubsetPlan",
    "ThrottlePlan",
    "make_plan",
    "gates_for",
    "is_active",
    "PLAN_NAMES",
]

# every name make_plan accepts; config validation (launch.steps) checks
# against this so a typo'd DFLConfig.round_plan errors instead of silently
# flipping the gate pathway on
PLAN_NAMES = ("static", "one_peer", "random_subset", "throttle")


def is_active(plan: "RoundPlan | None") -> bool:
    """Whether a plan engages the gate pathway. THE single predicate both
    trainers use, and it must agree with the production step builder's
    config-side rule (``DFLConfig.round_plan != "static"``): a static plan
    is equivalent to no plan, so it keeps the gate pathway OFF — gating
    with all-ones is NOT a no-op on overlays whose Chow self-weight is
    negative (the gated branch clamps them to the lazy variant)."""
    return plan is not None and plan.name != "static"


def gates_for(plan: "RoundPlan | None", rnd: int,
              n_schedules: int) -> np.ndarray:
    """The round's gate vector: all-ones when no plan is configured (the
    shared helper both trainers ship into the jitted step)."""
    if plan is None:
        return np.ones(n_schedules, dtype=np.float32)
    return plan.gates(rnd, n_schedules)


class RoundPlan:
    """Base: all schedules on every round (same as no plan)."""

    name = "static"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        return np.ones(n_schedules, dtype=np.float32)


class StaticPlan(RoundPlan):
    pass


@dataclasses.dataclass
class OnePeerPlan(RoundPlan):
    """One-peer rotation: round r exchanges only over schedule r mod S.

    Over the ``onepeer_exp`` family this is the one-peer exponential
    rotation; over a matching-union family it is a deterministic
    time-varying matching sequence. Per-round mixing degree is 1, and S
    consecutive rounds cover the whole pool.
    """

    offset: int = 0
    name: str = "one_peer"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        g = np.zeros(n_schedules, dtype=np.float32)
        if n_schedules:
            g[(rnd + self.offset) % n_schedules] = 1.0
        return g


@dataclasses.dataclass
class RandomSubsetPlan(RoundPlan):
    """Randomized matching subsets: k schedules drawn per round (stateless:
    the draw is seeded by (seed, rnd), so replay/resume sees the same plan)."""

    k: int = 1
    seed: int = 0
    name: str = "random_subset"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        g = np.zeros(n_schedules, dtype=np.float32)
        if n_schedules:
            rng = np.random.default_rng((self.seed, rnd))
            k = min(max(int(self.k), 1), n_schedules)
            g[rng.choice(n_schedules, size=k, replace=False)] = 1.0
        return g


@dataclasses.dataclass
class ThrottlePlan(RoundPlan):
    """Bandwidth throttle: only ceil(fraction * S) schedules gossip per
    round, rotating through the pool so coverage stays uniform over time."""

    fraction: float = 0.5
    name: str = "throttle"

    def gates(self, rnd: int, n_schedules: int) -> np.ndarray:
        g = np.zeros(n_schedules, dtype=np.float32)
        if n_schedules:
            m = min(n_schedules,
                    max(1, int(np.ceil(self.fraction * n_schedules))))
            start = (rnd * m) % n_schedules
            g[(start + np.arange(m)) % n_schedules] = 1.0
        return g


def make_plan(name: str, *, k: int = 1, fraction: float = 0.5,
              seed: int = 0) -> RoundPlan:
    """Config-level factory (`DFLConfig.round_plan`)."""
    if name == "static":
        return StaticPlan()
    if name == "one_peer":
        return OnePeerPlan()
    if name == "random_subset":
        return RandomSubsetPlan(k=k, seed=seed)
    if name == "throttle":
        return ThrottlePlan(fraction=fraction)
    raise ValueError(f"unknown round plan {name!r}; available: "
                     f"{', '.join(PLAN_NAMES)}")
