"""Graph-family registry: named overlay constructions, selectable by config.

Every family is a function ``(n, degree, seed) -> Overlay`` registered under a
string name; :func:`build` adds a uniform metadata record (degree, spectral
gap, Chow lambda, mixing time) so sweeps and configs can treat the topology as
a first-class, comparable component instead of a hardcoded enum.

Families (beyond the paper's ring / expander / complete):

* ``torus``       — 2D wrap-around grid (4 cyclic-shift schedules). The
                    classic datacenter/ICI-native topology; kappa grows as
                    O(n) vs the ring's O(n^2).
* ``hypercube``   — n = 2^k, one XOR-involution schedule per dimension;
                    log2(n)-regular with O(1) spectral gap growth.
* ``random_regular`` — union of d independent random perfect matchings.
                    Near-Ramanujan w.h.p. (Friedman), the standard
                    "near-optimal d-regular expander" reference family.
* ``onepeer_exp`` — exponential graph: shifts by +-2^j. Designed for the
                    one-peer round plans (`repro.overlay.plan`): gating one
                    schedule per round gives the provably-efficient one-peer
                    exponential rotation at degree-1 per-round cost.
* ``erdos_renyi`` — G(n, ln n / n), converted to schedules through the
                    Misra-Gries decomposition (`repro.overlay.convert`) —
                    the "arbitrary given graph" pathway exercised end to end.

``ring``, ``expander`` (paper §4 virtual ring spaces), and ``complete`` are
registered too, so ``DFLConfig.topology`` can name any family.
"""
from __future__ import annotations

import math
from typing import Callable

import numpy as np

from repro.core import spectral, topology
from repro.core.topology import Overlay
from repro.overlay import convert

__all__ = [
    "register",
    "names",
    "get_family",
    "build",
    "overlay_meta",
    "chebyshev_schedule",
    "blocked_profile",
    "torus_overlay",
    "hypercube_overlay",
    "random_regular_overlay",
    "onepeer_exponential_overlay",
]

# family fn: (n, degree, seed) -> Overlay  (degree/seed ignored where moot)
Family = Callable[[int, int, int], Overlay]

_FAMILIES: dict[str, Family] = {}


def register(name: str):
    def deco(fn: Family) -> Family:
        if name in _FAMILIES:
            raise ValueError(f"overlay family {name!r} already registered")
        _FAMILIES[name] = fn
        return fn
    return deco


def names() -> list[str]:
    return sorted(_FAMILIES)


def get_family(name: str) -> Family:
    try:
        return _FAMILIES[name]
    except KeyError:
        raise ValueError(
            f"unknown overlay family {name!r}; available: {names()}") from None


def overlay_meta(overlay: Overlay) -> dict:
    """Uniform comparison record for one overlay (host-side, numpy)."""
    rep = overlay.spectral_report()
    meta = {
        "family": overlay.name,
        "n": overlay.n,
        "n_schedules": len(overlay.schedules),
        "degree_max": rep.degree_max,
        "connected": rep.connected,
        "kappa": rep.kappa,
        "is_ramanujan": rep.is_ramanujan,
    }
    if rep.connected:
        w = overlay.chow_weights()
        meta.update(lam=w.lam, spectral_gap=1.0 - w.lam,
                    mixing_time_1e3=spectral.mixing_time(w.lam),
                    # effective 2-sub-round contraction (1/T_2(1/lam)) —
                    # what the Chebyshev sub_rounds=2 timing cell buys,
                    # next to lam**2 for plain repetition
                    cheby_lambda_k2=spectral.chebyshev_lambda(w.lam, 2))
    else:
        meta.update(lam=1.0, spectral_gap=0.0, mixing_time_1e3=float("inf"),
                    cheby_lambda_k2=1.0)
    return meta


def chebyshev_schedule(overlay: Overlay, k: int,
                       theta: float | None = None) -> np.ndarray:
    """(k,) f32 Chebyshev sub-round coefficients for an overlay's Chow
    mixing matrix — the host-side coefficient chooser the trainers feed the
    engine's ``cheby`` operand from. Uses the SAME lambda(M) the registry
    metadata reports (``overlay_meta(...)['lam']`` == ``chow_weights().lam``:
    max(|lambda_2|, |lambda_N|) of M, always in [0, 1) for connected
    overlays — the sign/normalization convention pinned by
    tests/test_spectral.py). A lam outside [0, 1) (a badly-chosen theta can
    push it to 1) degenerates to all-ones — k plain rounds, never a
    blow-up; disconnected overlays have no Chow matrix and raise here like
    everywhere else."""
    return spectral.chebyshev_omegas(overlay.chow_weights(theta).lam, k)


def build(name: str, n: int, degree: int = 4, seed: int = 0
          ) -> tuple[Overlay, dict]:
    """Build a named family at size n; returns (overlay, metadata)."""
    overlay = get_family(name)(n, degree, seed)
    return overlay, overlay_meta(overlay)


def blocked_profile(overlay: Overlay, block: int) -> dict:
    """How an overlay's schedules partition under the ``blocked`` substrate
    (B clients per device, row-major placement): which schedules stay fully
    intra-device and how many whole-block collectives the rest cost per
    round. Structured families placed contiguously are intra-heavy (a torus
    row shift crosses only at block boundaries); a random expander's
    matchings touch many device pairs — this record is what bench_scale and
    the sweep reports use to compare them at fixed n.
    """
    from repro.core import gossip

    spec = gossip.make_gossip_spec(overlay)
    bs = gossip.make_blocked_spec(spec, block)
    return {
        "family": overlay.name,
        "n": overlay.n,
        "block": bs.block,
        "n_devices": bs.n_devices,
        "n_schedules": spec.degree,
        "intra_schedules": spec.degree - bs.cross_schedules,
        "cross_schedules": bs.cross_schedules,
        "transfers_per_round": bs.n_transfers,
    }


# ------------------------------------------------------------------ families
@register("ring")
def _ring(n: int, degree: int, seed: int) -> Overlay:
    return topology.ring_overlay(n)


@register("expander")
def _expander(n: int, degree: int, seed: int) -> Overlay:
    return topology.expander_overlay(n, degree, seed=seed)


@register("complete")
def _complete(n: int, degree: int, seed: int) -> Overlay:
    # n-1 cyclic shifts: shift-by-k's inverse is shift-by-(n-k), present for
    # every k, so the set is closed under inverse (all-to-all form)
    if n < 3:
        raise ValueError("complete needs n >= 3")
    scheds = [np.roll(np.arange(n), -k) for k in range(1, n)]
    return Overlay(n=n, schedules=scheds, name="complete")


def _torus_dims(n: int) -> tuple[int, int]:
    """Most-square factorization r*c = n with r, c >= 3."""
    for r in range(int(math.isqrt(n)), 2, -1):
        if n % r == 0 and n // r >= 3:
            return r, n // r
    raise ValueError(f"torus needs n = r*c with r, c >= 3; n={n} does not "
                     "factor that way")


@register("torus")
def torus_overlay(n: int, degree: int = 4, seed: int = 0) -> Overlay:
    """2D torus on the most-square r x c grid: 4 cyclic-shift schedules
    (row +-1, col +-1), the wrap-around mesh the hardware itself uses."""
    r, c = _torus_dims(n)
    a, b = np.divmod(np.arange(n), c)
    scheds = [
        ((a + 1) % r) * c + b,          # row successor
        ((a - 1) % r) * c + b,          # row predecessor
        a * c + (b + 1) % c,            # col successor
        a * c + (b - 1) % c,            # col predecessor
    ]
    return Overlay(n=n, schedules=[s.astype(np.int64) for s in scheds],
                   name=f"torus-{r}x{c}")


@register("hypercube")
def hypercube_overlay(n: int, degree: int = 0, seed: int = 0) -> Overlay:
    """Boolean k-cube (n = 2^k): one XOR involution per dimension."""
    k = n.bit_length() - 1
    if n < 4 or (1 << k) != n:
        raise ValueError(f"hypercube needs n a power of two >= 4, got {n}")
    idx = np.arange(n, dtype=np.int64)
    scheds = [idx ^ (1 << j) for j in range(k)]
    return Overlay(n=n, schedules=scheds, name=f"hypercube-{k}d")


def _matching_avoiding(n: int, rng: np.random.Generator,
                       used: np.ndarray, tries: int = 32) -> np.ndarray | None:
    """Random perfect matching avoiding the 0/1 ``used`` edge set: shuffle,
    then pair each node with a random non-used partner (retry when stuck)."""
    for _ in range(tries):
        pool = list(rng.permutation(n))
        s = np.arange(n, dtype=np.int64)
        ok = True
        while pool:
            u = pool.pop()
            options = [v for v in pool if not used[u, v]]
            if not options:
                ok = False
                break
            v = options[rng.integers(len(options))]
            pool.remove(v)
            s[u], s[v] = v, u
        if ok:
            return s
    return None


@register("random_regular")
def random_regular_overlay(n: int, degree: int = 4, seed: int = 0,
                           max_tries: int = 64) -> Overlay:
    """d-regular graph as a union of d random perfect matchings (n even);
    each matching is drawn conditioned to avoid the union so far (plain
    independent draws collide with probability ~1 at small n), and the
    whole draw retries until connected. Friedman's theorem: random regular
    graphs are near-Ramanujan (lambda_2 <= 2 sqrt(d-1) + eps) w.h.p."""
    if n % 2 != 0:
        raise ValueError("random_regular needs even n (perfect matchings)")
    if degree < 2:
        raise ValueError("random_regular needs degree >= 2")
    if degree >= n:
        raise ValueError(f"degree {degree} needs n > degree, got n={n}")
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        used = np.zeros((n, n), dtype=bool)
        scheds: list[np.ndarray] = []
        for _d in range(degree):
            s = _matching_avoiding(n, rng, used)
            if s is None:
                break
            scheds.append(s)
            used[np.arange(n), s] = True
            used[s, np.arange(n)] = True
        if len(scheds) < degree:
            continue
        ov = Overlay(n=n, schedules=scheds, name=f"random-regular-d{degree}")
        if spectral.is_connected(ov.multigraph_adjacency()):
            return ov
    raise RuntimeError(
        f"could not draw a simple connected {degree}-regular matching union")


@register("onepeer_exp")
def onepeer_exponential_overlay(n: int, degree: int = 0, seed: int = 0
                                ) -> Overlay:
    """Exponential graph: shifts by +-2^j for 2^j < n. The full graph is
    ~2 log2(n)-regular with O(1/log n) gap; under a one-peer round plan it
    is the provably-efficient one-peer exponential rotation."""
    if n < 3:
        raise ValueError("onepeer_exp needs n >= 3")
    idx = np.arange(n, dtype=np.int64)
    scheds, seen = [], set()
    j = 0
    while (1 << j) < n:
        for shift in (1 << j, -(1 << j)):
            s = (idx + shift) % n
            key = tuple(s.tolist())
            if key not in seen:   # 2^j == n/2: +shift and -shift coincide
                seen.add(key)
                scheds.append(s)
        j += 1
    return Overlay(n=n, schedules=scheds, name="onepeer-exp")


@register("erdos_renyi")
def _erdos_renyi(n: int, degree: int, seed: int) -> Overlay:
    adj = topology.erdos_renyi_adjacency(n, seed=seed)
    return convert.overlay_from_adjacency(adj.astype(np.int64),
                                          name="erdos-renyi")
