"""Graph -> Overlay conversion (paper §4: "an arbitrary given graph").

The packed gossip engine executes *permutation schedules* (one
``lax.ppermute`` each), not adjacency matrices. This module turns any
connected simple graph into that form by decomposing its edge set into
matchings, each of which is an involution schedule (``s[u] = v, s[v] = u``
for every colored edge, fixed points elsewhere):

* **edge coloring** (`misra_gries_edge_coloring`): the Misra-Gries
  constructive proof of Vizing's theorem colors the edges of a graph with
  maximum degree Delta using at most **Delta + 1** colors in O(V*E). Each
  color class is a matching, so an arbitrary graph becomes at most
  Delta + 1 schedules — within one of the information-theoretic floor
  (a matching covers each node at most once, so Delta schedules are
  necessary).
* **Euler-tour splitting** (`euler_split`): for high-degree graphs the
  O(V*E) fan/path recoloring gets slow, so `overlay_from_adjacency` first
  halves the graph recursively along Euler circuits (Gabow's divide step:
  walking an Euler circuit and assigning edges alternately to the two
  halves splits every vertex degree as evenly as possible), colors the
  low-degree leaves, and concatenates — a few extra colors
  (<= Delta + O(log Delta)) for a near-linear-time decomposition.

The resulting :class:`~repro.core.topology.Overlay` reproduces the input
exactly: ``overlay.multigraph_adjacency() == adj`` (each edge lands in
exactly one matching), and every schedule is its own inverse, so the
schedule set is trivially closed under inverse as `Overlay` requires.
"""
from __future__ import annotations

import numpy as np

from repro.core import spectral
from repro.core.topology import Overlay

__all__ = [
    "misra_gries_edge_coloring",
    "euler_split",
    "matchings_to_schedules",
    "overlay_from_adjacency",
]


def _validate_adjacency(adj: np.ndarray) -> np.ndarray:
    adj = np.asarray(adj)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if not np.array_equal(adj, adj.T):
        raise ValueError("adjacency must be symmetric (undirected graph)")
    if np.any(np.diag(adj) != 0):
        raise ValueError("adjacency must have zero diagonal (no self-loops)")
    if not np.isin(adj, (0, 1)).all():
        raise ValueError("adjacency must be 0/1 (simple graph)")
    return adj.astype(np.int64)


def misra_gries_edge_coloring(adj: np.ndarray) -> list[dict[int, int]]:
    """Proper edge coloring with <= max_degree + 1 colors (Vizing bound).

    Returns one ``{u: v, v: u}`` matching dict per color (empty classes
    dropped). Misra & Gries (1992): color edges one at a time; when the
    obvious color is taken, rotate a *maximal fan* of colored edges around
    one endpoint and invert an alternating *cd-path* to free it up.
    """
    adj = _validate_adjacency(adj)
    n = adj.shape[0]
    max_deg = int(adj.sum(axis=1).max()) if n else 0
    n_colors = max_deg + 1
    # color[u][v] = color of edge {u,v} (or -1); by_color[u][c] = partner of
    # u on color c (or -1). Both views kept in sync for O(1) queries.
    color = -np.ones((n, n), dtype=np.int64)
    by_color = -np.ones((n, n_colors + 1), dtype=np.int64)

    def set_color(u: int, v: int, c: int) -> None:
        old = color[u, v]
        if old >= 0:
            by_color[u, old] = -1
            by_color[v, old] = -1
        color[u, v] = color[v, u] = c
        by_color[u, c] = v
        by_color[v, c] = u

    def free_color(u: int) -> int:
        return int(np.argmin(by_color[u, :n_colors] >= 0))

    us, vs = np.nonzero(np.triu(adj, k=1))
    for u, v in zip(us.tolist(), vs.tolist()):
        # maximal fan of u starting at v: distinct colored neighbors
        # f_0=v, f_1, ... where color(u, f_{i+1}) is free on f_i
        fan = [v]
        in_fan = {v}
        candidates = [w for w in np.nonzero(adj[u])[0].tolist()
                      if color[u, w] >= 0]
        grew = True
        while grew:
            grew = False
            last = fan[-1]
            for w in candidates:
                if w not in in_fan and by_color[last, color[u, w]] < 0:
                    fan.append(w)
                    in_fan.add(w)
                    grew = True
                    break
        c = free_color(u)
        d = free_color(fan[-1])
        if by_color[u, d] >= 0:
            # invert the cd-path through u (edges alternate d, c, d, ...);
            # path is simple because each vertex has <= 1 edge per color
            x, col = u, d
            path: list[tuple[int, int]] = []
            while by_color[x, col] >= 0:
                y = int(by_color[x, col])
                path.append((x, y))
                x, col = y, (c if col == d else d)
                assert len(path) <= n, "cd-path cycled: coloring corrupt"
            # swap c <-> d along the path: clear first, then reassign —
            # flipping in place would transiently duplicate a color at the
            # shared vertex of consecutive path edges and corrupt by_color
            flipped = [d if int(color[x, y]) == c else c for x, y in path]
            for x, y in path:
                old = int(color[x, y])
                by_color[x, old] = -1
                by_color[y, old] = -1
                color[x, y] = color[y, x] = -1
            for (x, y), col in zip(path, flipped):
                set_color(x, y, col)
        # after the inversion d is free on u; rotate the shortest fan
        # prefix that (a) is still a fan under the post-inversion coloring
        # and (b) ends at a vertex with d free, then color its edge d
        w_idx = None
        for i, w in enumerate(fan):
            if i > 0:
                col = int(color[u, fan[i]])
                if col < 0 or by_color[fan[i - 1], col] >= 0:
                    break  # inversion broke the fan beyond this prefix
            if by_color[w, d] < 0:
                w_idx = i
                break
        assert w_idx is not None, "Misra-Gries lemma violated"
        # rotate: shift each fan edge's color down one position. Snapshot the
        # new colors and clear the old ones first — assigning in place would
        # transiently duplicate a color at u and corrupt the by_color view.
        shifted = [int(color[u, fan[i + 1]]) for i in range(w_idx)]
        for i in range(w_idx + 1):
            old = int(color[u, fan[i]])
            if old >= 0:
                by_color[u, old] = -1
                by_color[fan[i], old] = -1
                color[u, fan[i]] = color[fan[i], u] = -1
        for i in range(w_idx):
            set_color(u, fan[i], shifted[i])
        set_color(u, fan[w_idx], d)

    matchings: list[dict[int, int]] = [dict() for _ in range(n_colors)]
    for u, v in zip(us.tolist(), vs.tolist()):
        c = int(color[u, v])
        assert 0 <= c < n_colors and u not in matchings[c] \
            and v not in matchings[c], "edge coloring invariant violated"
        matchings[c][u] = v
        matchings[c][v] = u
    return [m for m in matchings if m]


def euler_split(adj: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Split a graph into two subgraphs with per-vertex degree split as
    evenly as possible (|d1 - d2| <= 2), by walking Euler circuits and
    assigning edges alternately to the halves.

    Odd-degree vertices are handled with the standard dummy-vertex trick
    (a virtual node adjacent to every odd vertex makes all degrees even,
    and its incident edges are discarded from the split).
    """
    adj = _validate_adjacency(adj)
    n = adj.shape[0]
    odd = np.nonzero(adj.sum(axis=1) % 2 == 1)[0]
    rem = np.zeros((n + 1, n + 1), dtype=np.int64)  # unused edge capacity
    rem[:n, :n] = adj
    rem[odd, n] = 1
    rem[n, odd] = 1
    nbr_lists = [np.nonzero(rem[u])[0].tolist() for u in range(n + 1)]
    ptr = [0] * (n + 1)  # monotone: capacity only ever decreases
    deg = rem.sum(axis=1)
    halves = (np.zeros((n, n), dtype=np.int64),
              np.zeros((n, n), dtype=np.int64))
    side = 0
    for start in range(n + 1):
        while deg[start] > 0:
            # stack-based Hierholzer: popped order is one closed circuit
            stack, trail = [start], []
            while stack:
                x = stack[-1]
                lst = nbr_lists[x]
                while ptr[x] < len(lst) and rem[x, lst[ptr[x]]] == 0:
                    ptr[x] += 1
                if ptr[x] == len(lst):
                    trail.append(stack.pop())
                    continue
                y = lst[ptr[x]]
                rem[x, y] -= 1
                rem[y, x] -= 1
                deg[x] -= 1
                deg[y] -= 1
                stack.append(y)
            # assign the circuit's edges alternately to the halves; dummy
            # edges are skipped but still flip the side, which is what
            # splits the odd-degree endpoints evenly
            for a, b in zip(trail, trail[1:]):
                if a != n and b != n:
                    halves[side][a, b] = halves[side][b, a] = 1
                side ^= 1
    return halves


_EULER_CUTOFF = 12  # Misra-Gries directly below this max degree


def matchings_to_schedules(n: int, matchings: list[dict[int, int]]
                           ) -> list[np.ndarray]:
    """Each matching becomes an involution schedule (fixed points for
    uncovered nodes) — exactly one ppermute on the packed engine."""
    schedules = []
    for m in matchings:
        s = np.arange(n, dtype=np.int64)
        for u, v in m.items():
            s[u] = v
        schedules.append(s)
    return schedules


def overlay_from_adjacency(adj: np.ndarray, name: str = "converted", *,
                           euler_cutoff: int = _EULER_CUTOFF,
                           require_connected: bool = True) -> Overlay:
    """Convert an arbitrary connected simple graph into a schedule-based
    :class:`Overlay` the packed gossip engine can execute.

    The edge set decomposes into <= Delta + 1 matchings (Vizing, via
    Misra-Gries), each shipped as one involution schedule / one
    ``lax.ppermute`` per round; graphs with max degree above
    ``euler_cutoff`` are first halved recursively along Euler circuits
    (a few extra colors, near-linear time). The conversion is lossless:
    ``overlay.multigraph_adjacency()`` equals ``adj``.
    """
    adj = _validate_adjacency(adj)
    if require_connected and not spectral.is_connected(adj):
        raise ValueError("graph is disconnected; gossip cannot reach "
                         "consensus (pass require_connected=False to force)")

    def decompose(a: np.ndarray) -> list[dict[int, int]]:
        if int(a.sum()) == 0:
            return []
        if int(a.sum(axis=1).max()) <= euler_cutoff:
            return misra_gries_edge_coloring(a)
        left, right = euler_split(a)
        return decompose(left) + decompose(right)

    matchings = decompose(adj)
    schedules = matchings_to_schedules(adj.shape[0], matchings)
    if not schedules:
        raise ValueError("graph has no edges")
    return Overlay(n=adj.shape[0], schedules=schedules, name=name)
