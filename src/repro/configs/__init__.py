"""Config system: schema (`base`) + the assigned-architecture registry."""
from repro.configs import base, registry  # noqa: F401
from repro.configs.registry import ARCHS, ARCH_IDS, get, parallel_for, reduced, shapes_for  # noqa: F401
