"""Config schema: model architecture, input shapes, parallelism, DFL settings.

Everything is a frozen dataclass so configs are hashable and can be closed
over by jitted functions / used as static args.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = [
    "MoEConfig",
    "SSMConfig",
    "RWKVConfig",
    "ModelConfig",
    "ShapeConfig",
    "ParallelConfig",
    "DFLConfig",
    "LM_SHAPES",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    n_shared_experts: int = 0      # always-on experts (kimi-style)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block settings."""

    d_state: int = 64
    head_dim: int = 64
    expand: int = 2                # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length
    attn_every: int = 6            # zamba2: shared attention after every k blocks
    n_groups: int = 1              # B/C groups


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 "Finch" settings."""

    head_dim: int = 64
    decay_lora: int = 64           # rank of the data-dependent decay LoRA
    mix_lora: int = 32             # rank of the token-shift mix LoRA
    # chunked WKV evaluation length; kept short so |LOG_W_MIN|*chunk stays
    # inside the f32 exp range (see models/rwkv.py numerical-safety note)
    chunk: int = 16


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["transformer", "rwkv", "zamba", "mlp", "lstm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None            # default: d_model // n_heads
    act: Literal["silu", "gelu"] = "silu"
    norm: Literal["rms", "layer"] = "rms"
    qkv_bias: bool = False
    pos_emb: Literal["rope", "sinusoidal", "none"] = "rope"
    rope_theta: float = 10_000.0
    attn_softcap: float | None = None      # gemma2: 50.0
    final_softcap: float | None = None     # gemma2: 30.0
    local_window: int | None = None        # gemma2: 4096
    layer_pattern: Literal["global", "local_global"] = "global"
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    rwkv: RWKVConfig | None = None
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    stub_prefix: int = 0                   # precomputed frontend embeddings prepended
    post_norm: bool = False                # gemma2: post-attn/post-ffn norms
    scale_embeddings: bool = False         # gemma2: x *= sqrt(d_model)
    norm_plus_one: bool = False            # gemma2: rmsnorm scale = (1 + w)
    dtype: str = "bfloat16"
    attn_q_chunk: int = 1024               # query-chunked prefill attention
    ce_chunk: int = 512                    # seq chunk for the fused CE loss
    # set True only for sub-quadratic families; gates the long_500k shape
    supports_500k: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 so the TP axis always divides it
        (standard practice; pad logits are masked to -inf in the loss/decoder)."""
        return (self.vocab + 127) // 128 * 128

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), used for rooflines."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d  # output head
        hd = self.resolved_head_dim
        qd, kvd = self.n_heads * hd, self.n_kv_heads * hd
        if self.family == "rwkv":
            assert self.rwkv is not None
            r = self.rwkv
            per = (5 * d * d                    # r,k,v,g,o (time-mix projections)
                   + 2 * d * r.decay_lora       # decay LoRA
                   + 5 * 2 * d * r.mix_lora     # per-projection mix LoRAs
                   + d * self.d_ff + self.d_ff * d  # channel mix
                   + 2 * d)                     # norms
            return total + self.n_layers * per
        if self.family == "zamba":
            assert self.ssm is not None
            s = self.ssm
            d_in = s.expand * d
            per_mamba = (d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
                         + d_in * s.conv_width + d_in * d + 2 * d + d_in)
            n_attn = self.n_layers // s.attn_every
            shared = (d * (qd + 2 * kvd) + qd * d + 3 * d * self.d_ff + 2 * d)
            return total + self.n_layers * per_mamba + shared  # shared counted once
        # transformer
        attn = d * (qd + 2 * kvd) + qd * d
        if self.qkv_bias:
            attn += qd + 2 * kvd
        if self.moe is not None:
            m = self.moe
            ffn = (m.n_experts + m.n_shared_experts) * 3 * d * m.d_ff + d * m.n_experts
        else:
            ffn = 3 * d * self.d_ff
        per = attn + ffn + 2 * d
        return total + self.n_layers * per

    def active_param_count(self) -> int:
        """Active params per token (= param_count for dense; routed subset for MoE)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        dense_ffn_per_layer = (m.top_k + m.n_shared_experts) * 3 * d * m.d_ff + d * m.n_experts
        full_ffn_per_layer = (m.n_experts + m.n_shared_experts) * 3 * d * m.d_ff + d * m.n_experts
        return self.param_count() - self.n_layers * (full_ffn_per_layer - dense_ffn_per_layer)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


# the assigned LM shape set (identical across the 10 archs)
LM_SHAPES: tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How one (arch x mesh) cell factorizes the device grid.

    The production mesh is fixed at (16,16)=(data,model) or (2,16,16)=
    (pod,data,model); `clients_per_pod` coarsens the DFL client axis by
    regrouping data rows into (client, fsdp): data=16 -> client=clients_per_pod,
    fsdp=16/clients_per_pod. fsdp does ZeRO sharding of each client's
    params/momentum AND data-parallelism of the client's local batch.
    """

    clients_per_pod: int = 16
    remat: Literal["none", "block"] = "block"
    attn_mode: Literal["heads", "sequence"] = "heads"  # TP choice for attention
    # gossip executor: "ppermute_packed" (default: flat-buffer payloads, d
    # collectives/round + fused Pallas reduction), "ppermute_packed_quant"
    # (packed + int8 wire payloads, per-row-block scales riding in the wire
    # buffer), "ppermute_packed_async" (pipelined: with gossip_delay=1 the d
    # permutes ship the *previous* round's snapshot, so they depend only on
    # step inputs and overlap with the local-step scan), per-leaf
    # "ppermute"/"ppermute_quant" baselines, or the paper-naive "dense"
    # mixing einsum
    gossip_impl: Literal["dense", "ppermute", "ppermute_quant",
                         "ppermute_packed", "ppermute_packed_quant",
                         "ppermute_packed_async"] = "ppermute_packed"
    # pipelined-gossip delay (only meaningful with "ppermute_packed_async"):
    # 0 = synchronous semantics, bit-identical to "ppermute_packed"
    # (regression-pinned); 1 = one-round-delayed mixing — round t mixes the
    # in-flight snapshot of round t-1's post-local-step params, so the wire
    # transfer hides behind a full local-step scan
    gossip_delay: int = 0
    # Chebyshev multi-round gossip (repro.core.engine sub_rounds axis):
    # k >= 2 runs k gossip sub-rounds per round with Chebyshev polynomial
    # weights over the mixing matrix (second-order recurrence; coefficients
    # from the overlay's lambda via spectral.chebyshev_omegas, shipped as
    # one more donated traced operand — zero retraces). k*d collectives per
    # round; 1 = the sync engine, byte-identical HLO. Packed substrates
    # only; does not compose with gossip_delay=1, screens, or stateful
    # codecs (engine-config validation rejects those cells).
    gossip_sub_rounds: int = 1
    # wire codec override (repro.core.engine): "auto" keeps the impl
    # alias's historical codec (f32 for the plain impls, int8_block for the
    # quant impls); any codec in the engine registry (engine.CODECS) names
    # one explicitly — built-ins: "f32" / "int8" (per-buffer scale) /
    # "int8_block" (one scale per kernel row-block tile) / "topk_ef"
    # (sparse top-k with error feedback: values + lane-folded indices wire,
    # per-client EF-residual codec state threaded as a donated step
    # operand). Pipelined + quantized gossip = "ppermute_packed_async" +
    # gossip_delay=1 + gossip_codec="int8_block" (the delayed snapshot is
    # then carried AND shipped in the int8 wire format: d int8
    # collectives/round, 4x smaller donated state); with "topk_ef" the
    # carried snapshot is the ~k-fold smaller sparse wire.
    gossip_codec: str = "auto"
    # Byzantine screen over received payloads (repro.core.engine): "none"
    # trusts every wire; "norm_clip" rescales any received buffer whose norm
    # exceeds gossip_clip_tau x the receiver's own norm; "trimmed_mean"
    # drops the gossip_trim_f largest/smallest live values per coordinate
    # and renormalizes over the survivors. Screens compose with every codec
    # x timing cell through config alone — still d collectives/round.
    gossip_screen: Literal["none", "norm_clip", "trimmed_mean"] = "none"
    gossip_clip_tau: float = 3.0
    gossip_trim_f: int = 1
    # in-graph round telemetry (repro.telemetry): False keeps the step HLO
    # textually identical to an untelemetered build; True makes the step's
    # metrics dict carry a "telemetry" subtree of traced round metrics
    # (consensus residual, live in-degree, per-schedule contributor mass,
    # norm-clip counts, wire bytes — zero extra collectives, zero retraces).
    # Packed (shard_map) impls only — the per-leaf / dense baselines reject
    # it at config parse.
    gossip_telemetry: bool = False
    local_steps: int = 2          # K inside the lowered round (scan)
    use_fused_sgdm: bool = True
    grad_accum: int = 4           # microbatches per local step (memory knob)
    zero3: bool = True            # shard weights over fsdp (ZeRO-3) vs replicate
    seq_parallel: bool = False    # Megatron-SP residual sharding over TP axis
    tp: int | None = None         # TP width (None = full model axis = 16)


@dataclasses.dataclass(frozen=True)
class DFLConfig:
    """Overlay settings for the DFL round."""

    # any family registered in repro.overlay.registry: "expander", "ring",
    # "complete", "torus", "hypercube", "random_regular", "onepeer_exp",
    # "erdos_renyi", ...
    topology: str = "expander"
    degree: int = 4
    seed: int = 0
    lr: float = 0.01
    momentum: float = 0.9
    # time-varying round plan (repro.overlay.plan): per-schedule gate vector
    # shipped into the jitted step as donated data — "static", "one_peer",
    # "random_subset" (plan_k schedules/round), "throttle" (plan_fraction of
    # the pool/round). Any plan reuses one executable: gates are data.
    round_plan: str = "static"
    plan_k: int = 1
    plan_fraction: float = 0.5
    # round-level client subsampling (repro.overlay.plan.ActiveSetPlan):
    # per-client participation vector shipped into the jitted step as
    # donated data next to alive/gates — "full" (everyone, signature
    # unchanged), "random_k" (active_k clients/round), "shards"
    # (round-robin over active_shards cohorts), "stratified" (active_k
    # spread over active_shards strata). Inactive clients keep their params
    # (identity rows) and never count as stragglers: the active set
    # multiplies the alive mask but stays invisible to HealthTracker.
    active_set: str = "full"
    active_k: int = 1
    active_shards: int = 2
    # elastic runtime (launch/elastic.py): heartbeat thresholds. A client
    # missing `straggler_rounds` heartbeats is masked out of gossip for the
    # round (alive-mask step argument — zero recompiles); one missing
    # `failure_rounds` is declared dead (splice repair + one re-jit).
    straggler_rounds: int = 1
    failure_rounds: int = 3
    # Byzantine attacker harness (repro.core.failures.AttackPlan): when
    # True the jitted step takes a (2, n) per-client attack operand + a
    # PRNG key as *data* (zero retraces under attacker churn) and applies
    # it to the post-local-step params before gossip. The all-honest
    # operand is a numerical no-op, so attack-free rounds share the trace.
    byzantine: bool = False
