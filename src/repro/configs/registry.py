"""Architecture registry: the 10 assigned archs (+ the paper's own models).

Each entry provides the FULL config (exact public hyper-parameters, exercised
only via the dry-run) and a `reduced()` smoke variant (same family/features,
tiny dims) that runs a real forward/train step on CPU.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    LM_SHAPES,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    RWKVConfig,
    ShapeConfig,
    SSMConfig,
)

__all__ = ["ARCHS", "get", "reduced", "shapes_for", "parallel_for", "ARCH_IDS"]


ARCHS: dict[str, ModelConfig] = {
    # [dense]  hf:stabilityai/stablelm-2-12b
    "stablelm-12b": ModelConfig(
        name="stablelm-12b", family="transformer", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=13824, vocab=100352,
        act="silu", norm="layer", pos_emb="rope"),
    # [dense]  arXiv:2408.00118 — local/global alternating, logit softcaps
    "gemma2-2b": ModelConfig(
        name="gemma2-2b", family="transformer", n_layers=26, d_model=2304,
        n_heads=8, n_kv_heads=4, head_dim=256, d_ff=9216, vocab=256000,
        act="gelu", norm="rms", local_window=4096, layer_pattern="local_global",
        attn_softcap=50.0, final_softcap=30.0, tie_embeddings=True,
        post_norm=True, scale_embeddings=True, norm_plus_one=True),
    # [dense]  arXiv:2407.10671 — GQA + QKV bias
    "qwen2-72b": ModelConfig(
        name="qwen2-72b", family="transformer", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=29568, vocab=152064, qkv_bias=True),
    # [dense]  hf:Qwen/Qwen2.5-3B — GQA + QKV bias
    "qwen2.5-3b": ModelConfig(
        name="qwen2.5-3b", family="transformer", n_layers=36, d_model=2048,
        n_heads=16, n_kv_heads=2, d_ff=11008, vocab=151936, qkv_bias=True),
    # [moe]  hf:xai-org/grok-1 — 8 experts top-2
    "grok-1-314b": ModelConfig(
        name="grok-1-314b", family="transformer", n_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=32768, vocab=131072,
        act="gelu",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32768)),
    # [moe]  Kimi K2 — trillion-param MoE, 384 experts top-8 (+1 shared)
    "kimi-k2-1t-a32b": ModelConfig(
        name="kimi-k2-1t-a32b", family="transformer", n_layers=61, d_model=7168,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=2048, vocab=163840,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared_experts=1,
                      capacity_factor=1.0)),
    # [audio]  arXiv:2306.05284 — decoder over EnCodec tokens, stub frontend
    "musicgen-medium": ModelConfig(
        name="musicgen-medium", family="transformer", n_layers=48, d_model=1536,
        n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048,
        act="gelu", norm="layer", pos_emb="sinusoidal",
        frontend="audio_stub", stub_prefix=64),
    # [ssm]  arXiv:2404.05892 — RWKV6 "Finch", data-dependent decay
    "rwkv6-1.6b": ModelConfig(
        name="rwkv6-1.6b", family="rwkv", n_layers=24, d_model=2048,
        n_heads=32, n_kv_heads=32, d_ff=7168, vocab=65536,
        rwkv=RWKVConfig(head_dim=64), supports_500k=True),
    # [vlm]  arXiv:2404.16821 — InternViT(stub) + InternLM2 backbone
    "internvl2-1b": ModelConfig(
        name="internvl2-1b", family="transformer", n_layers=24, d_model=896,
        n_heads=14, n_kv_heads=2, d_ff=4864, vocab=151655,
        frontend="vision_stub", stub_prefix=256),
    # [hybrid]  arXiv:2411.15242 — Mamba2 backbone + shared attention
    "zamba2-2.7b": ModelConfig(
        name="zamba2-2.7b", family="zamba", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240, vocab=32000,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, attn_every=6),
        supports_500k=True),
}

ARCH_IDS = tuple(ARCHS)


def get(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(ARCHS)}")
    return ARCHS[arch_id]


def reduced(arch_id: str) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests (2-4 layers, small dims)."""
    cfg = get(arch_id)
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=4 if cfg.family == "zamba" else 2,
        d_model=64, n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16, d_ff=128, vocab=512, attn_q_chunk=32,
    )
    if cfg.family == "zamba":
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                        attn_every=2, chunk=8)
        kw["n_kv_heads"] = 4
    if cfg.family == "rwkv":
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=16, decay_lora=8,
                                         mix_lora=4, chunk=8)
        kw["n_heads"], kw["n_kv_heads"] = 4, 4
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4,
                                        top_k=min(cfg.moe.top_k, 2), d_ff=64)
    if cfg.local_window is not None:
        kw["local_window"] = 32
    if cfg.stub_prefix:
        kw["stub_prefix"] = 8
    return dataclasses.replace(cfg, **kw)


def shapes_for(arch_id: str) -> tuple[ShapeConfig, ...]:
    """The assigned shape set, with long_500k gated on sub-quadratic support."""
    cfg = get(arch_id)
    return tuple(s for s in LM_SHAPES
                 if s.name != "long_500k" or cfg.supports_500k)


def skipped_shapes(arch_id: str) -> tuple[str, ...]:
    cfg = get(arch_id)
    return () if cfg.supports_500k else ("long_500k",)


# ---------------------------------------------------------- parallelism
# clients_per_pod coarsens the DFL client axis for models whose per-client
# state would not fit (see DESIGN.md §4). fsdp = 16 / clients_per_pod.
_PARALLEL: dict[str, ParallelConfig] = {
    "qwen2-72b": ParallelConfig(clients_per_pod=4, grad_accum=4),
    "grok-1-314b": ParallelConfig(clients_per_pod=2, grad_accum=4),
    "kimi-k2-1t-a32b": ParallelConfig(clients_per_pod=1, grad_accum=16),
    "stablelm-12b": ParallelConfig(clients_per_pod=8, grad_accum=4),
    # tp=8 measured best for the 2k-wide model (see EXPERIMENTS.md §Perf):
    # -19% collective, -28% memory vs tp=16
    "qwen2.5-3b": ParallelConfig(tp=8),
}


def parallel_for(arch_id: str) -> ParallelConfig:
    return _PARALLEL.get(arch_id, ParallelConfig())
