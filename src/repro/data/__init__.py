"""Data substrate: offline datasets + federated partitioners + batchers."""
from repro.data import federated, mnist, pipeline, shakespeare  # noqa: F401
