"""Federated partitioners: how the global dataset is split across clients.

Mirrors the paper's two regimes:
  * IID          — uniform random split (paper's "MNIST IID");
  * label-shard  — each client holds a *single* label (paper's "MNIST
                   Non-IID", "extremely unfavorable");
  * dirichlet    — standard Dirichlet(alpha) label-skew interpolation;
  * span         — contiguous overlapping text spans (paper's Shakespeare).
"""
from __future__ import annotations

import numpy as np


def iid_split(n_samples: int, n_clients: int, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def label_shard_split(labels: np.ndarray, n_clients: int, seed: int = 0
                      ) -> list[np.ndarray]:
    """Client i gets only label (i mod n_classes) — the paper's non-IID MNIST."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    out: list[np.ndarray] = []
    per_class = {int(c): rng.permutation(np.nonzero(labels == c)[0]) for c in classes}
    counters = {int(c): 0 for c in classes}
    owners = [int(classes[i % len(classes)]) for i in range(n_clients)]
    n_owners = {c: max(1, owners.count(c)) for c in set(owners)}
    for i in range(n_clients):
        c = owners[i]
        pool = per_class[c]
        share = len(pool) // n_owners[c]
        k = counters[c]
        out.append(np.sort(pool[k * share:(k + 1) * share]))
        counters[c] += 1
    return out


def dirichlet_split(labels: np.ndarray, n_clients: int, alpha: float = 0.5,
                    seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    idx_by_class = [rng.permutation(np.nonzero(labels == c)[0]) for c in classes]
    client_idx: list[list[int]] = [[] for _ in range(n_clients)]
    for idx_c in idx_by_class:
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx_c, cuts)):
            client_idx[i].extend(part.tolist())
    return [np.sort(np.asarray(ix, dtype=np.int64)) for ix in client_idx]


def span_split(n_tokens: int, n_clients: int, overlap: float = 0.2,
               seed: int = 0) -> list[tuple[int, int]]:
    """Contiguous overlapping token spans (paper's Shakespeare protocol)."""
    span = int(n_tokens / (n_clients * (1 - overlap) + overlap))
    stride = int(span * (1 - overlap))
    out = []
    for i in range(n_clients):
        start = min(i * stride, max(n_tokens - span, 0))
        out.append((start, min(start + span, n_tokens)))
    return out
