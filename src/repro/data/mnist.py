"""Synthetic MNIST-like dataset (offline container: no downloads).

Deterministic class-conditional generator: each digit class c has a fixed
random prototype image; samples are prototype + noise, re-normalized. The
task is linearly separable enough for the paper's MLP-200 to reach high
accuracy, while remaining non-trivial — what matters for the reproduction is
the *relative* behaviour of the overlay topologies, which depends on the
optimization/gossip dynamics, not on the pixel distribution.
"""
from __future__ import annotations

import dataclasses

import numpy as np

N_CLASSES = 10
DIM = 784


@dataclasses.dataclass(frozen=True)
class Dataset:
    x: np.ndarray  # (N, 784) float32 in [0, 1]-ish
    y: np.ndarray  # (N,) int32


def make_mnist_like(n_train: int = 10_000, n_test: int = 2_000, seed: int = 0,
                    noise: float = 0.9) -> tuple[Dataset, Dataset]:
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, size=(N_CLASSES, DIM)).astype(np.float32)

    def sample(n, salt):
        r = np.random.default_rng(seed * 1000 + salt)
        y = r.integers(0, N_CLASSES, size=n).astype(np.int32)
        x = protos[y] + noise * r.normal(0, 1, size=(n, DIM)).astype(np.float32)
        x = (x - x.mean(axis=1, keepdims=True)) / (x.std(axis=1, keepdims=True) + 1e-6)
        return Dataset(x=x.astype(np.float32), y=y)

    return sample(n_train, 1), sample(n_test, 2)
