"""Character-level Shakespeare corpus (bundled snippet; offline container).

The paper splits Shakespeare into 100 overlapping subsets with per-user
distribution shift (non-IID). We bundle a few scenes' worth of text and
replicate that protocol: each client gets a contiguous (overlapping) span, so
client vocab/style distributions differ.
"""
from __future__ import annotations

import numpy as np

_TEXT = """
To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;
All the world's a stage,
And all the men and women merely players:
They have their exits and their entrances;
And one man in his time plays many parts,
His acts being seven ages. At first the infant,
Mewling and puking in the nurse's arms.
And then the whining school-boy, with his satchel
And shining morning face, creeping like snail
Unwillingly to school. And then the lover,
Sighing like furnace, with a woeful ballad
Made to his mistress' eyebrow. Then a soldier,
Full of strange oaths and bearded like the pard,
Jealous in honour, sudden and quick in quarrel,
Seeking the bubble reputation
Even in the cannon's mouth. And then the justice,
In fair round belly with good capon lined,
With eyes severe and beard of formal cut,
Full of wise saws and modern instances;
And so he plays his part. The sixth age shifts
Into the lean and slipper'd pantaloon,
With spectacles on nose and pouch on side,
His youthful hose, well saved, a world too wide
For his shrunk shank; and his big manly voice,
Turning again toward childish treble, pipes
And whistles in his sound. Last scene of all,
That ends this strange eventful history,
Is second childishness and mere oblivion,
Sans teeth, sans eyes, sans taste, sans everything.
Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones;
So let it be with Caesar. The noble Brutus
Hath told you Caesar was ambitious:
If it were so, it was a grievous fault,
And grievously hath Caesar answer'd it.
Here, under leave of Brutus and the rest--
For Brutus is an honourable man;
So are they all, all honourable men--
Come I to speak in Caesar's funeral.
He was my friend, faithful and just to me:
But Brutus says he was ambitious;
And Brutus is an honourable man.
O Romeo, Romeo! wherefore art thou Romeo?
Deny thy father and refuse thy name;
Or, if thou wilt not, be but sworn my love,
And I'll no longer be a Capulet.
'Tis but thy name that is my enemy;
Thou art thyself, though not a Montague.
What's Montague? it is nor hand, nor foot,
Nor arm, nor face, nor any other part
Belonging to a man. O, be some other name!
What's in a name? that which we call a rose
By any other name would smell as sweet.
"""


def corpus(repeat: int = 50) -> tuple[np.ndarray, dict[str, int]]:
    """Returns (token array int32, char vocab). Repeats the snippet to give
    enough tokens for hundreds of rounds of local training."""
    text = (_TEXT * repeat)
    chars = sorted(set(text))
    vocab = {c: i for i, c in enumerate(chars)}
    toks = np.asarray([vocab[c] for c in text], dtype=np.int32)
    return toks, vocab


def vocab_size() -> int:
    return len(sorted(set(_TEXT)))
