"""Batching pipelines: per-client local-step batches for DFL rounds.

`ClientBatcher` yields, per round, a pytree whose leaves are
(n_clients, local_steps, batch, ...) — exactly what the vmapped/shard_mapped
DFedAvgM round consumes. Deterministic per (client, round): restart-safe
(the checkpoint only needs the round counter).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

PyTree = Any


@dataclasses.dataclass
class ClientBatcher:
    """Classification data (x, y) split by client index lists."""

    x: np.ndarray
    y: np.ndarray
    client_indices: list[np.ndarray]
    batch_size: int
    local_steps: int
    seed: int = 0

    @property
    def n_clients(self) -> int:
        return len(self.client_indices)

    def round_batches(self, rnd: int) -> dict[str, np.ndarray]:
        xs, ys = [], []
        for c, idx in enumerate(self.client_indices):
            rng = np.random.default_rng((self.seed, c, rnd))
            take = rng.choice(idx, size=(self.local_steps, self.batch_size),
                              replace=len(idx) < self.local_steps * self.batch_size)
            xs.append(self.x[take])
            ys.append(self.y[take])
        return {"x": np.stack(xs), "y": np.stack(ys)}


@dataclasses.dataclass
class TokenBatcher:
    """LM data: contiguous next-token windows from per-client token spans."""

    tokens: np.ndarray                 # (n_tokens,) int32
    spans: list[tuple[int, int]]       # per-client [start, end)
    batch_size: int
    seq_len: int
    local_steps: int
    seed: int = 0

    @property
    def n_clients(self) -> int:
        return len(self.spans)

    def round_batches(self, rnd: int) -> dict[str, np.ndarray]:
        toks, labs = [], []
        for c, (lo, hi) in enumerate(self.spans):
            rng = np.random.default_rng((self.seed, c, rnd))
            max_start = hi - self.seq_len - 1
            starts = rng.integers(lo, max(max_start, lo + 1),
                                  size=(self.local_steps, self.batch_size))
            window = starts[..., None] + np.arange(self.seq_len + 1)
            window = np.minimum(window, len(self.tokens) - 1)
            chunk = self.tokens[window]
            toks.append(chunk[..., :-1])
            labs.append(chunk[..., 1:])
        return {"tokens": np.stack(toks).astype(np.int32),
                "labels": np.stack(labs).astype(np.int32)}


def synthetic_token_batches(n_clients: int, local_steps: int, batch: int,
                            seq: int, vocab: int, rnd: int, seed: int = 0
                            ) -> dict[str, np.ndarray]:
    """Deterministic synthetic LM batches (markov-ish: labels = shifted mix)."""
    rng = np.random.default_rng((seed, rnd))
    toks = rng.integers(0, vocab, size=(n_clients, local_steps, batch, seq))
    labels = np.roll(toks, -1, axis=-1)
    return {"tokens": toks.astype(np.int32), "labels": labels.astype(np.int32)}
