"""Zamba2 hybrid: Mamba2 backbone + a single *shared* attention block applied
after every `attn_every` mamba blocks (arXiv:2411.15242).

The n_layers mamba blocks are grouped into G = n_layers/attn_every
super-blocks; the outer lax.scan runs over super-blocks (shared-attention
weights are closed over, so the compiled graph reuses them — exactly the
weight-sharing the paper exploits), the inner scan over the mamba blocks of
the group.

Deviations noted in DESIGN.md: the real Zamba2 feeds concat(hidden, embeds)
into the shared block and adds per-application LoRAs; we apply the shared
block to the hidden state directly (same compute/communication shape).

Decode state: per-layer mamba {ssd, conv} states + a KV cache per
shared-block *application* (G, B, Smax, KV, hd) — weights are shared, caches
are not.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import ssm
from repro.models.params import Leaf
from repro.models.sharding_ctx import annotate

F32 = jnp.float32
PyTree = Any


def n_groups(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.ssm.attn_every == 0
    return cfg.n_layers // cfg.ssm.attn_every


# ----------------------------------------------------------------- params
def param_struct(cfg: ModelConfig) -> PyTree:
    assert cfg.ssm is not None
    d, v, nl = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.dtype
    shared = {
        "ln1": Leaf((d,), ("embed",), dt, "ones"),
        "wq": Leaf((d, h, hd), ("embed", "heads", None), dt),
        "wk": Leaf((d, kv, hd), ("embed", "kv_heads", None), dt),
        "wv": Leaf((d, kv, hd), ("embed", "kv_heads", None), dt),
        "wo": Leaf((h, hd, d), ("heads", None, "embed"), dt),
        "ln2": Leaf((d,), ("embed",), dt, "ones"),
        "w_gate": Leaf((d, cfg.d_ff), ("embed", "ffn"), dt),
        "w_up": Leaf((d, cfg.d_ff), ("embed", "ffn"), dt),
        "w_down": Leaf((cfg.d_ff, d), ("ffn", "embed"), dt),
    }
    return {
        "embed": Leaf((v, d), ("vocab_in", "embed"), dt, scale=0.02),
        "head": Leaf((d, v), ("embed", "vocab"), dt),
        "final_norm": Leaf((d,), ("embed",), dt, "ones"),
        "mamba": ssm.block_struct(nl, d, cfg.ssm, dt),
        "shared_attn": shared,
    }


def state_struct(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    s = cfg.ssm
    d_in, h_ssm = ssm.dims(s, cfg.d_model)
    g = n_groups(cfg)
    hd = cfg.resolved_head_dim
    return {
        "ssd": Leaf((cfg.n_layers, batch, h_ssm, s.head_dim, s.d_state),
                    ("layers", "act_batch", "heads", None, None), "float32", "zeros"),
        "conv": Leaf((cfg.n_layers, batch, s.conv_width - 1, d_in),
                     ("layers", "act_batch", None, "ffn"), cfg.dtype, "zeros"),
        "k": Leaf((g, batch, max_seq, cfg.n_kv_heads, hd),
                  ("layers", "act_batch", "act_seq", "kv_heads", None),
                  cfg.dtype, "zeros"),
        "v": Leaf((g, batch, max_seq, cfg.n_kv_heads, hd),
                  ("layers", "act_batch", "act_seq", "kv_heads", None),
                  cfg.dtype, "zeros"),
    }


# ---------------------------------------------------------------- shared
def _shared_attn_full(x, p, positions, cfg: ModelConfig, return_kv=False):
    h = L.rms_norm(x, p["ln1"])
    q = jnp.einsum("bsd,dkh->bskh", h, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("bsd,dkh->bskh", h, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,dkh->bskh", h, p["wv"],
                   preferred_element_type=F32).astype(x.dtype)
    q = L.rope(q.astype(x.dtype), positions, cfg.rope_theta)
    k = L.rope(k.astype(x.dtype), positions, cfg.rope_theta)
    attn = L.chunked_causal_attention(q, k, v, q_chunk=cfg.attn_q_chunk)
    attn = jnp.einsum("bskh,khd->bsd", attn, p["wo"],
                      preferred_element_type=F32).astype(x.dtype)
    x = annotate(x + attn, "residual")
    h2 = L.rms_norm(x, p["ln2"])
    ff = L.glu_mlp(h2, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
    out = annotate(x + ff, "residual")
    if return_kv:
        return out, (k, v)
    return out


def _shared_attn_decode(x, p, k_cache, v_cache, pos, cfg: ModelConfig):
    h = L.rms_norm(x, p["ln1"])
    q = jnp.einsum("bsd,dkh->bskh", h, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("bsd,dkh->bskh", h, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,dkh->bskh", h, p["wv"],
                   preferred_element_type=F32).astype(x.dtype)
    q = L.rope(q.astype(x.dtype), pos[None], cfg.rope_theta)
    k = L.rope(k.astype(x.dtype), pos[None], cfg.rope_theta)
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype),
                                              pos, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype),
                                              pos, axis=1)
    attn = L.decode_attention(q, k_cache, v_cache, pos)
    attn = jnp.einsum("bskh,khd->bsd", attn, p["wo"],
                      preferred_element_type=F32).astype(x.dtype)
    x = x + attn
    h2 = L.rms_norm(x, p["ln2"])
    ff = L.glu_mlp(h2, p["w_gate"], p["w_up"], p["w_down"], cfg.act)
    return x + ff, k_cache, v_cache


# ------------------------------------------------------------------- api
def _group_params(cfg: ModelConfig, mamba_params):
    g = n_groups(cfg)
    return jax.tree.map(lambda a: a.reshape((g, cfg.ssm.attn_every) + a.shape[1:]),
                        mamba_params)


def _hidden(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            remat: bool = False) -> jax.Array:
    x = L.embed_lookup(params["embed"], tokens)
    x = annotate(x, "activation")
    positions = jnp.arange(x.shape[1])
    grouped = _group_params(cfg, params["mamba"])
    shared = params["shared_attn"]

    def inner(h, p):
        h, _ = ssm.mamba_block(h, p, None, cfg.ssm)
        return h, None

    def outer(h, pg):
        h, _ = lax.scan(inner, h, pg)
        h = _shared_attn_full(h, shared, positions, cfg)
        return h, None

    if remat:
        outer = jax.checkpoint(outer)
    x, _ = lax.scan(outer, x, grouped)
    return L.rms_norm(x, params["final_norm"])


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds=None, remat: bool = False) -> jax.Array:
    del prefix_embeds
    x = _hidden(params, tokens, cfg, remat=remat)
    logits = L.lm_logits(x, params["head"], valid_vocab=cfg.vocab)
    return annotate(logits, "logits")


def loss_fn(params: PyTree, batch: dict, cfg: ModelConfig,
            remat: bool = False) -> tuple[jax.Array, dict]:
    x = _hidden(params, batch["tokens"], cfg, remat=remat)
    loss = L.lm_loss_chunked(x, params["head"], batch["labels"],
                             valid_vocab=cfg.vocab, chunk=cfg.ce_chunk)
    return loss, {"loss": loss}


def prefill(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds=None) -> tuple[jax.Array, PyTree]:
    del prefix_embeds
    x = L.embed_lookup(params["embed"], tokens)
    positions = jnp.arange(x.shape[1])
    grouped = _group_params(cfg, params["mamba"])
    shared = params["shared_attn"]
    b = x.shape[0]
    d_in, h_ssm = ssm.dims(cfg.ssm, cfg.d_model)
    init_inner = {
        "ssd": jnp.zeros((b, h_ssm, cfg.ssm.head_dim, cfg.ssm.d_state), F32),
        "conv": jnp.zeros((b, cfg.ssm.conv_width - 1, d_in), jnp.dtype(cfg.dtype)),
    }

    def inner(h, p):
        h, st = ssm.mamba_block(h, p, init_inner, cfg.ssm)
        return h, st

    def outer(h, pg):
        h, sts = lax.scan(inner, h, pg)
        h, (k, v) = _shared_attn_full(h, shared, positions, cfg, return_kv=True)
        return h, (sts, k, v)

    x, (mamba_states, ck, cv) = lax.scan(outer, x, grouped)
    x = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(x[:, -1:], params["head"], valid_vocab=cfg.vocab)[:, 0]
    flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), mamba_states)
    return logits, {"ssd": flat["ssd"], "conv": flat["conv"], "k": ck, "v": cv}


def decode_step(params: PyTree, state: PyTree, tokens: jax.Array,
                pos: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, PyTree]:
    x = L.embed_lookup(params["embed"], tokens[:, None])
    grouped = _group_params(cfg, params["mamba"])
    shared = params["shared_attn"]
    g = n_groups(cfg)
    k_e = cfg.ssm.attn_every
    gstate = {
        "ssd": state["ssd"].reshape((g, k_e) + state["ssd"].shape[1:]),
        "conv": state["conv"].reshape((g, k_e) + state["conv"].shape[1:]),
    }

    def inner(h, xs):
        p, st = xs
        h, st2 = ssm.mamba_block(h, p, st, cfg.ssm, decode=True)
        return h, st2

    def outer(h, xs):
        pg, stg, k_c, v_c = xs
        h, sts = lax.scan(inner, h, (pg, stg))
        h, k_c, v_c = _shared_attn_decode(h, shared, k_c, v_c, pos, cfg)
        return h, (sts, k_c, v_c)

    x, (msts, ck, cv) = lax.scan(outer, x, (grouped, gstate, state["k"], state["v"]))
    x = L.rms_norm(x, params["final_norm"])
    logits = L.lm_logits(x, params["head"], valid_vocab=cfg.vocab)[:, 0]
    flat = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), msts)
    return logits, {"ssd": flat["ssd"], "conv": flat["conv"], "k": ck, "v": cv}
