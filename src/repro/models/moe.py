"""Top-k routed mixture-of-experts with sort-based dispatch (expert parallel).

Dispatch is the sort/capacity scheme (MegaBlocks/Switch-style, dropless up to
the capacity factor): tokens are routed to (expert, slot) buffers via a sort
by expert id, experts run as one batched einsum over the expert-sharded
buffer (E on the "model"/EP mesh axis — XLA inserts the all-to-all), and
results are combined with the router weights. Tokens beyond capacity are
dropped (their combine weight is 0), matching capacity-factor semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import act_fn
from repro.models.sharding_ctx import annotate

F32 = jnp.float32


def capacity(n_tokens: int, cfg: MoEConfig) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(8, (cap + 7) // 8 * 8)  # pad to 8 for TPU-friendly shapes


def moe_ffn(x: jax.Array, router: jax.Array, w_gate: jax.Array, w_up: jax.Array,
            w_down: jax.Array, cfg: MoEConfig, act: str,
            shared: tuple[jax.Array, jax.Array, jax.Array] | None = None
            ) -> jax.Array:
    """x: (B, S, D); router: (D, E); expert weights: (E, D, F)/(E, F, D).

    Returns (B, S, D). `shared` holds optional always-on expert weights
    (gate/up/down of shapes (D, n_sh*F)/(D, n_sh*F)/(n_sh*F, D)).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    c = capacity(t, cfg)
    xt = x.reshape(t, d)

    # ---- routing (f32 router math)
    logits = jnp.einsum("td,de->te", xt.astype(F32), router.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                     # (t, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch to (E, C) slots
    flat_e = top_e.reshape(-1)                                  # (t*k,)
    order = jnp.argsort(flat_e)                                 # group by expert
    sorted_e = flat_e[order]
    # slot index within expert = position - start offset of that expert
    counts = jnp.bincount(sorted_e, length=e)
    starts = jnp.cumsum(counts) - counts                        # (e,)
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < c
    slot = sorted_e * c + jnp.clip(pos_in_e, 0, c - 1)          # (t*k,)
    src_token = order // k

    buf = jnp.zeros((e * c, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xt[src_token], 0))
    # constrain the dispatch buffer: E on the EP axis when divisible, C on the
    # within-client DP axis — without this, SPMD materializes the buffer
    # replicated and all-reduces it per layer (catastrophic for few-expert
    # MoEs like grok-1 where E doesn't divide the EP axis)
    buf = annotate(buf.reshape(e, c, d), "moe_buffer")

    # ---- expert computation (batched over E; EP shards E on "model")
    # explicit resharding point: ZeRO-3 gathers the bf16 weights here rather
    # than letting XLA gather a f32-converted copy (2x the fsdp traffic)
    w_gate = annotate(w_gate, "expert_weights")
    w_up = annotate(w_up, "expert_weights")
    w_down = annotate(w_down, "expert_weights_t")
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = (act_fn(act, g.astype(F32)) * u.astype(F32)).astype(x.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)
    out_buf = annotate(out_buf, "moe_buffer")

    # ---- combine back to tokens with router weights (model dtype: the f32
    # variant made the whole backward dispatch path f32 => 2x wire bytes)
    gathered = out_buf.reshape(e * c, d)[slot]                  # (t*k, d)
    w = (top_w.reshape(-1)[order] * keep).astype(F32)
    contrib = (gathered.astype(F32) * w[:, None]).astype(x.dtype)
    yt = jnp.zeros((t, d), x.dtype).at[src_token].add(contrib)

    if shared is not None:
        sg, su, sd_ = shared
        g2 = jnp.einsum("td,df->tf", xt, sg)
        u2 = jnp.einsum("td,df->tf", xt, su)
        h2 = (act_fn(act, g2.astype(F32)) * u2.astype(F32)).astype(x.dtype)
        yt = yt + jnp.einsum("tf,fd->td", h2, sd_)

    return yt.reshape(b, s, d)


def moe_aux_loss(x: jax.Array, router: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(F32), router.astype(F32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.n_experts, dtype=F32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.n_experts * jnp.sum(frac * imp)
