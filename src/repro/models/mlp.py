"""The paper's MNIST model: MLP with one hidden layer of 200 units (§5)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import Leaf

F32 = jnp.float32
PyTree = Any


def param_struct(n_in: int = 784, n_hidden: int = 200, n_out: int = 10,
                 dtype: str = "float32") -> PyTree:
    return {
        "w1": Leaf((n_in, n_hidden), (None, None), dtype),
        "b1": Leaf((n_hidden,), (None,), dtype, "zeros"),
        "w2": Leaf((n_hidden, n_out), (None, None), dtype),
        "b2": Leaf((n_out,), (None,), dtype, "zeros"),
    }


def forward(params: PyTree, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def loss_fn(params: PyTree, batch: dict) -> tuple[jax.Array, dict]:
    logits = forward(params, batch["x"])
    labels = batch["y"]
    logp = jax.nn.log_softmax(logits.astype(F32))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(F32))
    return nll, {"loss": nll, "acc": acc}
