"""Parameter-structure utilities: one declaration drives init, dry-run shapes,
and sharding specs.

A model declares its parameters as a pytree of :class:`Leaf` descriptors
(shape + *logical axes* + init). From that single structure we derive:

* `init_params`     — materialized arrays (smoke tests / real training),
* `shape_structs`   — `jax.ShapeDtypeStruct`s (dry-run: no allocation),
* `partition_specs` — `PartitionSpec`s under a logical->mesh-axis rule set,
  with automatic divisibility fallback (a logical axis maps to a mesh axis
  only if the dim is divisible by the mesh axis size — otherwise replicated).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

__all__ = ["Leaf", "init_params", "shape_structs", "partition_specs", "count_params"]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Leaf:
    """One parameter tensor: shape, logical axes (len == ndim), init spec."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: str = "bfloat16"
    init: str = "normal"     # normal | zeros | ones
    scale: float | None = None  # stddev for normal; default fan-in

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x) -> bool:
    return isinstance(x, Leaf)


def _fan_in_scale(leaf: Leaf) -> float:
    if leaf.scale is not None:
        return leaf.scale
    fan_in = leaf.shape[0] if len(leaf.shape) >= 2 else max(leaf.shape[-1], 1)
    # for 3D projections (embed, heads, hd) fan-in is the first dim
    return 1.0 / np.sqrt(max(fan_in, 1))


def init_params(struct: PyTree, rng: jax.Array) -> PyTree:
    """Materialize arrays; rng folded per-leaf by path hash (deterministic)."""
    # jax.tree_util spelling: jax.tree.leaves_with_path is absent in this jax
    paths = jax.tree_util.tree_leaves_with_path(struct, is_leaf=_is_leaf)

    leaves = []
    for path, leaf in paths:
        key = jax.random.fold_in(rng, hash(jax.tree_util.keystr(path)) % (2**31))
        dt = jnp.dtype(leaf.dtype)
        if leaf.init == "zeros":
            arr = jnp.zeros(leaf.shape, dt)
        elif leaf.init == "ones":
            arr = jnp.ones(leaf.shape, dt)
        else:
            arr = (jax.random.normal(key, leaf.shape, jnp.float32)
                   * _fan_in_scale(leaf)).astype(dt)
        leaves.append(arr)
    treedef = jax.tree.structure(struct, is_leaf=_is_leaf)
    return jax.tree.unflatten(treedef, leaves)


def shape_structs(struct: PyTree) -> PyTree:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.dtype(l.dtype)),
        struct, is_leaf=_is_leaf)


def _mesh_axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def partition_specs(struct: PyTree, rules: dict[str, Any], mesh) -> PyTree:
    """Logical axes -> PartitionSpec with divisibility fallback.

    rules: {"logical_name": candidate | [candidates...]} where a candidate is
    a mesh axis name, a tuple of names (sharded jointly), or None. For a list,
    the first candidate that (a) divides the dim and (b) doesn't reuse an
    axis already taken in this spec wins — e.g. "experts": ["model", "fsdp"]
    puts 384 kimi experts on the EP axis but falls back to fsdp for grok's 8.
    """

    def one(leaf: Leaf) -> PartitionSpec:
        used: set[str] = set()
        parts = []
        for size, logical in zip(leaf.shape, leaf.axes):
            rule = rules.get(logical) if logical is not None else None
            candidates = rule if isinstance(rule, list) else [rule]
            chosen = None
            for axis in candidates:
                if axis is None:
                    continue
                names = axis if isinstance(axis, tuple) else (axis,)
                if (not any(n in used for n in names)
                        and size % _mesh_axis_size(mesh, axis) == 0):
                    chosen = axis
                    used.update(names)
                    break
            parts.append(chosen)
        return PartitionSpec(*parts)

    return jax.tree.map(one, struct, is_leaf=_is_leaf)


def count_params(struct: PyTree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(struct, is_leaf=_is_leaf))
