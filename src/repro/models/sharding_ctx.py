"""Activation-sharding context: models call `annotate(x, role)`; the launcher
installs a rule set mapping roles -> PartitionSpecs. Outside any context the
calls are no-ops, so models stay mesh-agnostic (smoke tests, simulator).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any

import jax

_RULES: contextvars.ContextVar[dict[str, Any] | None] = contextvars.ContextVar(
    "activation_sharding_rules", default=None)


@contextlib.contextmanager
def activation_sharding(rules: dict[str, Any]):
    """rules: {"role": PartitionSpec or NamedSharding}."""
    token = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(token)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    names = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def annotate(x: jax.Array, role: str) -> jax.Array:
    """Apply the role's sharding constraint with per-dim divisibility fallback:
    axes that don't divide the corresponding dim are dropped (replicated)
    instead of erroring — so one rule serves many architectures."""
    rules = _RULES.get()
    if rules is None or role not in rules:
        return x
    spec = rules[role]
    if spec is None:
        return x
    try:
        from jax.sharding import NamedSharding, PartitionSpec
        if isinstance(spec, NamedSharding):
            mesh, pspec = spec.mesh, spec.spec
            parts = list(pspec) + [None] * (x.ndim - len(pspec))
            eff = []
            for dim, axis in zip(x.shape, parts[: x.ndim]):
                eff.append(axis if axis is None or dim % _axis_size(mesh, axis) == 0
                           else None)
            spec = NamedSharding(mesh, PartitionSpec(*eff))
        return jax.lax.with_sharding_constraint(x, spec)
    except ValueError:
        # rank mismatch (e.g. extra vmap batch dim): leave unsharded
        return x
