"""Mamba2 (SSD — state-space duality) block, chunked for TPU (arXiv:2405.21060).

Structure per block (config SSMConfig):
  in-proj -> (z gate, x, B, C, dt heads) ; short causal conv on x ;
  SSD recurrence with per-head scalar decay  h_t = exp(A dt_t) h_{t-1} +
  dt_t x_t (x) B_t ;  y_t = C_t . h_t + D x_t ;  gated rmsnorm ; out-proj.

Chunked evaluation: within a chunk the (C x C) decay-weighted quadratic runs
on the MXU; across chunks the (H, P, N) state is carried by lax.scan — O(S)
time and O(1) decode state (feeds the 500k-decode shape for zamba2).

Numerical safety: per-step log-decay A*dt is clamped to >= LOG_A_MIN so the
within-chunk cumulative stays in comfortable f32 range (decay differences are
<= 0, so exp() never overflows; the clamp bounds *cancellation* error).

Deviation from the reference CUDA impl (noted in DESIGN.md): the causal conv
is applied to x only (not the concatenated xBC), and n_groups defaults to 1.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import SSMConfig
from repro.models import layers as L
from repro.models.params import Leaf

F32 = jnp.float32
PyTree = Any

LOG_A_MIN = -8.0  # clamp per-step log decay


def dims(cfg_ssm: SSMConfig, d_model: int) -> tuple[int, int]:
    d_in = cfg_ssm.expand * d_model
    n_heads = d_in // cfg_ssm.head_dim
    return d_in, n_heads


def block_struct(nl: int, d: int, s: SSMConfig, dt: str) -> dict[str, Leaf]:
    """Stacked (nl, ...) parameter leaves for mamba2 blocks."""
    d_in, h = dims(s, d)
    g, n = s.n_groups, s.d_state
    return {
        "ln": Leaf((nl, d), ("layers", "embed"), dt, "ones"),
        "w_z": Leaf((nl, d, d_in), ("layers", "embed", "ffn"), dt),
        "w_x": Leaf((nl, d, d_in), ("layers", "embed", "ffn"), dt),
        "w_B": Leaf((nl, d, g * n), ("layers", "embed", None), dt),
        "w_C": Leaf((nl, d, g * n), ("layers", "embed", None), dt),
        "w_dt": Leaf((nl, d, h), ("layers", "embed", "heads"), dt),
        "dt_bias": Leaf((nl, h), ("layers", "heads"), dt, "zeros"),
        "conv_w": Leaf((nl, s.conv_width, d_in), ("layers", None, "ffn"), dt,
                       scale=0.2),
        "conv_b": Leaf((nl, d_in), ("layers", "ffn"), dt, "zeros"),
        "A_log": Leaf((nl, h), ("layers", "heads"), "float32", "zeros"),
        "D": Leaf((nl, h), ("layers", "heads"), "float32", "ones"),
        "norm": Leaf((nl, d_in), ("layers", "ffn"), dt, "ones"),
        "w_out": Leaf((nl, d_in, d), ("layers", "ffn", "embed"), dt),
    }


def state_struct_one(d: int, s: SSMConfig, batch: int) -> dict[str, tuple]:
    d_in, h = dims(s, d)
    return {
        "ssd": ((batch, h, s.head_dim, s.d_state), "float32"),
        "conv": ((batch, s.conv_width - 1, d_in), "bfloat16"),
    }


# ----------------------------------------------------------------- conv
def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv over seq. x: (B,S,Din); w: (W,Din); b: (Din,).

    conv_state: (B, W-1, Din) past inputs (decode) or None (train: zero pad).
    Returns (y, new_conv_state).
    """
    bsz, s, d_in = x.shape
    wlen = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((bsz, wlen - 1, d_in), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+W-1, Din)
    y = jnp.zeros((bsz, s, d_in), F32)
    for i in range(wlen):  # W is tiny (4): unrolled shifts, no conv primitive
        y = y + xp[:, i:i + s].astype(F32) * w[i].astype(F32)
    y = jax.nn.silu(y + b.astype(F32))
    new_state = xp[:, -(wlen - 1):]  # last W-1 raw inputs
    return y.astype(x.dtype), new_state


# ------------------------------------------------------------------ SSD
def ssd_chunked(xh, bmat, cmat, log_a, dt, state, chunk: int):
    """Chunkwise SSD (n_groups=1).

    xh: (B,S,H,P) head inputs; bmat/cmat: (B,S,N); log_a: (B,S,H) per-step log
    decay (<=0); dt: (B,S,H) step sizes; state: (B,H,P,N) f32.
    Returns (y (B,S,H,P), final state).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:  # zero-pad: dt=0 & log_a=0 leave the state untouched
        z3 = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        z4 = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, state = ssd_chunked(z4(xh), z3(bmat), z3(cmat), z3(log_a), z3(dt),
                               state, chunk)
        return y[:, :s], state
    nc = s // chunk
    xc = xh.reshape(b, nc, chunk, h, p).astype(F32)
    bc = bmat.reshape(b, nc, chunk, n).astype(F32)
    cc = cmat.reshape(b, nc, chunk, n).astype(F32)
    ac = log_a.reshape(b, nc, chunk, h).astype(F32)
    dc = dt.reshape(b, nc, chunk, h).astype(F32)

    cum = jnp.cumsum(ac, axis=2)        # inclusive within-chunk
    tot = cum[:, :, -1]                 # (b, nc, h)

    def body(st, xs):
        x_, b_, c_, cum_, dt_, tot_ = xs
        # inter-chunk: y_t += C_t . (exp(cum_t) * st)
        dec_q = jnp.exp(cum_)                              # (b,c,h)
        inter = jnp.einsum("bcn,bhpn,bch->bchp", c_, st, dec_q)
        # intra-chunk: att[t,s] = (C_t.B_s) exp(cum_t - cum_s) dt_s, s <= t
        scores = jnp.einsum("btn,bsn->bts", c_, b_)        # (b,c,c)
        dec = jnp.exp(cum_[:, :, None] - cum_[:, None, :])  # (b,t,s,h)
        tri = jnp.tril(jnp.ones((dec.shape[1], dec.shape[2]), bool))
        w = jnp.where(tri[None, :, :, None], scores[..., None] * dec, 0.0)
        intra = jnp.einsum("btsh,bsh,bshp->bthp", w, dt_, x_)
        y = inter + intra
        # state update
        dec_k = jnp.exp(tot_[:, None] - cum_) * dt_        # (b,c,h)
        st = (jnp.exp(tot_)[:, :, None, None] * st
              + jnp.einsum("bch,bchp,bcn->bhpn", dec_k, x_, b_))
        return st, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xc, bc, cc, cum, dc, tot))
    state, ys = lax.scan(body, state.astype(F32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y, state


def ssd_step(xh, bmat, cmat, log_a, dt, state):
    """Single-token SSD. xh: (B,H,P); bmat/cmat: (B,N); log_a/dt: (B,H)."""
    x_, b_, c_ = xh.astype(F32), bmat.astype(F32), cmat.astype(F32)
    a = jnp.exp(log_a.astype(F32))                         # (B,H)
    st = (a[..., None, None] * state
          + jnp.einsum("bh,bhp,bn->bhpn", dt.astype(F32), x_, b_))
    y = jnp.einsum("bn,bhpn->bhp", c_, st)
    return y, st


# ----------------------------------------------------------------- block
def mamba_block(x, p, state, s: SSMConfig, decode: bool = False):
    """One mamba2 block. x: (B,S,D); state: {"ssd", "conv"} or None (train).

    Returns (out, new_state).
    """
    d = x.shape[-1]
    d_in, h = dims(s, d)
    hn = L.rms_norm(x, p["ln"])
    z = jnp.einsum("bsd,de->bse", hn, p["w_z"], preferred_element_type=F32)
    xin = jnp.einsum("bsd,de->bse", hn, p["w_x"],
                     preferred_element_type=F32).astype(x.dtype)
    bmat = jnp.einsum("bsd,dn->bsn", hn, p["w_B"],
                      preferred_element_type=F32).astype(x.dtype)
    cmat = jnp.einsum("bsd,dn->bsn", hn, p["w_C"],
                      preferred_element_type=F32).astype(x.dtype)
    dt_raw = jnp.einsum("bsd,dh->bsh", hn, p["w_dt"], preferred_element_type=F32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(F32))
    a = -jnp.exp(p["A_log"].astype(F32))                   # (H,) negative
    log_a = jnp.clip(a[None, None] * dt, LOG_A_MIN, -1e-6)

    conv_state = state["conv"] if state is not None else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xh = xc.reshape(x.shape[0], x.shape[1], h, s.head_dim)

    ssd_state = (state["ssd"] if state is not None
                 else jnp.zeros((x.shape[0], h, s.head_dim, s.d_state), F32))
    if decode:
        y, ssd_state = ssd_step(xh[:, 0], bmat[:, 0], cmat[:, 0],
                                log_a[:, 0], dt[:, 0], ssd_state)
        y = y[:, None]
    else:
        y, ssd_state = ssd_chunked(xh, bmat, cmat, log_a, dt, ssd_state, s.chunk)
    y = y + p["D"].astype(F32)[None, None, :, None] * xh.astype(F32)
    y = y.reshape(x.shape[0], x.shape[1], d_in)
    # gated norm + out-proj
    y = L.rms_norm(y.astype(x.dtype), p["norm"])
    y = (y.astype(F32) * jax.nn.silu(z)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"],
                     preferred_element_type=F32).astype(x.dtype)
    return x + out, {"ssd": ssd_state, "conv": new_conv}
