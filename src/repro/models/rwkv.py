"""RWKV6 "Finch" — attention-free LM with data-dependent decay (arXiv:2404.05892).

Faithful structure, adapted for TPU:
  * time-mix block: token shift with LoRA-modulated lerp coefficients,
    r/k/v/g projections (kept head-shaped for TP), per-channel data-dependent
    decay ``w = exp(-exp(w0 + tanh(x A) B))`` and current-token bonus ``u``;
  * the WKV recurrence runs **chunkwise** (gated-linear-attention form):
    within a chunk a (C x C) per-head quadratic runs on the MXU, across
    chunks a (H, hd, hd) state is carried by `lax.scan` — O(S) time, O(1)
    state, which is what makes the 500k-decode shape feasible;
  * numerical safety: per-step log-decay is clamped to [LOG_W_MIN, 0] and the
    chunk is kept short (default 16) so every intermediate exponent is
    bounded by |LOG_W_MIN|*chunk < 88 (f32 exp range). Channels decaying
    faster than e^{LOG_W_MIN}/step are numerically dead anyway;
  * channel-mix block: token shift + squared-relu MLP.

Decode state: {wkv (L,B,H,hd,hd) f32, tm_prev (L,B,D), cm_prev (L,B,D)}.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import Leaf
from repro.models.sharding_ctx import annotate

F32 = jnp.float32
PyTree = Any

LOG_W_MIN = -4.0  # clamp per-step log decay; e^-4 ~ 0.018/step


def _dims(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.rwkv.head_dim
    return cfg.d_model // hd, hd


# ----------------------------------------------------------------- params
def param_struct(cfg: ModelConfig) -> PyTree:
    assert cfg.rwkv is not None
    d, v, nl = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    r = cfg.rwkv
    h, hd = _dims(cfg)
    dt = cfg.dtype

    blocks = {
        "ln1": Leaf((nl, d), ("layers", "embed"), dt, "ones"),
        "ln2": Leaf((nl, d), ("layers", "embed"), dt, "ones"),
        # token-shift lerp base + LoRA (5 targets: r, k, v, g, w)
        "mix_base": Leaf((nl, 5, d), ("layers", None, "embed"), dt, "zeros"),
        "mix_a": Leaf((nl, d, 5, r.mix_lora), ("layers", "embed", None, None),
                      dt, scale=0.01),
        "mix_b": Leaf((nl, 5, r.mix_lora, d), ("layers", None, None, "embed"),
                      dt, scale=0.01),
        # time-mix projections (head-shaped for TP on "heads")
        "wr": Leaf((nl, d, h, hd), ("layers", "embed", "heads", None), dt),
        "wk": Leaf((nl, d, h, hd), ("layers", "embed", "heads", None), dt),
        "wv": Leaf((nl, d, h, hd), ("layers", "embed", "heads", None), dt),
        "wg": Leaf((nl, d, h, hd), ("layers", "embed", "heads", None), dt),
        "wo": Leaf((nl, h, hd, d), ("layers", "heads", None, "embed"), dt),
        # data-dependent decay: logit = w0 + tanh(x A) B ; w = exp(-exp(logit))
        "w0": Leaf((nl, h, hd), ("layers", "heads", None), dt, "zeros"),
        "decay_a": Leaf((nl, d, r.decay_lora), ("layers", "embed", None), dt,
                        scale=0.01),
        "decay_b": Leaf((nl, r.decay_lora, h, hd), ("layers", None, "heads", None),
                        dt, scale=0.01),
        "bonus_u": Leaf((nl, h, hd), ("layers", "heads", None), dt, "zeros"),
        "ln_x": Leaf((nl, d), ("layers", "embed"), dt, "ones"),  # per-head norm scale
        # channel mix
        "cm_mix": Leaf((nl, 2, d), ("layers", None, "embed"), dt, "zeros"),
        "cm_k": Leaf((nl, d, cfg.d_ff), ("layers", "embed", "ffn"), dt),
        "cm_v": Leaf((nl, cfg.d_ff, d), ("layers", "ffn", "embed"), dt),
        "cm_r": Leaf((nl, d, d), ("layers", "embed", None), dt),
    }
    return {
        "embed": Leaf((v, d), ("vocab_in", "embed"), dt, scale=0.02),
        "head": Leaf((d, v), ("embed", "vocab"), dt),
        "final_norm": Leaf((d,), ("embed",), dt, "ones"),
        "blocks": blocks,
    }


def state_struct(cfg: ModelConfig, batch: int) -> PyTree:
    h, hd = _dims(cfg)
    nl, d = cfg.n_layers, cfg.d_model
    return {
        "wkv": Leaf((nl, batch, h, hd, hd),
                    ("layers", "act_batch", "heads", None, None), "float32", "zeros"),
        "tm_prev": Leaf((nl, batch, d), ("layers", "act_batch", "embed"),
                        cfg.dtype, "zeros"),
        "cm_prev": Leaf((nl, batch, d), ("layers", "act_batch", "embed"),
                        cfg.dtype, "zeros"),
    }


# ------------------------------------------------------------- WKV chunked
def wkv_chunked(r, k, v, logw, u, state, chunk: int):
    """Chunkwise WKV. r,k,v,logw: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) f32.

    Per-head recurrence (state S maps k-dim -> v-dim):
        out_t = r_t . S_{t-1} + (r_t . (u*k_t)) v_t
        S_t   = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (out (B,S,H,hd), final state).
    """
    b, s, h, hd = r.shape
    pad = (-s) % chunk
    if pad:  # zero-pad: k=v=0 adds nothing to state, logw=0 leaves decay alone
        zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out, state = wkv_chunked(zpad(r), zpad(k), zpad(v), zpad(logw), u,
                                 state, chunk)
        return out[:, :s], state
    nc = s // chunk
    shp = (b, nc, chunk, h, hd)
    rc = r.reshape(shp).astype(F32)
    kc = k.reshape(shp).astype(F32)
    vc = v.reshape(shp).astype(F32)
    lw = logw.reshape(shp).astype(F32)

    ci = jnp.cumsum(lw, axis=2)       # inclusive within-chunk log-decay sums
    ce = ci - lw                      # exclusive
    tot = ci[:, :, -1]                # (b, nc, h, hd)

    uu = u.astype(F32)

    def body(st, xs):
        r_, k_, v_, ci_, ce_, tot_ = xs  # (b, chunk, h, hd) / tot_ (b, h, hd)
        rd = r_ * jnp.exp(ce_)           # decayed-to-chunk-start queries
        kd = k_ * jnp.exp(-ci_)          # keys normalized to chunk start
        inter = jnp.einsum("bthk,bhkv->bthv", rd, st)
        att = jnp.einsum("bthk,bshk->btsh", rd, kd)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)  # strictly past
        att = jnp.where(tri[None, :, :, None], att, 0.0)
        intra = jnp.einsum("btsh,bshv->bthv", att, v_)
        bonus = jnp.einsum("bthk,bthk->bth", r_ * uu[None, None], k_)
        out = inter + intra + bonus[..., None] * v_
        kw = k_ * jnp.exp(tot_[:, None] - ci_)
        st = jnp.exp(tot_)[..., None] * st + jnp.einsum("bthk,bthv->bhkv", kw, v_)
        return st, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, ci, ce, tot))
    state, outs = lax.scan(body, state.astype(F32), xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return out.astype(r.dtype), state


def wkv_step(r, k, v, logw, u, state):
    """Single-token WKV. r,k,v,logw: (B,H,hd); state (B,H,hd,hd) f32."""
    r_, k_, v_ = r.astype(F32), k.astype(F32), v.astype(F32)
    out = jnp.einsum("bhk,bhkv->bhv", r_, state)
    bonus = jnp.einsum("bhk,bhk->bh", r_ * u.astype(F32)[None], k_)
    out = out + bonus[..., None] * v_
    state = jnp.exp(logw.astype(F32))[..., None] * state + k_[..., :, None] * v_[..., None, :]
    return out.astype(r.dtype), state


# ----------------------------------------------------------------- blocks
def _token_shift(x, prev):
    """shift(x)_t = x_{t-1}; position 0 uses `prev` (B, D)."""
    shifted = jnp.roll(x, 1, axis=1)
    return shifted.at[:, 0].set(prev.astype(x.dtype))


def _head_norm(x, scale, h, hd):
    """Per-head rms norm over hd, then channel scale (RWKV GroupNorm analogue)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, h, hd).astype(F32)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    xh = xh * lax.rsqrt(var + 1e-5)
    return (xh.reshape(b, s, d) * scale.astype(F32)).astype(x.dtype)


def _mix_inputs(x, prev, p):
    """Token-shift lerp with LoRA modulation for the 5 targets (r,k,v,g,w)."""
    xs = _token_shift(x, prev)
    delta = (xs - x).astype(F32)
    lora = jnp.einsum("bsd,dnr->bsnr", x.astype(F32), p["mix_a"].astype(F32))
    lora = jnp.einsum("bsnr,nrd->bsnd", jnp.tanh(lora), p["mix_b"].astype(F32))
    mix = p["mix_base"].astype(F32)[None, None] + lora      # (B,S,5,D)
    xi = x.astype(F32)[:, :, None] + delta[:, :, None] * mix
    return xi.astype(x.dtype)  # (B, S, 5, D): r,k,v,g,w inputs


def _time_mix(x, prev, state, p, cfg: ModelConfig, chunk: int | None):
    h, hd = _dims(cfg)
    xi = _mix_inputs(x, prev, p)
    xr, xk, xv, xg, xw = (xi[:, :, i] for i in range(5))
    r = jnp.einsum("bsd,dkh->bskh", xr, p["wr"], preferred_element_type=F32)
    k = jnp.einsum("bsd,dkh->bskh", xk, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,dkh->bskh", xv, p["wv"], preferred_element_type=F32)
    g = jax.nn.silu(jnp.einsum("bsd,dkh->bskh", xg, p["wg"],
                               preferred_element_type=F32))
    dl = jnp.einsum("bsd,dr->bsr", xw.astype(F32), p["decay_a"].astype(F32))
    dl = jnp.einsum("bsr,rkh->bskh", jnp.tanh(dl), p["decay_b"].astype(F32))
    logw = -jnp.exp(p["w0"].astype(F32)[None, None] + dl)
    logw = jnp.clip(logw, LOG_W_MIN, -1e-6)

    if chunk is None:  # decode: (B, 1, ...) squeezed
        out, state = wkv_step(r[:, 0], k[:, 0], v[:, 0], logw[:, 0],
                              p["bonus_u"], state)
        out = out[:, None]
    else:
        out, state = wkv_chunked(r, k, v, logw, p["bonus_u"], state, chunk)
    b, s = x.shape[:2]
    out = _head_norm(out.reshape(b, s, -1), p["ln_x"], h, hd)
    out = (out.astype(F32) * g.reshape(b, s, -1)).astype(x.dtype)
    out = jnp.einsum("bskh,khd->bsd", out.reshape(b, s, h, hd), p["wo"],
                     preferred_element_type=F32).astype(x.dtype)
    return out, state, x[:, -1]  # new tm_prev = last input token


def _channel_mix(x, prev, p):
    xs = _token_shift(x, prev)
    delta = (xs - x).astype(F32)
    mix = p["cm_mix"].astype(F32)
    xk = (x.astype(F32) + delta * mix[0][None, None]).astype(x.dtype)
    xr = (x.astype(F32) + delta * mix[1][None, None]).astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", xk, p["cm_k"], preferred_element_type=F32)
    k = jnp.square(jax.nn.relu(k)).astype(x.dtype)
    kv = jnp.einsum("bsf,fd->bsd", k, p["cm_v"], preferred_element_type=F32)
    rgate = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr.astype(F32), p["cm_r"].astype(F32)))
    return (rgate * kv).astype(x.dtype), x[:, -1]


def _block(x, p, state, cfg: ModelConfig, chunk: int | None):
    """One RWKV block. state: dict(wkv, tm_prev, cm_prev) for this layer."""
    h = L.apply_norm(cfg.norm, x, p["ln1"])
    tm_out, wkv, tm_prev = _time_mix(h, state["tm_prev"], state["wkv"], p, cfg, chunk)
    x = annotate(x + tm_out, "residual")
    h2 = L.apply_norm(cfg.norm, x, p["ln2"])
    cm_out, cm_prev = _channel_mix(h2, state["cm_prev"], p)
    x = annotate(x + cm_out, "residual")
    return x, {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}


# ------------------------------------------------------------------- api
def _zero_state(cfg: ModelConfig, b: int):
    h, hd = _dims(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    return {
        "wkv": jnp.zeros((b, h, hd, hd), F32),
        "tm_prev": jnp.zeros((b, d), dt),
        "cm_prev": jnp.zeros((b, d), dt),
    }


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds=None, remat: bool = False,
            return_state: bool = False):
    """tokens (B, S) -> logits (B, S, V); S % rwkv.chunk == 0."""
    del prefix_embeds
    x = L.embed_lookup(params["embed"], tokens)
    x = annotate(x, "activation")
    b = x.shape[0]
    init = _zero_state(cfg, b)

    def body(h, p):
        h, st = _block(h, p, init, cfg, cfg.rwkv.chunk)
        return h, st

    if remat:
        body = jax.checkpoint(body)
    x, states = lax.scan(body, x, params["blocks"])
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    logits = L.lm_logits(x, params["head"], valid_vocab=cfg.vocab)
    if return_state:
        return annotate(logits, "logits"), states
    return annotate(logits, "logits")


def _hidden(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            remat: bool = False) -> jax.Array:
    x = L.embed_lookup(params["embed"], tokens)
    x = annotate(x, "activation")
    init = _zero_state(cfg, x.shape[0])

    def body(h, p):
        h, st = _block(h, p, init, cfg, cfg.rwkv.chunk)
        return h, st

    if remat:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["blocks"])
    return L.apply_norm(cfg.norm, x, params["final_norm"])


def loss_fn(params: PyTree, batch: dict, cfg: ModelConfig,
            remat: bool = False) -> tuple[jax.Array, dict]:
    x = _hidden(params, batch["tokens"], cfg, remat=remat)
    loss = L.lm_loss_chunked(x, params["head"], batch["labels"],
                             valid_vocab=cfg.vocab, chunk=cfg.ce_chunk)
    return loss, {"loss": loss}


def prefill(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds=None) -> tuple[jax.Array, PyTree]:
    logits, states = forward(params, tokens, cfg, return_state=True)
    return logits[:, -1], states


def decode_step(params: PyTree, state: PyTree, tokens: jax.Array,
                pos: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, PyTree]:
    """tokens (B,); state leaves have leading layer axis."""
    del pos  # recurrent: position-free
    x = L.embed_lookup(params["embed"], tokens[:, None])
    x = annotate(x, "activation")

    def body(h, xs):
        p, st = xs
        h, st2 = _block(h, p, st, cfg, chunk=None)
        return h, st2

    x, new_state = lax.scan(body, x, (params["blocks"], state))
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    logits = L.lm_logits(x, params["head"], valid_vocab=cfg.vocab)[:, 0]
    return logits, new_state
