"""Config-driven decoder-only transformer LM (covers 8 of the 10 assigned archs).

Features, all driven by ModelConfig:
  * GQA with arbitrary (n_heads, n_kv_heads, head_dim); optional QKV bias
    (qwen2), attention/final logit softcaps + local/global alternating layers
    (gemma2), tied embeddings, sinusoidal or rotary positions (musicgen),
    pre/post norms, (1+w) rmsnorm and embedding scaling (gemma2);
  * dense GLU FFN or routed MoE (grok-1, kimi-k2) with shared experts;
  * stub modality frontends: `stub_prefix` precomputed embeddings are
    prepended over the token embeddings (internvl2 vision, musicgen audio);
  * scan-over-layers with stacked parameters (compile-time O(1) in depth);
    the local/global pattern scans over layer *pairs* so the window is a
    static argument (no doubled attention compute);
  * optional per-block remat, query-chunked prefill attention;
  * prefill/decode paths with (L, B, Smax, KV, hd) KV caches.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models.params import Leaf
from repro.models.sharding_ctx import annotate

F32 = jnp.float32
PyTree = Any


# ----------------------------------------------------------------- params
def param_struct(cfg: ModelConfig) -> PyTree:
    d, v, nl = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    dt = cfg.dtype

    blocks: dict[str, Leaf] = {
        "ln1": Leaf((nl, d), ("layers", "embed"), dt, "ones"),
        "wq": Leaf((nl, d, h, hd), ("layers", "embed", "heads", None), dt),
        "wk": Leaf((nl, d, kv, hd), ("layers", "embed", "kv_heads", None), dt),
        "wv": Leaf((nl, d, kv, hd), ("layers", "embed", "kv_heads", None), dt),
        "wo": Leaf((nl, h, hd, d), ("layers", "heads", None, "embed"), dt),
        "ln2": Leaf((nl, d), ("layers", "embed"), dt, "ones"),
    }
    if cfg.qkv_bias:
        blocks["bq"] = Leaf((nl, h, hd), ("layers", "heads", None), dt, "zeros")
        blocks["bk"] = Leaf((nl, kv, hd), ("layers", "kv_heads", None), dt, "zeros")
        blocks["bv"] = Leaf((nl, kv, hd), ("layers", "kv_heads", None), dt, "zeros")
    if cfg.post_norm:
        blocks["pn1"] = Leaf((nl, d), ("layers", "embed"), dt, "ones")
        blocks["pn2"] = Leaf((nl, d), ("layers", "embed"), dt, "ones")
    if cfg.moe is not None:
        m = cfg.moe
        e, f = m.n_experts, m.d_ff
        blocks["router"] = Leaf((nl, d, e), ("layers", "embed", None), "float32")
        # experts shard on the EP axis when divisible (kimi: 384 experts);
        # otherwise the divisibility fallback leaves E unsharded and the
        # "ffn" tag shards the per-expert hidden dim instead (grok: 8 experts
        # on a 16-way model axis would otherwise replicate ALL expert compute)
        blocks["we_gate"] = Leaf((nl, e, d, f), ("layers", "experts", "embed", "ffn"), dt)
        blocks["we_up"] = Leaf((nl, e, d, f), ("layers", "experts", "embed", "ffn"), dt)
        blocks["we_down"] = Leaf((nl, e, f, d), ("layers", "experts", "ffn", "embed"), dt)
        if m.n_shared_experts:
            sf = m.n_shared_experts * f
            blocks["ws_gate"] = Leaf((nl, d, sf), ("layers", "embed", "ffn"), dt)
            blocks["ws_up"] = Leaf((nl, d, sf), ("layers", "embed", "ffn"), dt)
            blocks["ws_down"] = Leaf((nl, sf, d), ("layers", "ffn", "embed"), dt)
    else:
        f = cfg.d_ff
        blocks["w_gate"] = Leaf((nl, d, f), ("layers", "embed", "ffn"), dt)
        blocks["w_up"] = Leaf((nl, d, f), ("layers", "embed", "ffn"), dt)
        blocks["w_down"] = Leaf((nl, f, d), ("layers", "ffn", "embed"), dt)

    struct = {
        "embed": Leaf((v, d), ("vocab_in", "embed"), dt, scale=0.02),
        "final_norm": Leaf((d,), ("embed",), dt, "ones"),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        struct["head"] = Leaf((d, v), ("embed", "vocab"), dt)
    return struct


def _is_paired(cfg: ModelConfig) -> bool:
    return (cfg.layer_pattern == "local_global" and cfg.local_window is not None
            and cfg.n_layers % 2 == 0)


# ---------------------------------------------------------------- forward
def _qkv(x, p, cfg: ModelConfig):
    # bf16-out projections: see layers.glu_mlp note (f32 outputs make the
    # whole backward f32 and double collective bytes)
    q = jnp.einsum("bsd,dkh->bskh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return q, k, v


def _ffn(x, p, cfg: ModelConfig):
    if cfg.moe is not None:
        shared = None
        if cfg.moe.n_shared_experts:
            shared = (p["ws_gate"], p["ws_up"], p["ws_down"])
        return moe_lib.moe_ffn(x, p["router"], p["we_gate"], p["we_up"],
                               p["we_down"], cfg.moe, cfg.act, shared)
    return L.glu_mlp(x, p["w_gate"], p["w_up"], p["w_down"], cfg.act)


def _block_full(x, p, positions, cfg: ModelConfig, window: int | None,
                return_kv: bool = False):
    """One transformer block over the full sequence (train / prefill).

    `window` is STATIC (None => global attention).
    """
    h = L.apply_norm(cfg.norm, x, p["ln1"], plus_one=cfg.norm_plus_one)
    q, k, v = _qkv(h, p, cfg)
    if cfg.pos_emb == "rope":
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    q = annotate(q, "attn_q")
    k = annotate(k, "attn_kv")
    v = annotate(v, "attn_kv")
    attn = L.chunked_causal_attention(q, k, v, q_chunk=cfg.attn_q_chunk,
                                      window=window, cap=cfg.attn_softcap)
    attn = jnp.einsum("bskh,khd->bsd", attn, p["wo"])
    if cfg.post_norm:
        attn = L.apply_norm(cfg.norm, attn, p["pn1"], plus_one=cfg.norm_plus_one)
    x = annotate(x + attn, "residual")
    h = L.apply_norm(cfg.norm, x, p["ln2"], plus_one=cfg.norm_plus_one)
    ff = _ffn(h, p, cfg)
    if cfg.post_norm:
        ff = L.apply_norm(cfg.norm, ff, p["pn2"], plus_one=cfg.norm_plus_one)
    out = annotate(x + ff, "residual")
    if return_kv:
        return out, (k, v)
    return out


def _scan_blocks(x, blocks, positions, cfg: ModelConfig, remat: bool,
                 collect_kv: bool = False):
    """Scan over stacked layers; paired scan for the local/global pattern."""
    paired = _is_paired(cfg)

    if paired:
        pairs = jax.tree.map(lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]),
                             blocks)

        def body(h, p2):
            p_local = jax.tree.map(lambda a: a[0], p2)
            p_global = jax.tree.map(lambda a: a[1], p2)
            if collect_kv:
                h, kv0 = _block_full(h, p_local, positions, cfg,
                                     cfg.local_window, return_kv=True)
                h, kv1 = _block_full(h, p_global, positions, cfg, None,
                                     return_kv=True)
                return h, (jnp.stack([kv0[0], kv1[0]]), jnp.stack([kv0[1], kv1[1]]))
            h = _block_full(h, p_local, positions, cfg, cfg.local_window)
            h = _block_full(h, p_global, positions, cfg, None)
            return h, None

        if remat:
            body = jax.checkpoint(body)
        x, kvs = lax.scan(body, x, pairs)
        if collect_kv:
            ck = kvs[0].reshape((-1,) + kvs[0].shape[2:])
            cv = kvs[1].reshape((-1,) + kvs[1].shape[2:])
            return x, (ck, cv)
        return x, None

    window = cfg.local_window if cfg.layer_pattern == "global" and cfg.local_window else None

    def body(h, p):
        if collect_kv:
            h, (k, v) = _block_full(h, p, positions, cfg, window, return_kv=True)
            return h, (k, v)
        return _block_full(h, p, positions, cfg, window), None

    if remat:
        body = jax.checkpoint(body)
    return lax.scan(body, x, blocks)


def _embed_inputs(params, tokens, cfg: ModelConfig, prefix_embeds=None):
    x = L.embed_lookup(params["embed"], tokens)
    if cfg.stub_prefix:
        assert prefix_embeds is not None, f"{cfg.name} needs frontend embeddings"
        p = cfg.stub_prefix
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x[:, p:]], axis=1)
    if cfg.scale_embeddings:
        x = (x.astype(F32) * np.sqrt(cfg.d_model)).astype(x.dtype)
    if cfg.pos_emb == "sinusoidal":
        s = x.shape[1]
        x = (x.astype(F32) + L.sinusoidal_pos(jnp.arange(s), cfg.d_model)).astype(x.dtype)
    return annotate(x, "activation")


def _head(params):
    return params["head"] if "head" in params else params["embed"].T


def _hidden(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: jax.Array | None = None,
            remat: bool = False) -> jax.Array:
    x = _embed_inputs(params, tokens, cfg, prefix_embeds)
    positions = jnp.arange(x.shape[1])
    x, _ = _scan_blocks(x, params["blocks"], positions, cfg, remat)
    return L.apply_norm(cfg.norm, x, params["final_norm"],
                        plus_one=cfg.norm_plus_one)


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: jax.Array | None = None,
            remat: bool = False) -> jax.Array:
    """Teacher-forcing forward. tokens (B, S) -> logits (B, S, V) f32."""
    x = _hidden(params, tokens, cfg, prefix_embeds, remat)
    logits = L.lm_logits(x, _head(params), cap=cfg.final_softcap,
                         valid_vocab=cfg.vocab)
    return annotate(logits, "logits")


def loss_fn(params: PyTree, batch: dict, cfg: ModelConfig,
            remat: bool = False) -> tuple[jax.Array, dict]:
    x = _hidden(params, batch["tokens"], cfg,
                prefix_embeds=batch.get("prefix_embeds"), remat=remat)
    mask = None
    if cfg.stub_prefix:
        s = x.shape[1]
        mask = ((jnp.arange(s) >= cfg.stub_prefix)[None, :]
                * jnp.ones(batch["labels"].shape, F32))
    loss = L.lm_loss_chunked(x, _head(params), batch["labels"],
                             valid_vocab=cfg.vocab, chunk=cfg.ce_chunk,
                             cap=cfg.final_softcap, mask=mask)
    return loss, {"loss": loss}


# ------------------------------------------------------------------ decode
def cache_struct(cfg: ModelConfig, batch: int, max_seq: int) -> PyTree:
    hd = cfg.resolved_head_dim
    return {
        "k": Leaf((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd),
                  ("layers", "act_batch", "act_seq", "kv_heads", None),
                  cfg.dtype, "zeros"),
        "v": Leaf((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd),
                  ("layers", "act_batch", "act_seq", "kv_heads", None),
                  cfg.dtype, "zeros"),
    }


def prefill(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: jax.Array | None = None
            ) -> tuple[jax.Array, PyTree]:
    """Run the prompt; returns (last-position logits (B, V), KV cache)."""
    x = _embed_inputs(params, tokens, cfg, prefix_embeds)
    positions = jnp.arange(x.shape[1])
    x, (ck, cv) = _scan_blocks(x, params["blocks"], positions, cfg,
                               remat=False, collect_kv=True)
    x = L.apply_norm(cfg.norm, x, params["final_norm"], plus_one=cfg.norm_plus_one)
    logits = L.lm_logits(x[:, -1:], _head(params), cap=cfg.final_softcap,
                         valid_vocab=cfg.vocab)[:, 0]
    return logits, {"k": annotate(ck, "cache"), "v": annotate(cv, "cache")}


def _block_decode(h, p, k_l, v_l, pos, cfg: ModelConfig, window: int | None):
    hn = L.apply_norm(cfg.norm, h, p["ln1"], plus_one=cfg.norm_plus_one)
    q, k, v = _qkv(hn, p, cfg)
    if cfg.pos_emb == "rope":
        q = L.rope(q, pos[None], cfg.rope_theta)
        k = L.rope(k, pos[None], cfg.rope_theta)
    k_l = lax.dynamic_update_slice_in_dim(k_l, k.astype(k_l.dtype), pos, axis=1)
    v_l = lax.dynamic_update_slice_in_dim(v_l, v.astype(v_l.dtype), pos, axis=1)
    attn = L.decode_attention(q, k_l, v_l, pos, window=window,
                              cap=cfg.attn_softcap)
    attn = jnp.einsum("bskh,khd->bsd", attn, p["wo"])
    if cfg.post_norm:
        attn = L.apply_norm(cfg.norm, attn, p["pn1"], plus_one=cfg.norm_plus_one)
    h2 = h + attn
    hn2 = L.apply_norm(cfg.norm, h2, p["ln2"], plus_one=cfg.norm_plus_one)
    ff = _ffn(hn2, p, cfg)
    if cfg.post_norm:
        ff = L.apply_norm(cfg.norm, ff, p["pn2"], plus_one=cfg.norm_plus_one)
    return h2 + ff, k_l, v_l


def decode_step(params: PyTree, cache: PyTree, tokens: jax.Array,
                pos: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, PyTree]:
    """One decode step. tokens (B,) int32; pos scalar; cache (L,B,Smax,KV,hd).

    Returns (logits (B, V) f32, updated cache).
    """
    x = L.embed_lookup(params["embed"], tokens[:, None])  # (B, 1, D)
    if cfg.scale_embeddings:
        x = (x.astype(F32) * np.sqrt(cfg.d_model)).astype(x.dtype)
    if cfg.pos_emb == "sinusoidal":
        x = (x.astype(F32) + L.sinusoidal_pos(pos[None], cfg.d_model)).astype(x.dtype)
    x = annotate(x, "activation")

    if _is_paired(cfg):
        pairs = jax.tree.map(
            lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]),
            (params["blocks"], cache["k"], cache["v"]))

        def body(h, xs):
            p2, k2, v2 = xs
            sel = lambda t, i: jax.tree.map(lambda a: a[i], t)
            h, k0, v0 = _block_decode(h, sel(p2, 0), k2[0], v2[0], pos, cfg,
                                      cfg.local_window)
            h, k1, v1 = _block_decode(h, sel(p2, 1), k2[1], v2[1], pos, cfg, None)
            return h, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))

        x, (ck, cv) = lax.scan(body, x, pairs)
        ck = ck.reshape((-1,) + ck.shape[2:])
        cv = cv.reshape((-1,) + cv.shape[2:])
    else:
        def body(h, xs):
            p, k_l, v_l = xs
            h, k_l, v_l = _block_decode(h, p, k_l, v_l, pos, cfg, None)
            return h, (k_l, v_l)

        x, (ck, cv) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))

    x = L.apply_norm(cfg.norm, x, params["final_norm"], plus_one=cfg.norm_plus_one)
    logits = L.lm_logits(x, _head(params), cap=cfg.final_softcap,
                         valid_vocab=cfg.vocab)[:, 0]
    return logits, {"k": ck, "v": cv}
