"""Shared neural building blocks (pure jnp, config-driven).

Conventions:
* activations (B, S, D); attention heads kept as separate dims (B, S, H, hd);
* all matmuls accumulate in f32 (`preferred_element_type`);
* prefill attention is query-chunked (lax.scan) so no (S, S) score tensor is
  ever materialized — required for the 32k shapes;
* decode attention supports a KV cache with a sharded sequence axis
  (flash-decode style: XLA inserts the tiny softmax-stat collectives).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


# ------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    x32 = x.astype(F32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    s = scale.astype(F32)
    if plus_one:
        s = s + 1.0
    return (y * s).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array | None = None,
               eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * lax.rsqrt(var + eps) * scale.astype(F32)
    if bias is not None:
        y = y + bias.astype(F32)
    return y.astype(x.dtype)


def apply_norm(kind: str, x: jax.Array, scale: jax.Array, **kw) -> jax.Array:
    return rms_norm(x, scale, **kw) if kind == "rms" else layer_norm(x, scale)


# -------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None] * freqs  # (S, half) or (B, S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos, sin = cos[..., None, :], sin[..., None, :]  # broadcast over heads
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=F32) / half)
    ang = positions.astype(F32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------- attention
def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _attn_block(q: jax.Array, k: jax.Array, v: jax.Array, q_offset,
                *, window: int | None, cap: float | None,
                kv_len: jax.Array | None = None) -> jax.Array:
    """One query block vs full K/V. q: (B, Cq, H, hd); k/v: (B, Skv, KV, hd).

    q_offset: scalar (traced ok) position of the first query row.
    kv_len: optional number of valid KV rows (decode with partial cache).
    """
    b, cq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    qg = q.reshape(b, cq, kv, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k, preferred_element_type=F32)
    scores = scores / math.sqrt(hd)
    scores = softcap(scores, cap)
    qpos = q_offset + jnp.arange(cq)
    kpos = jnp.arange(skv)
    mask = kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.reshape(b, cq, h, hd).astype(v.dtype)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             q_chunk: int, window: int | None = None,
                             cap: float | None = None) -> jax.Array:
    """Prefill/train attention, scanned over query chunks (no S x S tensor)."""
    b, s, h, hd = q.shape
    if s <= q_chunk:
        return _attn_block(q, k, v, 0, window=window, cap=cap)
    nq, rem = divmod(s, q_chunk)

    def body(_, i):
        qi = lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        oi = _attn_block(qi, k, v, i * q_chunk, window=window, cap=cap)
        return None, oi

    _, outs = lax.scan(body, None, jnp.arange(nq))  # (nq, B, Cq, H, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * q_chunk, h, hd)
    if rem:  # ragged tail block
        tail = _attn_block(q[:, nq * q_chunk:], k, v, nq * q_chunk,
                           window=window, cap=cap)
        out = jnp.concatenate([out, tail], axis=1)
    return out


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: int | None = None,
                     cap: float | None = None) -> jax.Array:
    """Single-token attention vs cache. q: (B, 1, H, hd); caches (B, Smax, KV, hd).

    pos: scalar index of the query token (cache rows < pos+1 are valid).
    """
    return _attn_block(q, k_cache, v_cache, pos, window=window, cap=cap,
                       kv_len=pos + 1)


# -------------------------------------------------------------------- mlps
def act_fn(kind: str, x: jax.Array) -> jax.Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def glu_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
            act: str) -> jax.Array:
    # NOTE: projection einsums keep the model dtype end to end (bf16): the
    # MXU accumulates in f32 internally, and f32 *outputs* would make every
    # backward cotangent f32 — doubling all fsdp/TP collective bytes (and
    # XLA then gathers f32 weight copies). Measured in EXPERIMENTS.md §Perf.
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = (act_fn(act, g.astype(F32)) * u.astype(F32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, w_down)


# --------------------------------------------------------------- embedding
def embed_lookup(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def lm_logits(x: jax.Array, head: jax.Array, cap: float | None = None,
              valid_vocab: int | None = None) -> jax.Array:
    """x: (B, S, D); head: (D, V) -> logits (B, S, V) in f32.

    valid_vocab: mask padded vocab columns (>= valid) to -inf.
    """
    logits = jnp.einsum("bsd,dv->bsv", x, head, preferred_element_type=F32)
    logits = softcap(logits, cap)
    v = logits.shape[-1]
    if valid_vocab is not None and valid_vocab < v:
        keep = jnp.arange(v) < valid_vocab
        logits = jnp.where(keep, logits, -1e30)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over valid positions; logits f32 (B, S, V), labels int (B, S)."""
    logits = logits.astype(F32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(F32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss_chunked(x: jax.Array, head: jax.Array, labels: jax.Array, *,
                    valid_vocab: int, chunk: int = 512,
                    cap: float | None = None,
                    mask: jax.Array | None = None) -> jax.Array:
    """Cross-entropy straight from hidden states, scanned over seq chunks.

    Never materializes the full (B, S, V) logits — peak transient is
    (B, chunk, V) per device (vocab TP-sharded), which is what makes 150k+
    vocabularies trainable at 4k sequance on 16 GiB chips. The chunk body is
    rematerialized in the backward pass (jax.checkpoint).
    """
    b, s, d = x.shape
    v = head.shape[1]
    if mask is None:
        mask = jnp.ones((b, s), F32)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (s + pad) // chunk
    xc = x.reshape(b, nc, chunk, d)
    lc = labels.reshape(b, nc, chunk)
    mc = mask.reshape(b, nc, chunk).astype(F32)

    @jax.checkpoint
    def body(carry, xs):
        xi, li, mi = xs  # (b, chunk, d), (b, chunk), (b, chunk)
        logits = jnp.einsum("bsd,dv->bsv", xi, head, preferred_element_type=F32)
        logits = softcap(logits, cap)
        if valid_vocab < v:
            keep = jnp.arange(v) < valid_vocab
            logits = jnp.where(keep, logits, -1e30)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll_sum, m_sum = carry
        return (nll_sum + jnp.sum((lse - gold) * mi), m_sum + jnp.sum(mi)), None

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0), jnp.moveaxis(mc, 1, 0))
    (nll_sum, m_sum), _ = lax.scan(body, (jnp.zeros((), F32), jnp.zeros((), F32)), xs)
    return nll_sum / jnp.maximum(m_sum, 1.0)
