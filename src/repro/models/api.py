"""Unified model API: one entry point per (family x phase), used by the
launcher, the dry-run, the smoke tests, and the benchmarks.

    model = ModelAPI(cfg)
    model.param_struct()                  -> Leaf pytree (init/dry-run/sharding)
    model.loss_fn(params, batch)          -> (loss, aux)        [train]
    model.prefill(params, tokens, ...)    -> (logits, cache)    [serving]
    model.decode_step(params, cache, tok, pos) -> (logits, cache)
    model.cache_struct(batch, max_seq)    -> Leaf pytree of the decode state
    model.input_specs(shape)              -> ShapeDtypeStruct batch for `shape`
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import params as params_lib
from repro.models import rwkv, transformer, zamba

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig

    # ------------------------------------------------------------- params
    def param_struct(self) -> PyTree:
        if self.cfg.family == "transformer":
            return transformer.param_struct(self.cfg)
        if self.cfg.family == "rwkv":
            return rwkv.param_struct(self.cfg)
        if self.cfg.family == "zamba":
            return zamba.param_struct(self.cfg)
        raise ValueError(f"unknown family {self.cfg.family}")

    def init_params(self, rng: jax.Array) -> PyTree:
        return params_lib.init_params(self.param_struct(), rng)

    def param_count(self) -> int:
        return params_lib.count_params(self.param_struct())

    # -------------------------------------------------------------- train
    def loss_fn(self, params, batch, remat: bool = False):
        mod = {"transformer": transformer, "rwkv": rwkv, "zamba": zamba}[self.cfg.family]
        return mod.loss_fn(params, batch, self.cfg, remat=remat)

    def forward(self, params, tokens, **kw):
        mod = {"transformer": transformer, "rwkv": rwkv, "zamba": zamba}[self.cfg.family]
        return mod.forward(params, tokens, self.cfg, **kw)

    # -------------------------------------------------------------- serve
    def prefill(self, params, tokens, prefix_embeds=None):
        mod = {"transformer": transformer, "rwkv": rwkv, "zamba": zamba}[self.cfg.family]
        return mod.prefill(params, tokens, self.cfg, prefix_embeds=prefix_embeds)

    def decode_step(self, params, cache, tokens, pos):
        mod = {"transformer": transformer, "rwkv": rwkv, "zamba": zamba}[self.cfg.family]
        return mod.decode_step(params, cache, tokens, pos, self.cfg)

    def cache_struct(self, batch: int, max_seq: int) -> PyTree:
        if self.cfg.family == "transformer":
            return transformer.cache_struct(self.cfg, batch, max_seq)
        if self.cfg.family == "rwkv":
            return rwkv.state_struct(self.cfg, batch)
        if self.cfg.family == "zamba":
            return zamba.state_struct(self.cfg, batch, max_seq)
        raise ValueError(self.cfg.family)

    # -------------------------------------------------------------- specs
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape.

        train:   {"tokens", "labels"[, "prefix_embeds"]}
        prefill: {"tokens"[, "prefix_embeds"]}
        decode:  {"tokens" (B,), "pos" scalar, "cache": <struct>}
        """
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                     "labels": jax.ShapeDtypeStruct((b, s), i32)}
            if self.cfg.stub_prefix:
                specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                    (b, self.cfg.stub_prefix, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            return specs
        if shape.kind == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
            if self.cfg.stub_prefix:
                specs["prefix_embeds"] = jax.ShapeDtypeStruct(
                    (b, self.cfg.stub_prefix, self.cfg.d_model),
                    jnp.dtype(self.cfg.dtype))
            return specs
        # decode: one new token against a cache of size seq_len
        cache = params_lib.shape_structs(self.cache_struct(b, s))
        return {"tokens": jax.ShapeDtypeStruct((b,), i32),
                "pos": jax.ShapeDtypeStruct((), i32),
                "cache": cache}
