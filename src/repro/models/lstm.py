"""The paper's language model: 2-layer LSTM, 256 hidden units (§5, Shakespeare)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.params import Leaf

F32 = jnp.float32
PyTree = Any


def param_struct(vocab: int, d_embed: int = 128, d_hidden: int = 256,
                 n_layers: int = 2, dtype: str = "float32") -> PyTree:
    layers = {
        "wx": Leaf((n_layers, d_embed if n_layers == 1 else max(d_embed, d_hidden),
                    4 * d_hidden), ("layers", None, None), dtype),
        "wh": Leaf((n_layers, d_hidden, 4 * d_hidden), ("layers", None, None), dtype),
        "b": Leaf((n_layers, 4 * d_hidden), ("layers", None), dtype, "zeros"),
    }
    return {
        "embed": Leaf((vocab, d_embed), (None, None), dtype, scale=0.05),
        "proj_in": Leaf((d_embed, max(d_embed, d_hidden)), (None, None), dtype),
        "layers": layers,
        "head": Leaf((d_hidden, vocab), (None, None), dtype),
    }


def _lstm_cell(x, h, c, wx, wh, b):
    gates = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(gates.astype(F32), 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(x.dtype), c


def forward(params: PyTree, tokens: jax.Array) -> jax.Array:
    """tokens (B, S) -> logits (B, S, V)."""
    emb = jnp.take(params["embed"], tokens, axis=0)       # (B, S, E)
    x = emb @ params["proj_in"]                            # (B, S, H_in)
    b, s, _ = x.shape
    n_layers = params["layers"]["wx"].shape[0]
    d_hidden = params["layers"]["wh"].shape[1]

    for l in range(n_layers):
        wx = params["layers"]["wx"][l][:x.shape[-1]]
        wh = params["layers"]["wh"][l]
        bb = params["layers"]["b"][l]

        def step(carry, xt):
            h, c = carry
            h, c = _lstm_cell(xt, h, c, wx, wh, bb)
            return (h, c), h

        init = (jnp.zeros((b, d_hidden), x.dtype), jnp.zeros((b, d_hidden), F32))
        _, hs = lax.scan(step, init, jnp.moveaxis(x, 1, 0))
        x = jnp.moveaxis(hs, 0, 1)                         # (B, S, H)
    return x @ params["head"]


def loss_fn(params: PyTree, batch: dict) -> tuple[jax.Array, dict]:
    logits = forward(params, batch["tokens"])
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(F32))
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1).mean()
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(F32))
    return nll, {"loss": nll, "acc": acc}
