"""Model substrate: every assigned architecture in pure JAX.

`api.ModelAPI` is the single entry point; family modules (`transformer`,
`rwkv`, `zamba`) implement param structure + train/prefill/decode; `mlp` and
`lstm` are the paper's own experiment models.
"""
from repro.models.api import ModelAPI  # noqa: F401
