"""GossipEngine: ONE gossip executor assembled from three orthogonal layers.

The repo used to carry seven hand-specialized executors (per-leaf f32,
per-leaf int8, packed f32, packed int8, packed delayed, stacked, stacked
delayed) whose bodies were copy-pasted variations of the same round. Every
new lever (quantize the wire, pipeline the wire, simulate on one device)
multiplied the zoo instead of composing with it — the ROADMAP item
"pipelined + quantized gossip" could not be wired without this refactor.

The engine factors the round into:

* **WireCodec** — what travels on the wire and how it folds back into the
  mixing reduction. ``"f32"`` ships the packed buffer unchanged and reduces
  through the fused ``gossip_mix_2d`` stack pass; ``"int8"`` /
  ``"int8_block"`` quantize through the Pallas quantize kernels, fold the
  f32 scale(s) INTO the shipped int8 buffer (one collective per schedule —
  ``fold_scale(s)_into_wire``), and fold each received wire into the
  accumulator through the fused ``dequant_accumulate_2d[_blockwise]``
  kernels. A codec owns encode -> ship -> fused-decode-accumulate; it never
  sees the topology.
* **timing** — ``delay=0`` (synchronous: this round's collectives carry this
  round's post-local-step buffers) or ``delay=1`` (pipelined: the
  collectives read the PREVIOUS round's snapshot, a donated step input with
  no data dependency on the local-step scan, so XLA overlaps the wire with
  compute — ``mix_dense_delayed`` semantics). The carried state is the
  codec's *wire format*, so delayed x int8 ships int8 bytes and carries a 4x
  smaller snapshot for free.
* **substrate** — where the round runs: ``"shard_map"`` (the production
  ppermute island: d collectives/round over the client mesh axes),
  ``"stacked"`` (the single-device simulator: gathers on a stacked client
  axis — the elastic runtime's path), ``"blocked"`` (the massive-client
  simulator: ``block`` clients per device in the stacked layout *under*
  shard_map — intra-device edges stay stacked gathers, cross-device edges
  ship whole per-device wire blocks via the precomputed
  :class:`~repro.core.gossip.BlockedSpec` partition, so n decouples from
  the mesh and O(10^4+) clients run on a handful of devices), ``"per_leaf"``
  (the d x n_leaves ppermute baseline), or ``"dense"`` (the paper-naive
  mixing einsum).
* **screen** — Byzantine-robust aggregation of what arrived: ``"none"``
  (trust every payload: the plain weighted reduction), ``"norm_clip"``
  (per-sender squared-norm pass over the packed wire; any received buffer
  whose norm exceeds ``clip_tau x`` the receiver's own norm is rescaled
  down onto that ball — a *payload* rescale, folded into the
  post-renormalization received weights so the alive/gates renorm is
  untouched and an all-ones clip is the exact identity), or
  ``"trimmed_mean"`` (coordinate-wise trimmed mean over the d+1 stack
  through the fused ``gossip_mix_2d_trimmed[_quant]`` kernels: per element
  the ``trim_f`` largest and smallest live values are dropped and the
  survivors renormalize — dead/gated/fixed-point senders are excluded from
  the order statistics via the same contributor weights the masked
  reduction uses). Screens are local and per-receiver: each client defends
  its own update with information it already holds; there is no reputation
  exchange and no extra collective — the wire still ships exactly d
  buffers/round.

Alive masks and round-plan gates thread through the ONE shared weight path
(:func:`repro.core.gossip.alive_weight_table` and its per-client local form)
for every combination — they are traced step data, never trace structure, so
straggler churn and per-round topologies retrace nothing.

The payoff that proves the factoring: ``delay=1 x int8`` (pipelined +
quantized) is a free composition — zero new executor code, exactly d
collectives/round of int8 wire bytes, and the same zero-retrace / splice-
repair story as every other cell of the cube. Legacy entry points
(``gossip.ppermute_mix_packed`` et al.) and legacy ``gossip_impl`` strings
all resolve here (see ``LEGACY_GOSSIP_IMPLS``); ``sync x f32 x shard_map``
lowers to HLO textually identical to the pre-refactor ``ppermute_packed``
path, and ``delay=0`` is bit-identical to sync (both pinned in tests).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import gossip, packing
from repro.core.gossip import GossipSpec
from repro.telemetry.metrics import TelemetryConfig

__all__ = [
    "CODECS",
    "SCREENS",
    "SUBSTRATES",
    "DELAY_SUBSTRATES",
    "SCREEN_SUBSTRATES",
    "STATEFUL_SUBSTRATES",
    "TELEMETRY_SUBSTRATES",
    "CHEBY_SUBSTRATES",
    "LEGACY_GOSSIP_IMPLS",
    "GossipEngineConfig",
    "GossipExecutor",
    "TopKEFCodec",
    "build_gossip_executor",
    "get_codec",
    "parse_gossip_impl",
    "register_codec",
    "resolve_trainer_engine",
]

PyTree = Any

SUBSTRATES = ("shard_map", "stacked", "blocked", "per_leaf", "dense")
SCREENS = ("none", "norm_clip", "trimmed_mean")
# the cells the delay and screen layers are wired for; "blocked" joins when
# its snapshot-carry and screen-norm passes land (validation names this
# tuple so every error message enumerates the same cells). Stateful codecs
# (per-client codec state, e.g. the topk_ef EF residual) ride the same two
# substrates the delay snapshot does — the state threads through the step
# exactly like the in-flight wire.
DELAY_SUBSTRATES = ("shard_map", "stacked")
SCREEN_SUBSTRATES = ("shard_map", "stacked")
STATEFUL_SUBSTRATES = ("shard_map", "stacked")
# telemetry rides "blocked" too: the metrics-only cell (consensus residual +
# in-degree) is computable from the device-local rows the blocked round
# already gathers, with ZERO extra collectives; screens (and hence clip
# counts) stay stacked/shard_map-only
TELEMETRY_SUBSTRATES = ("shard_map", "stacked", "blocked")
# Chebyshev multi-round gossip (sub_rounds > 1): the two packed substrates
# whose round bodies loop the d-collectives-per-schedule structure
CHEBY_SUBSTRATES = ("shard_map", "stacked")

# legacy ParallelConfig.gossip_impl strings -> (substrate, codec). The delay
# axis rides separately (ParallelConfig.gossip_delay); "ppermute_packed_async"
# is the only alias that accepts delay=1, and at delay=0 it IS
# "ppermute_packed" (identical engine config => textually identical HLO).
# The "blocked" substrate has NO legacy alias on purpose: it is an
# engine-config-only cell (spell it GossipEngineConfig(substrate="blocked",
# block=B)) because the production gossip_impl strings all assume one client
# per device slice, which is exactly the assumption it removes.
LEGACY_GOSSIP_IMPLS = {
    "dense": ("dense", "f32"),
    "ppermute": ("per_leaf", "f32"),
    "ppermute_quant": ("per_leaf", "int8"),
    "ppermute_packed": ("shard_map", "f32"),
    "ppermute_packed_quant": ("shard_map", "int8_block"),
    "ppermute_packed_async": ("shard_map", "f32"),
}


@dataclasses.dataclass(frozen=True)
class GossipEngineConfig:
    """Static (hashable) engine cell: substrate x codec x timing x screen.

    Attributes:
      substrate: "shard_map" | "stacked" | "blocked" | "per_leaf" | "dense".
      codec: "f32" | "int8" (per-buffer scale) | "int8_block" (one scale per
        kernel row-block tile, the tighter default wire format for quant).
      delay: 0 = synchronous, 1 = pipelined (one-round-delayed snapshot;
        shard_map | stacked only — see DELAY_SUBSTRATES).
      sub_rounds: k >= 1 gossip sub-rounds per round (the second timing
        axis). 1 (the default) is the synchronous engine, byte-identical —
        the sub-round machinery is a build-time branch, exactly like
        delay=0. k > 1 runs Chebyshev-accelerated multi-round gossip
        (shard_map | stacked — see CHEBY_SUBSTRATES): each sub-round
        reuses the round's d-collectives-per-schedule structure and fused
        reduce kernels on the SAME weight table (k*d collectives total),
        combined through the second-order recurrence
        ``x_(j+1) = omega[j] * (W x_j - x_(j-1)) + x_(j-1)`` whose
        per-sub-round ``omega`` coefficients ship as one more traced
        operand next to alive/gates (``cheby=`` — derive them from the
        overlay's lambda via :func:`repro.core.spectral.chebyshev_omegas`
        or :meth:`GossipExecutor.cheby_coeffs`; varying them retraces
        nothing). Composes with any stateless codec; delay=1 (the snapshot
        is one round stale, not one sub-round), screens (per-sub-round
        order statistics are undefined) and stateful codecs (the EF
        residual updates once per round) are rejected.
      mix_impl: kernel implementation knob threaded to the fused
        gossip_mix / quant kernels ("auto" | "pallas" | "pallas_interpret" |
        "ref").
      screen: Byzantine screen over received payloads — "none" |
        "norm_clip" | "trimmed_mean" (shard_map | stacked only — see
        SCREEN_SUBSTRATES; module docstring has the exact semantics).
      clip_tau: norm_clip threshold — a received buffer is rescaled when
        its norm exceeds ``clip_tau x`` the receiver's own norm.
      trim_f: trimmed_mean per-side drop count (clamped per coordinate so
        at least one live value always survives; 0 = renormalized mean).
      block: B, simulated clients per device — required (>= 1, dividing
        ``n_clients``) on the "blocked" substrate, must stay 0 elsewhere.
        The blocked cell runs the stacked gather/einsum round on a
        device-local ``(B, ...)`` slice under shard_map; cross-device
        schedule edges ship whole per-device wire blocks via the
        :class:`~repro.core.gossip.BlockedSpec` partition baked at build
        time, so an intra-heavy placement pays almost no wire.
      telemetry: None (the default — the round's HLO is textually identical
        to an untelemetered build) or a
        :class:`repro.telemetry.metrics.TelemetryConfig`, which makes the
        executor additionally return a RoundMetrics dict of traced values
        (shard_map | stacked | blocked — see TELEMETRY_SUBSTRATES; the
        blocked cell is metrics-only, measured on device-local rows).
        Metrics are outputs, never trace structure: no extra collectives,
        no retraces.
    """

    substrate: str = "shard_map"
    codec: str = "f32"
    delay: int = 0
    sub_rounds: int = 1
    mix_impl: str = "auto"
    screen: str = "none"
    clip_tau: float = 3.0
    trim_f: int = 1
    block: int = 0
    telemetry: TelemetryConfig | None = None

    def __post_init__(self):
        if self.substrate not in SUBSTRATES:
            raise ValueError(f"unknown substrate {self.substrate!r}; "
                             f"available: {', '.join(SUBSTRATES)}")
        codec_obj = get_codec(self.codec)  # raises the unknown-codec error
        if getattr(codec_obj, "stateful", False):
            if self.substrate not in STATEFUL_SUBSTRATES:
                raise ValueError(
                    f"stateful codec {self.codec!r} (per-client codec "
                    "state) runs on the "
                    f"{' | '.join(STATEFUL_SUBSTRATES)} substrates, got "
                    f"{self.substrate!r}")
            if self.screen != "none":
                raise ValueError(
                    f"screen={self.screen!r} is not wired for the stateful "
                    f"codec {self.codec!r} yet (the screened rounds do not "
                    "thread per-client codec state)")
        if self.delay not in (0, 1):
            raise ValueError(f"delay must be 0 or 1, got {self.delay}")
        if self.delay and self.substrate not in DELAY_SUBSTRATES:
            raise ValueError(
                "pipelined (delay=1) gossip runs on the "
                f"{' | '.join(DELAY_SUBSTRATES)} substrates, got "
                f"{self.substrate!r}"
                + (" (the blocked cell is not wired for a carried snapshot "
                   "yet)" if self.substrate == "blocked" else ""))
        if not isinstance(self.sub_rounds, int) or self.sub_rounds < 1:
            raise ValueError(
                f"sub_rounds must be an int >= 1, got {self.sub_rounds!r}")
        if self.sub_rounds > 1:
            if self.substrate not in CHEBY_SUBSTRATES:
                raise ValueError(
                    "Chebyshev multi-round gossip (sub_rounds > 1) runs on "
                    f"the {' | '.join(CHEBY_SUBSTRATES)} substrates, got "
                    f"{self.substrate!r}")
            if self.delay:
                raise ValueError(
                    "sub_rounds > 1 is synchronous; it does not compose "
                    "with the delayed snapshot (delay=1): the carried wire "
                    "is one ROUND stale, not one sub-round")
            if self.screen != "none":
                raise ValueError(
                    f"screen={self.screen!r} does not compose with "
                    "sub_rounds > 1 (per-sub-round order statistics are "
                    "undefined); screen the k=1 cell instead")
            if getattr(codec_obj, "stateful", False):
                raise ValueError(
                    f"stateful codec {self.codec!r} does not compose with "
                    "sub_rounds > 1 (its per-client state updates once per "
                    "round, not per sub-round)")
        if self.substrate == "per_leaf" and self.codec == "int8_block":
            raise ValueError("per-leaf payloads are not tile-aligned; use "
                             "codec='int8' for the per-leaf baseline")
        if (self.substrate == "dense"
                and not getattr(codec_obj, "identity_wire", False)):
            raise ValueError("the dense reference substrate has no wire; "
                             f"codec must be 'f32', got {self.codec!r}")
        if self.screen not in SCREENS:
            raise ValueError(f"unknown screen {self.screen!r}; "
                             f"available: {', '.join(SCREENS)}")
        if self.screen != "none" and self.substrate not in SCREEN_SUBSTRATES:
            raise ValueError(
                f"screen={self.screen!r} runs on the "
                f"{' | '.join(SCREEN_SUBSTRATES)} substrates, got "
                f"{self.substrate!r}"
                + (" (the blocked cell is not wired for screens yet)"
                   if self.substrate == "blocked" else ""))
        if self.substrate == "blocked":
            if self.block < 1:
                raise ValueError(
                    "the blocked substrate needs block >= 1 (simulated "
                    f"clients per device), got block={self.block}")
        elif self.block:
            raise ValueError(
                "block is a 'blocked'-substrate knob; substrate "
                f"{self.substrate!r} keeps block=0, got block={self.block}")
        if self.clip_tau <= 0:
            raise ValueError(f"clip_tau must be > 0, got {self.clip_tau}")
        if self.trim_f < 0:
            raise ValueError(f"trim_f must be >= 0, got {self.trim_f}")
        if self.telemetry is not None:
            if not isinstance(self.telemetry, TelemetryConfig):
                raise ValueError(
                    "telemetry must be a repro.telemetry.TelemetryConfig "
                    f"(or None), got {type(self.telemetry).__name__}")
            if self.substrate not in TELEMETRY_SUBSTRATES:
                raise ValueError(
                    "round telemetry runs on the "
                    f"{' | '.join(TELEMETRY_SUBSTRATES)} substrates, got "
                    f"{self.substrate!r}")


def parse_gossip_impl(gossip_impl: str, delay: int = 0,
                      codec: str = "auto", screen: str = "none",
                      clip_tau: float = 3.0, trim_f: int = 1,
                      telemetry: TelemetryConfig | None = None,
                      sub_rounds: int = 1,
                      ) -> GossipEngineConfig:
    """Parse a legacy ``gossip_impl`` string (+ the ``gossip_delay`` /
    ``gossip_codec`` / ``gossip_screen`` knobs) into an engine config.

    ``codec="auto"`` keeps the alias's historical codec (f32 for the plain
    impls, int8_block for the quant impls); naming a codec overrides it —
    that is how the pipelined+quantized composition is spelled:
    ``gossip_impl="ppermute_packed_async", gossip_delay=1,
    gossip_codec="int8_block"``. ``screen`` rides the same way: any packed
    alias composes with "norm_clip" / "trimmed_mean" through config alone,
    and ``telemetry`` (a :class:`TelemetryConfig`) with any packed alias.
    ``sub_rounds`` (ParallelConfig.gossip_sub_rounds) is the Chebyshev
    multi-round axis — k > 1 composes with any stateless-codec packed
    alias at delay=0.
    """
    if gossip_impl not in LEGACY_GOSSIP_IMPLS:
        raise ValueError(f"unknown gossip_impl {gossip_impl!r}; available: "
                         f"{', '.join(sorted(LEGACY_GOSSIP_IMPLS))}")
    substrate, alias_codec = LEGACY_GOSSIP_IMPLS[gossip_impl]
    if codec in (None, "auto"):
        codec = alias_codec
    if delay and gossip_impl != "ppermute_packed_async":
        raise ValueError("gossip_delay=1 requires "
                         f"gossip_impl='ppermute_packed_async', got "
                         f"{gossip_impl!r}")
    return GossipEngineConfig(substrate=substrate, codec=codec, delay=delay,
                              sub_rounds=sub_rounds, screen=screen,
                              clip_tau=clip_tau, trim_f=trim_f,
                              telemetry=telemetry)


# legacy per-knob trainer arguments and their defaults — the shim behind the
# trainers' ``engine=GossipEngineConfig(...)`` front door. NOTE the naming
# drift this resolves: the trainers historically called the norm-clip
# threshold ``screen_tau`` while ParallelConfig calls it ``gossip_clip_tau``;
# both are GossipEngineConfig.clip_tau.
_LEGACY_TRAINER_KNOBS = (
    ("gossip_codec", "f32"),
    ("gossip_delay", 0),
    ("gossip_sub_rounds", 1),
    ("gossip_block", 0),
    ("gossip_screen", "none"),
    ("screen_tau", 3.0),
    ("screen_trim", 1),
)


def resolve_trainer_engine(trainer) -> None:
    """ONE engine-config front door for the simulator trainers.

    ``trainer`` is an ElasticTrainer / SimTrainer mid-``__post_init__``: if
    ``trainer.engine`` is a :class:`GossipEngineConfig`, its cell is mirrored
    onto the legacy per-knob attributes (everything downstream — round
    builders, splice repair, the step — keeps reading one source of truth),
    so ``engine=`` construction is bitwise-equivalent to the knobs it
    replaces. Passing both is an error; passing non-default legacy knobs
    without ``engine=`` emits a :class:`DeprecationWarning` naming the
    replacement.
    """
    explicit = [k for k, d in _LEGACY_TRAINER_KNOBS
                if getattr(trainer, k) != d]
    if trainer.engine is not None:
        if explicit:
            raise ValueError(
                "pass the engine cell EITHER as engine=GossipEngineConfig("
                "...) or via the legacy gossip_* knobs, not both (legacy "
                f"knobs set: {', '.join(explicit)})")
        ecfg = trainer.engine
        if not isinstance(ecfg, GossipEngineConfig):
            raise TypeError("engine must be a repro.core.engine."
                            "GossipEngineConfig (got "
                            f"{type(ecfg).__name__})")
        if ecfg.substrate not in ("stacked", "blocked"):
            raise ValueError(
                f"{type(trainer).__name__} runs the stacked | blocked "
                f"substrates, got engine.substrate={ecfg.substrate!r} "
                "(production shard_map cells are built by "
                "launch.steps.build_train_step from ParallelConfig)")
        trainer.gossip_codec = ecfg.codec
        trainer.gossip_delay = ecfg.delay
        trainer.gossip_sub_rounds = ecfg.sub_rounds
        trainer.gossip_screen = ecfg.screen
        trainer.screen_tau = ecfg.clip_tau
        trainer.screen_trim = ecfg.trim_f
        trainer.gossip_block = ecfg.block if ecfg.substrate == "blocked" else 0
        if ecfg.telemetry is not None:
            if trainer.telemetry is not None:
                raise ValueError("telemetry passed twice: on the engine "
                                 "config AND the trainer; set it in one "
                                 "place")
            trainer.telemetry = ecfg.telemetry
    elif explicit:
        import warnings
        warnings.warn(
            f"the per-knob gossip arguments ({', '.join(explicit)}) of "
            f"{type(trainer).__name__} are deprecated; pass engine="
            "repro.core.engine.GossipEngineConfig(substrate='stacked' | "
            "'blocked', codec=..., delay=..., screen=..., clip_tau=..., "
            "trim_f=..., block=...) instead (the trainer knob screen_tau "
            "is GossipEngineConfig.clip_tau — the value ParallelConfig "
            "calls gossip_clip_tau)",
            DeprecationWarning, stacklevel=4)


# ------------------------------------------------------------------ codecs
def _renormalized_weights(weights, contrib):
    """The alive/gates renormalization of the fused masked kernels, computed
    on the (d+1,) scalar operands (ref ``gossip_mix`` semantics: weights
    masked by contrib, rescaled to unit mass over the live contributors,
    dead self => identity row). The norm-clip screen needs the
    renormalization OUTSIDE the kernel so the clip can multiply the
    post-renormalization received weights without entering the denominator.
    """
    w = jnp.asarray(weights, jnp.float32)
    if contrib is None:
        return w
    a = jnp.asarray(contrib, jnp.float32)
    wa = w * a
    tot = jnp.sum(wa)
    # no renormalizable mass => identity row REPLACES the renormalized term
    # (inv zeroed, so tiny fractional mass cannot double-count)
    ok = (tot > 1e-12).astype(jnp.float32)
    inv = ok / jnp.maximum(tot, 1e-12)
    a_self = a[0]
    eff = a_self * wa * inv
    return eff.at[0].add((1.0 - a_self) + a_self * (1.0 - ok))


def _clip_factors(r2, lim):
    """Norm-clip rescale factors: 1 inside the ball, sqrt(lim/r2) outside
    (so the clipped payload lands exactly ON the tau x self-norm ball)."""
    return jnp.where(r2 > lim, jnp.sqrt(lim / jnp.maximum(r2, 1e-30)), 1.0)


class _F32Codec:
    """Identity wire: ship the packed buffer, reduce via the fused stack
    pass (``gossip_mix_2d``). The encode is literally the buffer, so the
    delayed snapshot is the packed fresh state — byte-identical to the
    pre-refactor delayed executors."""

    name = "f32"
    identity_wire = True   # wire IS the packed buffer (no encode/decode)
    stateful = False

    def wire_struct(self, struct: jax.ShapeDtypeStruct,
                    n_blocks: int) -> jax.ShapeDtypeStruct:
        return struct

    def encode(self, buf, *, n_blocks, block_rows, impl):
        return buf

    def decode(self, wire, dtype, *, n_blocks, block_rows):
        return wire

    def reduce(self, fresh, received, weights, contrib, *, edge_weight,
               n_blocks, block_rows, impl, sender_scale=None):
        from repro.kernels.gossip_mix import ops as mix_ops

        stack = jnp.stack([fresh] + received)
        if sender_scale is None:
            return mix_ops.gossip_mix_packed(stack, weights, contrib,
                                             block_rows=block_rows, impl=impl)
        # norm-clip: renormalize outside the kernel, then scale the received
        # weights only (column 0 untouched) — an all-ones clip is bitwise
        # the same weight vector the masked kernel would have built
        eff = _renormalized_weights(weights, contrib)
        eff = jnp.concatenate([eff[:1], eff[1:] * sender_scale])
        return mix_ops.gossip_mix_packed(stack, eff, None,
                                         block_rows=block_rows, impl=impl)

    def reduce_trimmed(self, fresh, received, u, live, *, trim, n_blocks,
                       block_rows, impl):
        from repro.kernels.gossip_mix import ops as mix_ops

        stack = jnp.stack([fresh] + received)
        return mix_ops.gossip_mix_trimmed_packed(stack, u, live, trim=trim,
                                                 block_rows=block_rows,
                                                 impl=impl)

    def wire_sqnorm(self, wire, *, n_blocks, block_rows, impl):
        from repro.kernels.gossip_mix import ops as mix_ops

        return jnp.sum(mix_ops.packed_sqnorms(wire, block_rows=block_rows,
                                              impl=impl))

    # per-leaf baseline hooks
    def encode_leaf(self, x, impl):
        return (x,)

    def decode_leaf(self, parts, dtype, impl):
        return parts[0]


class _Int8Codec:
    """int8 wire payloads: quantize through the Pallas kernels, bitcast the
    f32 scale(s) into trailing lane rows of the SAME shipped buffer (one
    collective per schedule), and fold each received wire into the
    accumulator through the fused dequant-accumulate kernels. The local term
    stays full precision, so the int8 error only enters through the (small,
    renormalized) edge weights."""

    identity_wire = False
    stateful = False

    def __init__(self, block_scales: bool):
        self.block_scales = block_scales
        self.name = "int8_block" if block_scales else "int8"

    def _tail_rows(self, n_blocks: int) -> int:
        return packing.scale_rows(n_blocks) if self.block_scales else 1

    def wire_struct(self, struct: jax.ShapeDtypeStruct,
                    n_blocks: int) -> jax.ShapeDtypeStruct:
        rows = struct.shape[0] + self._tail_rows(n_blocks)
        return jax.ShapeDtypeStruct((rows, packing.LANE), jnp.int8)

    def encode(self, buf, *, n_blocks, block_rows, impl):
        from repro.kernels.quant_gossip import ops as qops

        if self.block_scales:
            q, scales = qops.quantize_packed_blockwise(
                buf, block_rows=block_rows, impl=impl)
            return qops.fold_scales_into_wire(q, scales)
        q, scale = qops.quantize_packed(buf, block_rows=block_rows, impl=impl)
        return qops.fold_scale_into_wire(q, scale)

    def decode(self, wire, dtype, *, n_blocks, block_rows):
        """Plain dequantize (the stacked substrate's gather source); the
        shard_map substrate never materializes this — it uses the fused
        :meth:`reduce` accumulation instead."""
        from repro.kernels.quant_gossip import ops as qops

        if self.block_scales:
            q, scales = qops.split_wire_blockwise(wire, n_blocks)
            return qops.dequantize_packed_blockwise(q, scales, dtype,
                                                    block_rows=block_rows)
        q, scale = qops.split_wire(wire)
        return qops.dequantize_packed(q, scale, dtype)

    def reduce(self, fresh, received, weights, contrib, *, edge_weight,
               n_blocks, block_rows, impl, sender_scale=None):
        from repro.kernels.quant_gossip import ops as qops

        c = edge_weight
        if contrib is None:
            self_scale = weights[0]
            recv_w = [None] * len(received)
        else:
            a_self, src_a = contrib[0], contrib[1:]
            wa0 = weights[0] * a_self
            tot = wa0 + c * jnp.sum(src_a)
            # no renormalizable mass => identity row REPLACES the
            # renormalized term (inv zeroed, so tiny fractional mass cannot
            # double-count)
            ok = (tot > 1e-12).astype(jnp.float32)
            inv = ok / jnp.maximum(tot, 1e-12)
            self_scale = (a_self * wa0 * inv + (1.0 - a_self)
                          + a_self * (1.0 - ok))
            recv_w = [a_self * src_a[k] * inv for k in range(len(received))]
        if sender_scale is not None:
            # norm-clip folds into the per-sender weight operand of the
            # fused dequant-accumulate — post-renormalization, so the
            # alive/gates denominator above is untouched
            recv_w = [sender_scale[k] if a is None else a * sender_scale[k]
                      for k, a in enumerate(recv_w)]
        acc = self_scale.astype(fresh.dtype) * fresh
        for rwire, a in zip(received, recv_w):
            if self.block_scales:
                rq, rs = qops.split_wire_blockwise(rwire, n_blocks)
                acc = qops.dequant_accumulate_packed_blockwise(
                    rq, rs, c, acc, a, block_rows=block_rows, impl=impl)
            else:
                rq, rs = qops.split_wire(rwire)
                acc = qops.dequant_accumulate_packed(
                    rq, rs, c, acc, a, block_rows=block_rows, impl=impl)
        return acc

    def reduce_trimmed(self, fresh, received, u, live, *, trim, n_blocks,
                       block_rows, impl):
        from repro.kernels.gossip_mix import ops as mix_ops
        from repro.kernels.quant_gossip import ops as qops

        if self.block_scales:
            pairs = [qops.split_wire_blockwise(w, n_blocks)
                     for w in received]
            scales = jnp.stack([s for _, s in pairs])          # (d, n_blocks)
        else:
            pairs = [qops.split_wire(w) for w in received]
            scales = jnp.stack([s.reshape(1) for _, s in pairs])  # (d, 1)
        qstack = jnp.stack([q for q, _ in pairs])
        return mix_ops.gossip_mix_trimmed_quant_packed(
            fresh, qstack, scales, u, live, trim=trim,
            block_rows=block_rows, impl=impl)

    def wire_sqnorm(self, wire, *, n_blocks, block_rows, impl):
        from repro.kernels.gossip_mix import ops as mix_ops
        from repro.kernels.quant_gossip import ops as qops

        # decoded-payload norm straight off the int8 wire: per-block
        # sum(q^2) x scale^2 (exact for what the mix would dequantize)
        if self.block_scales:
            q, scales = qops.split_wire_blockwise(wire, n_blocks)
            part = mix_ops.packed_sqnorms(q.astype(jnp.float32),
                                          block_rows=block_rows, impl=impl)
            return jnp.sum(part * scales.astype(jnp.float32) ** 2)
        q, scale = qops.split_wire(wire)
        part = mix_ops.packed_sqnorms(q.astype(jnp.float32),
                                      block_rows=block_rows, impl=impl)
        return scale.astype(jnp.float32) ** 2 * jnp.sum(part)

    # per-leaf baseline hooks (per-tensor scale; no tile alignment)
    def encode_leaf(self, x, impl):
        from repro.kernels.quant_gossip import ops as qops

        return qops.quantize_int8(x, impl=impl)

    def decode_leaf(self, parts, dtype, impl):
        from repro.kernels.quant_gossip import ops as qops

        return qops.dequantize_int8(parts[0], parts[1], dtype, impl=impl)


class TopKEFCodec:
    """Sparse top-k wire with error feedback — the first STATEFUL codec.

    The WireCodec contract grows three optional hooks for codecs that carry
    per-client state across rounds (all declared via class attrs / methods,
    never via executor special-casing):

    * ``stateful = True`` — the executor threads a per-buffer state operand
      through the round and returns the updated state right after the delay
      snapshot (a donated step input, exactly like the in-flight wire);
    * ``state_struct(struct, n_blocks)`` — the per-client state layout for
      one packed buffer (here: an f32 residual shaped like the payload);
    * ``init_state(struct)`` — the priming value (zeros: nothing dropped
      yet); :meth:`GossipExecutor.init_codec_state` maps it over the pack
      spec (with the client axis in front on the stacked substrate, so a
      splice repair remaps the state by the same old2new row take as the
      params and the in-flight snapshot).

    Encode is ``ef_compress`` on the packed ``(rows, 128)`` buffer: add the
    residual, keep the k = max(1, floor(k_fraction * rows * 128)) largest-
    magnitude entries, remember what was dropped. The wire is the k f32
    values with their k int32 flat indices lane-folded into ONE int8 buffer
    (:func:`repro.kernels.quant_gossip.ops.fold_topk_into_wire`), so each
    schedule still ships a single collective of ~8k bytes — ~2 *
    k_fraction of the dense f32 wire. Reduce folds each received wire into
    the accumulator through the fused scatter-accumulate Pallas kernel
    (``scatter_accumulate_2d``), one dense HBM pass per wire like the int8
    path. The self row stays the FRESH full-precision buffer everywhere, so
    sparsification error only enters through the received edges (and is
    re-injected next round by the sender's residual).
    """

    identity_wire = False
    stateful = True

    def __init__(self, k_fraction: float, name: str = "topk_ef"):
        if not 0.0 < float(k_fraction) <= 1.0:
            raise ValueError("k_fraction must be in (0, 1], got "
                             f"{k_fraction}")
        self.k_fraction = float(k_fraction)
        self.name = name

    def k_for(self, rows: int) -> int:
        """ef_compress's k on a (rows, LANE) packed buffer."""
        return max(1, int(self.k_fraction * rows * packing.LANE))

    def wire_struct(self, struct: jax.ShapeDtypeStruct,
                    n_blocks: int) -> jax.ShapeDtypeStruct:
        rows = packing.topk_wire_rows(self.k_for(struct.shape[0]))
        return jax.ShapeDtypeStruct((rows, packing.LANE), jnp.int8)

    def state_struct(self, struct: jax.ShapeDtypeStruct,
                     n_blocks: int) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(struct.shape, jnp.float32)

    def init_state(self, struct: jax.ShapeDtypeStruct) -> jax.Array:
        return jnp.zeros(struct.shape, jnp.float32)

    def encode(self, buf, *, n_blocks, block_rows, impl, state):
        from repro.core import compression
        from repro.kernels.quant_gossip import ops as qops

        y = buf.astype(jnp.float32) + state
        vals, idx = compression.topk_sparsify(y, self.k_for(buf.shape[0]))
        dense = (jnp.zeros(y.size, jnp.float32).at[idx].set(vals)
                 .reshape(y.shape))
        return qops.fold_topk_into_wire(vals, idx), y - dense

    def decode(self, wire, dtype, *, n_blocks, block_rows):
        """Scatter back to dense (the stacked substrate's gather source);
        the shard_map substrate never materializes this — it uses the fused
        :meth:`reduce` scatter-accumulation instead."""
        from repro.kernels.quant_gossip import ops as qops

        rows = n_blocks * block_rows
        vals, idx = qops.split_topk_wire(wire, self.k_for(rows))
        dense = jnp.zeros(rows * packing.LANE, jnp.float32).at[idx].set(vals)
        return dense.reshape(rows, packing.LANE).astype(dtype)

    def reduce(self, fresh, received, weights, contrib, *, edge_weight,
               n_blocks, block_rows, impl, sender_scale=None):
        from repro.kernels.quant_gossip import ops as qops

        c = edge_weight
        if contrib is None:
            self_scale = weights[0]
            recv_w = [None] * len(received)
        else:
            a_self, src_a = contrib[0], contrib[1:]
            wa0 = weights[0] * a_self
            tot = wa0 + c * jnp.sum(src_a)
            # no renormalizable mass => identity row REPLACES the
            # renormalized term (same fallback as the int8 reduce)
            ok = (tot > 1e-12).astype(jnp.float32)
            inv = ok / jnp.maximum(tot, 1e-12)
            self_scale = (a_self * wa0 * inv + (1.0 - a_self)
                          + a_self * (1.0 - ok))
            recv_w = [a_self * src_a[k] * inv for k in range(len(received))]
        if sender_scale is not None:
            recv_w = [sender_scale[k] if a is None else a * sender_scale[k]
                      for k, a in enumerate(recv_w)]
        k_top = self.k_for(n_blocks * block_rows)
        acc = self_scale.astype(fresh.dtype) * fresh
        for rwire, a in zip(received, recv_w):
            vals, idx = qops.split_topk_wire(rwire, k_top)
            acc = qops.scatter_accumulate_packed(
                vals, idx, c, acc, a, block_rows=block_rows, impl=impl)
        return acc

    def wire_sqnorm(self, wire, *, n_blocks, block_rows, impl):
        from repro.kernels.quant_gossip import ops as qops

        vals, _ = qops.split_topk_wire(wire,
                                       self.k_for(n_blocks * block_rows))
        return jnp.sum(vals.astype(jnp.float32) ** 2)
    # no reduce_trimmed / encode_leaf hooks: screens and the per-leaf
    # baseline are rejected for stateful codecs at config validation.


# ------------------------------------------------------------ registry
# Codecs plug in by NAME: config validation, the trainers' front door, the
# legacy-knob shims and the wire-byte accounting all consult this registry,
# so a new codec (including out-of-tree ones) never edits the engine body.
_CODECS: dict[str, Any] = {}
CODECS: tuple[str, ...] = ()


def register_codec(name: str, codec) -> Any:
    """Register a WireCodec instance under ``name`` (last write wins).

    ``codec`` follows the duck-typed WireCodec contract (wire_struct /
    encode / decode / reduce / wire_sqnorm, plus the optional stateful
    hooks — see :class:`TopKEFCodec`). After registration the name is valid
    anywhere a codec is spelled: ``GossipEngineConfig(codec=name)``, the
    trainers' ``engine=`` front door, and the benches' wire accounting.
    """
    global CODECS
    if not name or not isinstance(name, str):
        raise ValueError(f"codec name must be a non-empty string, got "
                         f"{name!r}")
    _CODECS[name] = codec
    CODECS = tuple(_CODECS)
    return codec


def get_codec(name: str):
    """Public codec lookup (benches/tests derive wire shapes from it)."""
    if name not in _CODECS:
        raise ValueError(f"unknown codec {name!r}; available: "
                         f"{', '.join(CODECS)}")
    return _CODECS[name]


register_codec("f32", _F32Codec())
register_codec("int8", _Int8Codec(block_scales=False))
register_codec("int8_block", _Int8Codec(block_scales=True))
register_codec("topk_ef", TopKEFCodec(k_fraction=0.01))


# --------------------------------------------------------------- executor
@dataclasses.dataclass(frozen=True)
class GossipExecutor:
    """One assembled gossip round. Call signature by timing:

    * sync: ``executor(tree, alive=..., gates=...) -> mixed_tree``
    * delayed: ``executor(tree, state=..., alive=..., gates=...) ->
      (mixed_tree, new_state)`` where ``state`` is the codec-wire snapshot
      of the previous round (prime it with :meth:`init_state`).

    A STATEFUL codec (``codec.stateful``, e.g. ``topk_ef``'s EF residual)
    adds one more threaded operand: pass ``codec_state=...`` (prime it with
    :meth:`init_codec_state`) and the updated per-buffer state tuple is
    returned right AFTER the delay snapshot (``(mixed, new_codec_state)``
    sync, ``(mixed, new_state, new_codec_state)`` delayed). Like the
    snapshot, codec state is step data in the codec's ``state_struct``
    layout — donated, remapped through splice repair by the same old2new
    row compaction, never trace structure.

    With ``config.sub_rounds = k > 1`` (Chebyshev multi-round gossip) the
    call takes one more traced operand: ``cheby=...``, the (k,) f32
    per-sub-round coefficient vector (host-side source:
    :meth:`cheby_coeffs`, which reads the baked ``spec.lam``). Like
    alive/gates it is data — recomputing it after a splice repair or
    sweeping it across rounds retraces nothing. The k=1 cell takes no such
    operand and IS the sync engine (build-time branch, delay=0 style).

    With ``config.telemetry`` set, a RoundMetrics dict of traced values is
    appended as the LAST element of the return tuple (``(mixed, metrics)``
    sync, ``(mixed, new_state, metrics)`` delayed); :meth:`metrics_structs`
    declares its exact key set and shapes. Telemetry never changes the
    collectives or the trace structure — ``telemetry=None`` builds lower to
    HLO textually identical to pre-telemetry anchors.

    ``tree`` is the client-local shard pytree on the ``shard_map`` /
    ``per_leaf`` substrates (call inside the island), the client-stacked
    pytree on ``stacked`` / ``dense``, and the device-local ``(block, ...)``
    stacked slice on ``blocked`` (call inside the island over a 1-D client
    device axis; a ``P(axis)`` sharding of the stacked tree IS that slice).
    ``alive`` / ``gates`` are traced data on the packed substrates — on
    ``blocked`` they stay full-length replicated ``(n,)`` / ``(S,)``
    vectors, the executor slices its own device's rows (``per_leaf`` and
    ``dense``-with-gates follow the legacy conventions: per-leaf ignores
    both).
    """

    config: GossipEngineConfig
    spec: GossipSpec
    axis_names: Any = None
    pack_spec: packing.PackSpec | None = None
    blocked: gossip.BlockedSpec | None = None

    @property
    def delayed(self) -> bool:
        return self.config.delay == 1

    @property
    def codec(self):
        return _CODECS[self.config.codec]

    @property
    def stateful(self) -> bool:
        """Whether this executor threads per-client codec state."""
        return bool(getattr(self.codec, "stateful", False))

    def __call__(self, tree: PyTree, *, state=None, codec_state=None,
                 alive=None, gates=None, cheby=None):
        cfg = self.config
        if self.delayed and state is None:
            raise ValueError("delayed executor needs the carried snapshot "
                             "(prime it with init_state)")
        if self.stateful and codec_state is None:
            raise ValueError(f"codec {cfg.codec!r} is stateful and needs "
                             "its per-client codec state (prime it with "
                             "init_codec_state)")
        if not self.stateful and codec_state is not None:
            raise ValueError(f"codec {cfg.codec!r} carries no codec state; "
                             "drop the codec_state operand")
        if cfg.sub_rounds > 1 and cheby is None:
            raise ValueError(
                f"sub_rounds={cfg.sub_rounds} needs the (sub_rounds,) "
                "per-sub-round Chebyshev coefficient operand (build it "
                "with cheby_coeffs / spectral.chebyshev_omegas)")
        if cfg.sub_rounds == 1 and cheby is not None:
            raise ValueError(
                "cheby coefficients are a sub_rounds > 1 operand; the "
                "sub_rounds=1 cell is the sync engine — drop the operand")
        if cfg.substrate == "dense":
            return gossip.mix_dense(
                tree, gossip.gated_mixing_matrix(self.spec, gates, alive))
        if cfg.substrate == "per_leaf":
            return self._per_leaf_round(tree)
        if cfg.substrate == "stacked":
            if cfg.sub_rounds > 1:
                return self._stacked_round_cheby(tree, alive, gates, cheby)
            return self._stacked_round(tree, state, codec_state, alive,
                                       gates)
        if cfg.substrate == "blocked":
            return self._blocked_round(tree, alive, gates)
        if cfg.sub_rounds > 1:
            return self._shard_map_round_cheby(tree, alive, gates, cheby)
        return self._shard_map_round(tree, state, codec_state, alive, gates)

    # ------------------------------------------------- pipelined state
    def init_state(self, tree: PyTree) -> tuple[jax.Array, ...]:
        """Prime the pipeline: the codec-wire snapshot of ``tree`` (round 0
        then mixes the initial params as its delayed snapshot — the
        ``mix_dense_delayed`` y_{-1} := x_0 convention). The snapshot layout
        depends only on the parameter structure, never on the topology, so
        a splice repair remaps it by the same old2new row compaction as the
        params."""
        cfg, codec = self.config, self.codec

        def enc(x, b, pack_spec):
            kw = dict(n_blocks=pack_spec.buffer_blocks(b),
                      block_rows=pack_spec.block_rows, impl=cfg.mix_impl)
            if self.stateful:
                # prime against a zero residual; the priming residual is
                # discarded (init_codec_state owns the carried zeros) — the
                # y_{-1} := x_0 snapshot is the one EF-unfed wire
                wire, _ = codec.encode(
                    x, state=jnp.zeros(x.shape, jnp.float32), **kw)
                return wire
            return codec.encode(x, **kw)

        if cfg.substrate == "stacked":
            pack_spec = self.pack_spec or gossip._stacked_pack_spec(tree)
            bufs = jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)
            return tuple(
                jax.vmap(lambda x, b=b: enc(x, b, pack_spec))(buf)
                for b, buf in enumerate(bufs))
        pack_spec = self.pack_spec or packing.make_pack_spec(tree)
        return tuple(
            enc(buf, b, pack_spec)
            for b, buf in enumerate(packing.pack_tree(tree, pack_spec)))

    def state_structs(self) -> tuple[jax.ShapeDtypeStruct, ...]:
        """Per-device wire shapes of the carried snapshot (requires a baked
        ``pack_spec``) — what the production step declares as its donated
        in-flight argument."""
        if self.pack_spec is None:
            raise ValueError("state_structs needs a baked pack_spec")
        ps, codec = self.pack_spec, self.codec
        return tuple(
            codec.wire_struct(ps.buffer_struct(b), ps.buffer_blocks(b))
            for b in range(ps.n_buffers))

    # ------------------------------------------------- codec state
    def init_codec_state(self, tree: PyTree) -> tuple[jax.Array, ...]:
        """Prime the per-client codec state (``codec.init_state`` per packed
        buffer — the topk_ef EF residual starts at zeros: nothing dropped
        yet). On the stacked substrate the client axis rides in front, so a
        splice repair remaps this state by the same old2new row take as the
        params and the in-flight snapshot."""
        cfg, codec = self.config, self.codec
        if not self.stateful:
            raise ValueError(f"codec {cfg.codec!r} carries no codec state")
        if cfg.substrate == "stacked":
            pack_spec = self.pack_spec or gossip._stacked_pack_spec(tree)
            n = jax.tree.leaves(tree)[0].shape[0]
            return tuple(
                jnp.zeros((n,) + st.shape, st.dtype)
                for st in (codec.state_struct(pack_spec.buffer_struct(b),
                                              pack_spec.buffer_blocks(b))
                           for b in range(pack_spec.n_buffers)))
        pack_spec = self.pack_spec or packing.make_pack_spec(tree)
        return tuple(
            codec.init_state(pack_spec.buffer_struct(b))
            for b in range(pack_spec.n_buffers))

    def codec_state_structs(self) -> tuple[jax.ShapeDtypeStruct, ...]:
        """Per-device codec-state shapes (requires a baked ``pack_spec``) —
        what the production step declares as its donated codec-state
        argument."""
        if self.pack_spec is None:
            raise ValueError("codec_state_structs needs a baked pack_spec")
        if not self.stateful:
            raise ValueError(f"codec {self.config.codec!r} carries no "
                             "codec state")
        ps, codec = self.pack_spec, self.codec
        return tuple(
            codec.state_struct(ps.buffer_struct(b), ps.buffer_blocks(b))
            for b in range(ps.n_buffers))

    # ------------------------------------------------- cheby coefficients
    def cheby_coeffs(self):
        """Host-side (sub_rounds,) f32 Chebyshev coefficient vector for the
        baked spec's lambda(M) — the value the ``cheby=`` operand ships.
        Recompute after a splice repair (the rebuilt executor carries the
        new spec.lam); the shape only depends on ``config.sub_rounds``, so
        the refreshed values never retrace."""
        from repro.core import spectral

        return spectral.chebyshev_omegas(self.spec.lam,
                                         self.config.sub_rounds)

    # ----------------------------------------------------- telemetry
    def metrics_structs(self) -> dict:
        """ShapeDtypeStructs of the RoundMetrics this executor returns —
        the key set is fixed by (telemetry, screen, substrate) at build
        time ({} when telemetry is off). Stacked metrics are client-stacked
        arrays; shard_map metrics are per-DEVICE locals (the caller's
        island sums them host-side — see repro.telemetry.metrics); blocked
        metrics are the device-local (block,)-leading rows (an island
        out_spec over the client device axis concatenates them back to the
        stacked layout)."""
        tel = self.config.telemetry
        if tel is None:
            return {}
        out = {}
        if self.config.substrate in ("stacked", "blocked"):
            n = (self.config.block if self.config.substrate == "blocked"
                 else self.spec.n_clients)
            n_sched = len(self.spec.recv_from)
            if tel.consensus:
                out["resid_sqnorm"] = jax.ShapeDtypeStruct((n,), jnp.float32)
            if tel.degree:
                out["in_degree"] = jax.ShapeDtypeStruct((n,), jnp.float32)
                out["sched_contrib"] = jax.ShapeDtypeStruct((n, n_sched),
                                                            jnp.float32)
            if tel.clip and self.config.screen == "norm_clip":
                out["clipped"] = jax.ShapeDtypeStruct((n,), jnp.int32)
        else:  # shard_map
            n_sched = len(gossip._live_schedules(self.spec))
            if tel.consensus:
                out["resid_sqnorm"] = jax.ShapeDtypeStruct((), jnp.float32)
            if tel.degree:
                out["in_degree"] = jax.ShapeDtypeStruct((), jnp.float32)
                out["sched_contrib"] = jax.ShapeDtypeStruct((n_sched,),
                                                            jnp.float32)
            if tel.clip and self.config.screen == "norm_clip":
                out["clip_recv"] = jax.ShapeDtypeStruct((), jnp.int32)
        return out

    def wire_bytes_per_round(self) -> int:
        """EXACT wire bytes one client ships per round: one codec wire per
        live schedule per packed buffer PER SUB-ROUND, from the same
        ``wire_struct`` shapes the collectives move (requires a baked
        ``pack_spec``; the dense reference substrate has no wire => 0).
        ``sub_rounds=k`` multiplies the wire k-fold — the cost side of the
        Chebyshev rounds-to-threshold trade the benches measure."""
        if self.config.substrate == "dense":
            return 0
        if self.pack_spec is None:
            raise ValueError("wire_bytes_per_round needs a baked pack_spec")
        if self.config.substrate == "per_leaf":
            raise ValueError("per-leaf wires are per-tensor, not packed; "
                             "wire accounting covers the packed substrates")
        ps, codec = self.pack_spec, self.codec
        per_sched = 0
        for b in range(ps.n_buffers):
            st = codec.wire_struct(ps.buffer_struct(b), ps.buffer_blocks(b))
            per_sched += math.prod(st.shape) * jnp.dtype(st.dtype).itemsize
        return (len(gossip._live_schedules(self.spec)) * per_sched
                * self.config.sub_rounds)

    def _sq(self, pack_spec):
        """Whole-buffer squared-norm closure through the fused per-block
        pass (the telemetry consensus metric's accumulator)."""
        from repro.kernels.gossip_mix import ops as mix_ops

        def sq(x):
            return jnp.sum(mix_ops.packed_sqnorms(
                x.astype(jnp.float32), block_rows=pack_spec.block_rows,
                impl=self.config.mix_impl))

        return sq

    # ---------------------------------------------------- substrates
    def _shard_map_round(self, tree, state, cstate, alive, gates):
        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        pack_spec = self.pack_spec or packing.make_pack_spec(tree)
        idx = gossip._client_index(self.axis_names)
        live = gossip._live_schedules(spec)
        perms = [p for _, p, _, _ in live]
        weights = gossip._local_raw_weights(spec, idx, len(perms), gates)
        # the trimmed screen ALWAYS builds the contributor vector: fixed
        # points deliver zeros on this substrate and must stay invisible to
        # the order statistics even with no alive/gates overlay
        contrib = (None if alive is None and gates is None
                   and cfg.screen != "trimmed_mean"
                   else gossip._local_contrib_vec(spec, idx, live, alive,
                                                  gates))
        # telemetry reads contributor mass through its OWN vector when the
        # reduce path runs contrib-less — forcing one into codec.reduce
        # would change the lowered arithmetic (renorm ops), and telemetry
        # must never touch the mixing HLO
        tcontrib = None
        if tel is not None:
            tcontrib = (contrib if contrib is not None
                        else gossip._local_contrib_vec(spec, idx, live,
                                                       alive, gates))
        if cfg.screen == "norm_clip":
            return self._shard_map_round_clipped(tree, state, weights,
                                                 contrib, pack_spec, perms,
                                                 tcontrib)
        if cfg.screen == "trimmed_mean":
            trim_u = jnp.maximum(weights, 0.0) * contrib
            trim_live = (contrib > 0.0).astype(jnp.float32)
        metrics = {}
        if tel is not None and tel.degree:
            metrics["in_degree"] = jnp.sum(tcontrib[1:])
            metrics["sched_contrib"] = tcontrib[1:]
        resid = jnp.float32(0.0)
        sq = self._sq(pack_spec)
        out_bufs, new_state, new_cstate = [], [], []
        for b, buf in enumerate(packing.pack_tree(tree, pack_spec)):
            n_blocks = pack_spec.buffer_blocks(b)
            if self.stateful:
                # the codec updates its per-client state exactly once per
                # round, at encode; with delay the permutes still read the
                # carried snapshot while the fresh wire becomes next
                # round's snapshot (sparse pipelined gossip: the donated
                # in-flight buffer IS the ~k-fold smaller codec wire)
                wire_fresh, res = codec.encode(
                    buf, n_blocks=n_blocks, block_rows=pack_spec.block_rows,
                    impl=cfg.mix_impl, state=cstate[b])
                new_cstate.append(res)
                wire = state[b] if cfg.delay else wire_fresh
                if cfg.delay:
                    new_state.append(wire_fresh)
            elif cfg.delay:
                # the permutes read the carried snapshot (a step input): no
                # dep on the local-step scan, so the scheduler can start
                # them at program entry and hide the wire behind compute
                wire = state[b]
                new_state.append(codec.encode(
                    buf, n_blocks=n_blocks, block_rows=pack_spec.block_rows,
                    impl=cfg.mix_impl))
            else:
                wire = codec.encode(buf, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)
            # all ppermutes issued before the reduction so XLA can overlap
            received = [jax.lax.ppermute(wire, self.axis_names, perm=p)
                        for p in perms]
            if tel is not None and tel.consensus:
                # consensus proxy over THIS shard: what each neighbor wire
                # dequantizes to, against the local fresh buffer
                for s, rwire in enumerate(received):
                    dec = codec.decode(rwire, buf.dtype, n_blocks=n_blocks,
                                       block_rows=pack_spec.block_rows)
                    resid = resid + tcontrib[1 + s] * sq(
                        dec.astype(jnp.float32) - buf.astype(jnp.float32))
            if cfg.screen == "trimmed_mean":
                out_bufs.append(codec.reduce_trimmed(
                    buf, received, trim_u, trim_live, trim=cfg.trim_f,
                    n_blocks=n_blocks, block_rows=pack_spec.block_rows,
                    impl=cfg.mix_impl))
            else:
                out_bufs.append(codec.reduce(
                    buf, received, weights, contrib,
                    edge_weight=float(spec.edge_weight), n_blocks=n_blocks,
                    block_rows=pack_spec.block_rows, impl=cfg.mix_impl))
        if tel is not None and tel.consensus:
            metrics["resid_sqnorm"] = resid
        mixed = packing.unpack_tree(tuple(out_bufs), pack_spec)
        ret = (mixed,)
        if cfg.delay:
            ret = ret + (tuple(new_state),)
        if self.stateful:
            ret = ret + (tuple(new_cstate),)
        if tel is not None:
            ret = ret + (metrics,)
        return ret[0] if len(ret) == 1 else ret

    def _shard_map_round_clipped(self, tree, state, weights, contrib,
                                 pack_spec, perms, tcontrib=None):
        """norm_clip needs whole-model norms, so the round splits into an
        encode+permute pass (all collectives still issued up front — the
        wire is byte-identical to the unscreened round), one tiny norm
        reduction per wire, and the per-buffer fused reduce with the clip
        folded into the received weight operands."""
        from repro.kernels.gossip_mix import ops as mix_ops

        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        fresh = list(packing.pack_tree(tree, pack_spec))
        wires, new_state = [], []
        s2 = jnp.float32(0.0)
        for b, buf in enumerate(fresh):
            n_blocks = pack_spec.buffer_blocks(b)
            if cfg.delay:
                wire = state[b]
                new_state.append(codec.encode(
                    buf, n_blocks=n_blocks, block_rows=pack_spec.block_rows,
                    impl=cfg.mix_impl))
            else:
                wire = codec.encode(buf, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)
            wires.append(wire)
            s2 = s2 + jnp.sum(mix_ops.packed_sqnorms(
                buf, block_rows=pack_spec.block_rows, impl=cfg.mix_impl))
        received = [[jax.lax.ppermute(wire, self.axis_names, perm=p)
                     for p in perms] for wire in wires]
        r2 = [sum(codec.wire_sqnorm(received[b][k],
                                    n_blocks=pack_spec.buffer_blocks(b),
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)
                  for b in range(len(fresh)))
              for k in range(len(perms))]
        clip = (_clip_factors(jnp.stack(r2), cfg.clip_tau ** 2 * s2)
                if r2 else jnp.zeros((0,), jnp.float32))
        metrics = {}
        if tel is not None:
            if tel.degree:
                metrics["in_degree"] = jnp.sum(tcontrib[1:])
                metrics["sched_contrib"] = tcontrib[1:]
            if tel.consensus:
                sq = self._sq(pack_spec)
                resid = jnp.float32(0.0)
                for b, buf in enumerate(fresh):
                    for k in range(len(perms)):
                        dec = codec.decode(
                            received[b][k], buf.dtype,
                            n_blocks=pack_spec.buffer_blocks(b),
                            block_rows=pack_spec.block_rows)
                        resid = resid + tcontrib[1 + k] * sq(
                            dec.astype(jnp.float32)
                            - buf.astype(jnp.float32))
                metrics["resid_sqnorm"] = resid
            if tel.clip:
                # LOCAL per-receiver count of incoming wires this client
                # clipped (a per-sender count here would need a reverse
                # collective; the stacked substrate has the global view)
                metrics["clip_recv"] = jnp.sum(
                    ((clip < 1.0) & (tcontrib[1:] > 0.0)).astype(jnp.int32))
        out_bufs = [
            codec.reduce(buf, received[b], weights, contrib,
                         edge_weight=float(spec.edge_weight),
                         n_blocks=pack_spec.buffer_blocks(b),
                         block_rows=pack_spec.block_rows, impl=cfg.mix_impl,
                         sender_scale=clip)
            for b, buf in enumerate(fresh)]
        mixed = packing.unpack_tree(tuple(out_bufs), pack_spec)
        ret = (mixed,)
        if cfg.delay:
            ret = ret + (tuple(new_state),)
        if tel is not None:
            ret = ret + (metrics,)
        return ret[0] if len(ret) == 1 else ret

    def _shard_map_round_cheby(self, tree, alive, gates, cheby):
        """Chebyshev multi-round gossip (sub_rounds = k > 1), shard_map.

        The traced ``cheby`` operand carries the (k,) per-sub-round weights
        (:func:`repro.core.spectral.chebyshev_omegas`) — plain data, so a
        splice repair's refreshed lambda never retraces. Each sub-round
        reuses the sync round's exact d-ppermute + fused-reduce structure
        (k*d collectives per round, HLO-counted by the anchor tests) and the
        second-order combine

            x^(j+1) = cheby[j] * (W x^(j) - x^(j-1)) + x^(j-1)

        with x^(-1) := x^(0) runs in f32 on the packed buffers. Weights /
        contributor vectors are computed once and reused every sub-round —
        the same W each application, exactly the ``mixing.chebyshev_mix``
        dense oracle. Telemetry (when on) measures the FIRST sub-round —
        the wires the k=1 cell would ship — so metrics stay comparable
        across the sub_rounds axis."""
        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        pack_spec = self.pack_spec or packing.make_pack_spec(tree)
        idx = gossip._client_index(self.axis_names)
        live = gossip._live_schedules(spec)
        perms = [p for _, p, _, _ in live]
        weights = gossip._local_raw_weights(spec, idx, len(perms), gates)
        contrib = (None if alive is None and gates is None
                   else gossip._local_contrib_vec(spec, idx, live, alive,
                                                  gates))
        tcontrib = None
        if tel is not None:
            tcontrib = (contrib if contrib is not None
                        else gossip._local_contrib_vec(spec, idx, live,
                                                       alive, gates))
        omg = jnp.asarray(cheby, jnp.float32)
        metrics = {}
        if tel is not None and tel.degree:
            metrics["in_degree"] = jnp.sum(tcontrib[1:])
            metrics["sched_contrib"] = tcontrib[1:]
        resid = jnp.float32(0.0)
        sq = self._sq(pack_spec)
        out_bufs = []
        for b, buf in enumerate(packing.pack_tree(tree, pack_spec)):
            n_blocks = pack_spec.buffer_blocks(b)
            x_prev = buf.astype(jnp.float32)
            x_cur = x_prev
            for j in range(cfg.sub_rounds):
                xj = x_cur.astype(buf.dtype)
                wire = codec.encode(xj, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)
                received = [jax.lax.ppermute(wire, self.axis_names, perm=p)
                            for p in perms]
                if j == 0 and tel is not None and tel.consensus:
                    for s, rwire in enumerate(received):
                        dec = codec.decode(rwire, buf.dtype,
                                           n_blocks=n_blocks,
                                           block_rows=pack_spec.block_rows)
                        resid = resid + tcontrib[1 + s] * sq(
                            dec.astype(jnp.float32)
                            - xj.astype(jnp.float32))
                y = codec.reduce(
                    xj, received, weights, contrib,
                    edge_weight=float(spec.edge_weight), n_blocks=n_blocks,
                    block_rows=pack_spec.block_rows,
                    impl=cfg.mix_impl).astype(jnp.float32)
                # dead self => y == x^(j) (identity fallback), and the
                # recurrence fixes the whole orbit: dead clients keep params
                x_next = omg[j] * (y - x_prev) + x_prev
                x_prev, x_cur = x_cur, x_next
            out_bufs.append(x_cur.astype(buf.dtype))
        if tel is not None and tel.consensus:
            metrics["resid_sqnorm"] = resid
        mixed = packing.unpack_tree(tuple(out_bufs), pack_spec)
        if tel is not None:
            return mixed, metrics
        return mixed

    def _stacked_round(self, tree, state, cstate, alive, gates):
        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        pack_spec = self.pack_spec or gossip._stacked_pack_spec(tree)
        if cfg.screen != "none":
            return self._stacked_round_screened(tree, state, alive, gates,
                                                pack_spec)
        w = (gossip._static_weight_table(spec)
             if alive is None and gates is None
             else gossip.alive_weight_table(spec, alive, gates))
        gathers = [jnp.asarray(rf) for rf in spec.recv_from]
        fresh = jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)
        metrics, tcontrib = self._stacked_metrics_init(alive, gates)
        resid = jnp.zeros((spec.n_clients,), jnp.float32)
        sq = jax.vmap(self._sq(pack_spec))
        out_bufs, new_state, new_cstate = [], [], []
        for b, buf in enumerate(fresh):
            n_blocks = pack_spec.buffer_blocks(b)

            def enc(x, b=b):
                return codec.encode(x, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)

            def dec(x, n_blocks=n_blocks, dtype=buf.dtype):
                return codec.decode(x, dtype, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows)

            if self.stateful:
                # per-client encode updates the codec state exactly once
                # per round; with delay the gathers read the carried
                # snapshot while the fresh wire becomes next round's
                wire, res = jax.vmap(
                    lambda x, r, b=b: codec.encode(
                        x, n_blocks=n_blocks,
                        block_rows=pack_spec.block_rows,
                        impl=cfg.mix_impl, state=r))(buf, cstate[b])
                new_cstate.append(res)
                src = jax.vmap(dec)(state[b] if cfg.delay else wire)
                if cfg.delay:
                    new_state.append(wire)
            elif codec.identity_wire:
                src = state[b] if cfg.delay else buf
            else:
                wire = state[b] if cfg.delay else jax.vmap(enc)(buf)
                src = jax.vmap(dec)(wire)
            # self row stays the FRESH full-precision buffer; only the
            # gathered neighbor rows go through the codec / the snapshot
            stack = jnp.stack([buf] + [jnp.take(src, idx, axis=0)
                                       for idx in gathers], axis=1)
            out = jnp.einsum("nk,nk...->n...", w, stack.astype(jnp.float32))
            out_bufs.append(out.astype(buf.dtype))
            if tel is not None and tel.consensus:
                for s in range(len(gathers)):
                    resid = resid + tcontrib[:, 1 + s] * sq(
                        stack[:, 1 + s].astype(jnp.float32)
                        - buf.astype(jnp.float32))
            if cfg.delay and not self.stateful:
                new_state.append(buf if codec.identity_wire
                                 else jax.vmap(enc)(buf))
        if tel is not None and tel.consensus:
            metrics["resid_sqnorm"] = resid
        mixed = jax.vmap(lambda bs: packing.unpack_tree(bs, pack_spec))(
            tuple(out_bufs))
        ret = (mixed,)
        if cfg.delay:
            ret = ret + (tuple(new_state),)
        if self.stateful:
            ret = ret + (tuple(new_cstate),)
        if tel is not None:
            ret = ret + (metrics,)
        return ret[0] if len(ret) == 1 else ret

    def _stacked_metrics_init(self, alive, gates):
        """(metrics dict seeded with the degree metrics, contributor table)
        for a stacked telemetry build — (empty, None) when telemetry is off
        so the call sites stay single-line."""
        tel = self.config.telemetry
        if tel is None:
            return {}, None
        _, tcontrib = gossip.raw_contrib_tables(self.spec, alive, gates)
        metrics = {}
        if tel.degree:
            metrics["in_degree"] = jnp.sum(tcontrib[:, 1:], axis=1)
            metrics["sched_contrib"] = tcontrib[:, 1:]
        return metrics, tcontrib

    def _stacked_round_cheby(self, tree, alive, gates, cheby):
        """Chebyshev multi-round gossip (sub_rounds = k > 1), stacked.

        Same contract as :meth:`_shard_map_round_cheby` on the client-
        stacked substrate: k gather+einsum applications of the one weight
        table (computed once — the same W each sub-round), the second-order
        combine in f32, telemetry measured on the first sub-round. The f32
        cell is the dense-oracle reference: it matches
        ``mixing.chebyshev_mix(x, gossip.gated_mixing_matrix(spec, gates,
        alive), cheby)`` to float tolerance."""
        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        pack_spec = self.pack_spec or gossip._stacked_pack_spec(tree)
        w = (gossip._static_weight_table(spec)
             if alive is None and gates is None
             else gossip.alive_weight_table(spec, alive, gates))
        gathers = [jnp.asarray(rf) for rf in spec.recv_from]
        fresh = jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)
        metrics, tcontrib = self._stacked_metrics_init(alive, gates)
        resid = jnp.zeros((spec.n_clients,), jnp.float32)
        sq = jax.vmap(self._sq(pack_spec))
        omg = jnp.asarray(cheby, jnp.float32)
        out_bufs = []
        for b, buf in enumerate(fresh):
            n_blocks = pack_spec.buffer_blocks(b)

            def enc(x, n_blocks=n_blocks):
                return codec.encode(x, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)

            def dec(x, n_blocks=n_blocks, dtype=buf.dtype):
                return codec.decode(x, dtype, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows)

            x_prev = buf.astype(jnp.float32)
            x_cur = x_prev
            for j in range(cfg.sub_rounds):
                xj = x_cur.astype(buf.dtype)
                # self row stays the current full-precision iterate; only
                # the gathered neighbor rows go through the codec wire
                src = (xj if codec.identity_wire
                       else jax.vmap(dec)(jax.vmap(enc)(xj)))
                stack = jnp.stack([xj] + [jnp.take(src, g, axis=0)
                                          for g in gathers], axis=1)
                y = jnp.einsum("nk,nk...->n...", w,
                               stack.astype(jnp.float32))
                if j == 0 and tel is not None and tel.consensus:
                    for s in range(len(gathers)):
                        resid = resid + tcontrib[:, 1 + s] * sq(
                            stack[:, 1 + s].astype(jnp.float32)
                            - xj.astype(jnp.float32))
                x_next = omg[j] * (y - x_prev) + x_prev
                x_prev, x_cur = x_cur, x_next
            out_bufs.append(x_cur.astype(buf.dtype))
        if tel is not None and tel.consensus:
            metrics["resid_sqnorm"] = resid
        mixed = jax.vmap(lambda bs: packing.unpack_tree(bs, pack_spec))(
            tuple(out_bufs))
        if tel is not None:
            return mixed, metrics
        return mixed

    def _stacked_round_screened(self, tree, state, alive, gates, pack_spec):
        """Screened stacked round. The gather sources (decoded codec wires /
        the delayed snapshot) are materialized for every buffer first so the
        norm-clip screen can compare whole-model norms; the per-buffer mix
        then runs with either the clip-scaled weight table (norm_clip: the
        same einsum as the plain round, so an all-ones clip is bitwise
        identical) or the vmapped trimmed-mean kernel (trimmed_mean).

        Under telemetry, the norm_clip cells emit per-SENDER ``clipped``
        counts of receivers that clipped them this round — the suspicion
        signal :class:`repro.core.failures.HealthTracker` accumulates."""
        from repro.kernels.gossip_mix import ops as mix_ops

        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        if cfg.screen == "norm_clip" and not codec.identity_wire:
            return self._stacked_round_clipped_quant(tree, state, alive,
                                                     gates, pack_spec)
        gathers = [jnp.asarray(rf) for rf in spec.recv_from]
        fresh = jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)
        srcs, new_state = [], []
        for b, buf in enumerate(fresh):
            n_blocks = pack_spec.buffer_blocks(b)

            def enc(x, n_blocks=n_blocks):
                return codec.encode(x, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)

            if codec.identity_wire:
                src = state[b] if cfg.delay else buf
            else:
                wire = state[b] if cfg.delay else jax.vmap(enc)(buf)
                src = jax.vmap(
                    lambda x, n_blocks=n_blocks, dtype=buf.dtype:
                    codec.decode(x, dtype, n_blocks=n_blocks,
                                 block_rows=pack_spec.block_rows))(wire)
            srcs.append(src)
            if cfg.delay:
                new_state.append(buf if codec.identity_wire
                                 else jax.vmap(enc)(buf))
        metrics, tcontrib = self._stacked_metrics_init(alive, gates)
        if cfg.screen == "norm_clip":
            w = (gossip._static_weight_table(spec)
                 if alive is None and gates is None
                 else gossip.alive_weight_table(spec, alive, gates))

            def sq(x):
                return jnp.sum(mix_ops.packed_sqnorms(
                    x, block_rows=pack_spec.block_rows, impl=cfg.mix_impl))

            s2 = sum(jax.vmap(sq)(buf) for buf in fresh)        # (n,)
            r2_src = sum(jax.vmap(sq)(src) for src in srcs)     # (n,)
            lim = jnp.float32(cfg.clip_tau) ** 2 * s2
            clip = jnp.stack([_clip_factors(r2_src[g], lim)
                              for g in gathers], axis=1)        # (n, S)
            # clip multiplies the post-renormalization received columns
            # only — the table already carries the alive/gates renorm and
            # the dead-self identity fallback, both untouched here
            eff = jnp.concatenate([w[:, :1], w[:, 1:] * clip], axis=1)
            if tel is not None and tel.clip:
                counts = jnp.zeros(spec.n_clients, jnp.int32)
                for s, g in enumerate(gathers):
                    flag = ((clip[:, s] < 1.0)
                            & (w[:, 1 + s] > 0.0)).astype(jnp.int32)
                    counts = counts.at[g].add(flag)
                metrics["clipped"] = counts

            def mixer(stack):
                return jnp.einsum("nk,nk...->n...", eff,
                                  stack.astype(jnp.float32))
        else:  # trimmed_mean
            raw, contrib = gossip.raw_contrib_tables(spec, alive, gates)
            trim_u = jnp.maximum(raw, 0.0) * contrib
            trim_live = (contrib > 0.0).astype(jnp.float32)

            def mixer(stack):
                return jax.vmap(
                    lambda st, uu, ll: mix_ops.gossip_mix_trimmed_packed(
                        st, uu, ll, trim=cfg.trim_f,
                        block_rows=pack_spec.block_rows,
                        impl=cfg.mix_impl))(stack, trim_u, trim_live)
        resid = jnp.zeros((spec.n_clients,), jnp.float32)
        vsq = jax.vmap(self._sq(pack_spec))
        out_bufs = []
        for b, buf in enumerate(fresh):
            # self row stays the FRESH full-precision buffer; only the
            # gathered neighbor rows go through the codec / the snapshot
            stack = jnp.stack([buf] + [jnp.take(srcs[b], idx, axis=0)
                                       for idx in gathers], axis=1)
            out_bufs.append(mixer(stack).astype(buf.dtype))
            if tel is not None and tel.consensus:
                for s in range(len(gathers)):
                    resid = resid + tcontrib[:, 1 + s] * vsq(
                        stack[:, 1 + s].astype(jnp.float32)
                        - buf.astype(jnp.float32))
        if tel is not None and tel.consensus:
            metrics["resid_sqnorm"] = resid
        mixed = jax.vmap(lambda bs: packing.unpack_tree(bs, pack_spec))(
            tuple(out_bufs))
        ret = (mixed,)
        if cfg.delay:
            ret = ret + (tuple(new_state),)
        if tel is not None:
            ret = ret + (metrics,)
        return ret[0] if len(ret) == 1 else ret

    def _stacked_round_clipped_quant(self, tree, state, alive, gates,
                                     pack_spec):
        """Fused quantized norm_clip on the stacked substrate: the int8
        wires are GATHERED, never decoded — the clip norms come straight off
        the wire (``wire_sqnorm``: per-block sum(q^2) x scale^2, exact for
        what the mix would dequantize) and each receiver folds its received
        wires through the same per-wire fused ``dequant_accumulate_2d``
        pass the shard_map cell uses, with the clip riding the per-sender
        weight operand. One arithmetic path for the quantized norm_clip
        screen across both packed substrates; only trimmed_mean still
        decodes-then-gathers here (its order statistics need the whole
        dequantized stack — see the ROADMAP design record)."""
        from repro.kernels.gossip_mix import ops as mix_ops

        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        gathers = [jnp.asarray(rf) for rf in spec.recv_from]
        fresh = jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)
        wires, new_state = [], []
        s2 = jnp.zeros((spec.n_clients,), jnp.float32)
        r2 = jnp.zeros((spec.n_clients,), jnp.float32)
        for b, buf in enumerate(fresh):
            n_blocks = pack_spec.buffer_blocks(b)

            def enc(x, n_blocks=n_blocks):
                return codec.encode(x, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)

            wire = state[b] if cfg.delay else jax.vmap(enc)(buf)
            wires.append(wire)
            if cfg.delay:
                new_state.append(jax.vmap(enc)(buf))
            s2 = s2 + jax.vmap(lambda x: jnp.sum(mix_ops.packed_sqnorms(
                x, block_rows=pack_spec.block_rows,
                impl=cfg.mix_impl)))(buf)
            r2 = r2 + jax.vmap(
                lambda x, n_blocks=n_blocks: codec.wire_sqnorm(
                    x, n_blocks=n_blocks, block_rows=pack_spec.block_rows,
                    impl=cfg.mix_impl))(wire)
        lim = jnp.float32(cfg.clip_tau) ** 2 * s2                    # (n,)
        clip = (jnp.stack([_clip_factors(r2[g], lim) for g in gathers],
                          axis=1)
                if gathers else jnp.zeros((spec.n_clients, 0), jnp.float32))
        # pre-renormalization tables: codec.reduce applies the same
        # per-client renorm + dead-self identity fallback as the shard_map
        # cell (fixed points stay invisible through the contrib zeros)
        raw, contrib = gossip.raw_contrib_tables(spec, alive, gates)
        metrics = {}
        if tel is not None:
            if tel.degree:
                metrics["in_degree"] = jnp.sum(contrib[:, 1:], axis=1)
                metrics["sched_contrib"] = contrib[:, 1:]
            if tel.clip:
                w = gossip.alive_weight_table(spec, alive, gates)
                counts = jnp.zeros(spec.n_clients, jnp.int32)
                for s, g in enumerate(gathers):
                    flag = ((clip[:, s] < 1.0)
                            & (w[:, 1 + s] > 0.0)).astype(jnp.int32)
                    counts = counts.at[g].add(flag)
                metrics["clipped"] = counts
            if tel.consensus:
                # the consensus proxy is the ONE telemetry metric this cell
                # pays real extra compute for: the fused path never decodes
                # the gathered wires, so residuals dequantize them here
                vsq = jax.vmap(self._sq(pack_spec))
                resid = jnp.zeros((spec.n_clients,), jnp.float32)
                for b, buf in enumerate(fresh):
                    n_blocks = pack_spec.buffer_blocks(b)
                    dec = jax.vmap(
                        lambda x, n_blocks=n_blocks, dtype=buf.dtype:
                        codec.decode(x, dtype, n_blocks=n_blocks,
                                     block_rows=pack_spec.block_rows))(
                        wires[b])
                    for s, g in enumerate(gathers):
                        resid = resid + contrib[:, 1 + s] * vsq(
                            jnp.take(dec, g, axis=0).astype(jnp.float32)
                            - buf.astype(jnp.float32))
                metrics["resid_sqnorm"] = resid
        out_bufs = []
        for b, buf in enumerate(fresh):
            n_blocks = pack_spec.buffer_blocks(b)
            recv = [jnp.take(wires[b], g, axis=0) for g in gathers]

            def red(fb, rw, cv, cl, *rs, n_blocks=n_blocks):
                return codec.reduce(
                    fb, list(rs), rw, cv,
                    edge_weight=float(spec.edge_weight), n_blocks=n_blocks,
                    block_rows=pack_spec.block_rows, impl=cfg.mix_impl,
                    sender_scale=cl)

            out_bufs.append(jax.vmap(red)(buf, raw, contrib, clip, *recv)
                            .astype(buf.dtype))
        mixed = jax.vmap(lambda bs: packing.unpack_tree(bs, pack_spec))(
            tuple(out_bufs))
        ret = (mixed,)
        if cfg.delay:
            ret = ret + (tuple(new_state),)
        if tel is not None:
            ret = ret + (metrics,)
        return ret[0] if len(ret) == 1 else ret

    def _blocked_round(self, tree, alive, gates):
        """The massive-client round: ``tree`` is this device's (block, ...)
        stacked slice inside a shard_map island over the 1-D client device
        axis. Intra-block edges are plain stacked gathers; every cross-block
        partial device permutation in ``self.blocked.transfers`` ships ONE
        whole (block, rows, 128) wire buffer via ppermute, and each client
        gathers its source row out of the [local + received] candidate stack
        through the static ``gather_flat`` table (sliced to this device by
        ``axis_index``). The final weighted reduction is the stacked
        substrate's einsum over the device-local rows of the SAME
        ``alive_weight_table`` — f32 cells are bit-identical to the stacked
        reference on the same overlay, and alive / active-set / gate churn
        stays plain data.

        Telemetry (when on) reads the device-local (block,) rows of the
        contributor table and measures residuals off the ALREADY-gathered
        candidate stack — zero extra collectives, asserted by the HLO
        guards in tests/test_telemetry.py. The island's out_spec over the
        client device axis concatenates the per-device rows back to the
        (n,)-stacked layout."""
        cfg, codec, spec = self.config, self.codec, self.spec
        tel = cfg.telemetry
        bs = self.blocked
        pack_spec = self.pack_spec or gossip._stacked_pack_spec(tree)
        b_sz = bs.block
        row0 = gossip._client_index(self.axis_names) * b_sz
        w = gossip.alive_weight_table(spec, alive, gates)       # (n, S+1)
        w_local = jax.lax.dynamic_slice(w, (row0, 0), (b_sz, w.shape[1]))
        idx_tab = jnp.asarray(bs.gather_flat, jnp.int32)        # (S, n)
        fresh = jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)
        metrics, tcontrib_local = {}, None
        if tel is not None:
            _, tcontrib = gossip.raw_contrib_tables(spec, alive, gates)
            tcontrib_local = jax.lax.dynamic_slice(
                tcontrib, (row0, 0), (b_sz, tcontrib.shape[1]))
            if tel.degree:
                metrics["in_degree"] = jnp.sum(tcontrib_local[:, 1:], axis=1)
                metrics["sched_contrib"] = tcontrib_local[:, 1:]
        resid = jnp.zeros((b_sz,), jnp.float32)
        vsq = jax.vmap(self._sq(pack_spec))
        out_bufs = []
        for b, buf in enumerate(fresh):
            n_blocks = pack_spec.buffer_blocks(b)

            def enc(x, n_blocks=n_blocks):
                return codec.encode(x, n_blocks=n_blocks,
                                    block_rows=pack_spec.block_rows,
                                    impl=cfg.mix_impl)

            wire = buf if codec.identity_wire else jax.vmap(enc)(buf)
            # all whole-block permutes issued before any gather so XLA can
            # overlap the wire; devices outside a partial permutation
            # receive zeros, which no gather table entry ever points at
            received = [jax.lax.ppermute(wire, self.axis_names, perm=list(t))
                        for t in bs.transfers]
            cand = jnp.concatenate([wire[None]] + [r[None] for r in received],
                                   axis=0)
            flat = cand.reshape((bs.n_transfers + 1) * b_sz, *wire.shape[1:])
            if not codec.identity_wire:
                flat = jax.vmap(
                    lambda x, n_blocks=n_blocks, dtype=buf.dtype:
                    codec.decode(x, dtype, n_blocks=n_blocks,
                                 block_rows=pack_spec.block_rows))(flat)
            srcs = [jnp.take(flat,
                             jax.lax.dynamic_slice(idx_tab[s], (row0,),
                                                   (b_sz,)), axis=0)
                    for s in range(spec.degree)]
            # self row stays the FRESH full-precision buffer; only the
            # gathered neighbor rows go through the codec wire
            stack = jnp.stack([buf] + srcs, axis=1)  # (B, S+1, rows, 128)
            out = jnp.einsum("bk,bk...->b...", w_local,
                             stack.astype(jnp.float32))
            out_bufs.append(out.astype(buf.dtype))
            if tel is not None and tel.consensus:
                # residuals off the already-gathered stack: the telemetry
                # build ships the exact same permutes as the metrics-off one
                for s in range(spec.degree):
                    resid = resid + tcontrib_local[:, 1 + s] * vsq(
                        stack[:, 1 + s].astype(jnp.float32)
                        - buf.astype(jnp.float32))
        if tel is not None and tel.consensus:
            metrics["resid_sqnorm"] = resid
        mixed = jax.vmap(lambda bso: packing.unpack_tree(bso, pack_spec))(
            tuple(out_bufs))
        if tel is not None:
            return mixed, metrics
        return mixed

    def _per_leaf_round(self, tree):
        cfg, codec, spec = self.config, self.codec, self.spec
        idx = gossip._client_index(self.axis_names)
        self_w = jnp.asarray(spec.self_weights)[idx]
        perms = [list(pairs) for pairs in spec.perms if len(pairs) > 0]

        def _mix(x):
            parts = codec.encode_leaf(x, cfg.mix_impl)
            received = [
                codec.decode_leaf(
                    tuple(jax.lax.ppermute(part, self.axis_names, perm=p)
                          for part in parts), x.dtype, cfg.mix_impl)
                for p in perms
            ]
            out = self_w.astype(x.dtype) * x
            c = jnp.asarray(spec.edge_weight, dtype=x.dtype)
            for r in received:
                out = out + c * r
            return out

        return jax.tree.map(_mix, tree)


def build_gossip_executor(config: GossipEngineConfig, spec: GossipSpec, *,
                          axis_names=None,
                          pack_spec: packing.PackSpec | None = None
                          ) -> GossipExecutor:
    """Assemble one gossip executor from an engine cell.

    ``axis_names`` names the client mesh axis/axes and is required on the
    ``shard_map`` / ``per_leaf`` / ``blocked`` substrates (the executor is
    called inside the fully-manual island; for ``blocked`` the axis indexes
    DEVICES, each holding ``config.block`` clients); the stacked / dense
    substrates run on a client-stacked pytree and ignore it. Pass
    ``pack_spec`` (built host-side from shape structs — the PER-CLIENT
    slice spec on stacked/blocked) to bake the packed layout into the
    jitted step; it is derived from the tree at call time otherwise. On
    ``blocked`` the schedule partition (:func:`gossip.make_blocked_spec`)
    is baked here, host-side, once per (spec, block).
    """
    if (config.substrate in ("shard_map", "per_leaf", "blocked")
            and axis_names is None):
        raise ValueError(f"substrate {config.substrate!r} runs inside "
                         "shard_map and needs axis_names")
    blocked = (gossip.make_blocked_spec(spec, config.block)
               if config.substrate == "blocked" else None)
    return GossipExecutor(config=config, spec=spec, axis_names=axis_names,
                          pack_spec=pack_spec, blocked=blocked)
