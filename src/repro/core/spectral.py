"""Spectral graph analysis for overlay-network design (paper §2-§3).

Everything here runs on the *host* (numpy) at topology-construction time; the
resulting mixing weights are baked into jitted train steps as constants.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "laplacian",
    "laplacian_spectrum",
    "kappa",
    "theta_star",
    "chow_lambda",
    "mixing_lambda",
    "c_lambda",
    "chebyshev_omegas",
    "chebyshev_lambda",
    "ramanujan_bound",
    "ring_kappa_lower_bound",
    "is_connected",
    "SpectralReport",
    "analyze",
]


def laplacian(adj: np.ndarray) -> np.ndarray:
    """Graph Laplacian L = D - A for a 0/1 symmetric adjacency matrix."""
    adj = np.asarray(adj, dtype=np.float64)
    if adj.ndim != 2 or adj.shape[0] != adj.shape[1]:
        raise ValueError(f"adjacency must be square, got {adj.shape}")
    if not np.allclose(adj, adj.T):
        raise ValueError("adjacency must be symmetric (undirected graph)")
    if np.any(np.diag(adj) != 0):
        raise ValueError("adjacency must have zero diagonal (no self-loops)")
    deg = adj.sum(axis=1)
    return np.diag(deg) - adj


def laplacian_spectrum(adj: np.ndarray) -> np.ndarray:
    """Sorted (ascending) eigenvalues of the graph Laplacian."""
    return np.linalg.eigvalsh(laplacian(adj))


def is_connected(adj: np.ndarray, tol: float = 1e-9) -> bool:
    """Connected iff the second-smallest Laplacian eigenvalue (Fiedler) > 0."""
    ev = laplacian_spectrum(adj)
    return bool(ev[1] > tol) if len(ev) > 1 else True


def kappa(adj: np.ndarray) -> float:
    """Reduced condition number kappa(L) = lambda_N(L) / lambda_2(L)  (eq. 3.1)."""
    ev = laplacian_spectrum(adj)
    lam2, lamN = float(ev[1]), float(ev[-1])
    if lam2 <= 1e-12:
        return float("inf")  # disconnected graph
    return lamN / lam2


def theta_star(kappa_val: float) -> float:
    """Optimal theta for the Chow mixing matrix: theta* = 1/kappa(L)  (paper §3)."""
    if not (kappa_val >= 1.0):
        raise ValueError(f"kappa must be >= 1, got {kappa_val}")
    return 1.0 / kappa_val


def chow_lambda(kappa_val: float, theta: float | None = None) -> float:
    """lambda(M) for the Chow matrix as a function of kappa(L) and theta.

    lambda = max(|1+theta-2/kappa|, 1-theta) / (1+theta); minimized at
    theta* = 1/kappa, where lambda* = (1 - 1/kappa) / (1 + 1/kappa)
           = (kappa - 1) / (kappa + 1).
    """
    if theta is None:
        theta = theta_star(kappa_val)
    if math.isinf(kappa_val):
        return 1.0
    a = abs(1.0 + theta - 2.0 / kappa_val)
    b = 1.0 - theta
    return max(a, b) / (1.0 + theta)


def mixing_lambda(mix: np.ndarray, tol: float = 1e-9) -> float:
    """lambda(M) = max(|lambda_2(M)|, |lambda_N(M)|) for a given mixing matrix."""
    ev = np.linalg.eigvalsh(np.asarray(mix, dtype=np.float64))
    # eigvalsh returns ascending; lambda_1(M)=1 is the largest.
    if abs(ev[-1] - 1.0) > 1e-6:
        raise ValueError(f"top eigenvalue of a mixing matrix must be 1, got {ev[-1]}")
    second = ev[-2] if len(ev) > 1 else 0.0
    bottom = ev[0]
    return float(max(abs(second), abs(bottom)))


def c_lambda(lam: float) -> float:
    """C_lambda from Theorem 2.5: the topology-dependent generalization constant.

    C_lambda = 2*lam^2 + 4*lam^2*ln(1/lam) + 2*lam + 2/ln(1/lam).

    Increasing in lam on (0,1); diverges as lam -> 1 (poorly-connected graphs
    generalize worse).
    """
    if not (0.0 < lam < 1.0):
        if lam <= 0.0:
            return 0.0
        return float("inf")
    log_inv = math.log(1.0 / lam)
    return 2 * lam * lam + 4 * lam * lam * log_inv + 2 * lam + 2.0 / log_inv


def chebyshev_omegas(lam: float, k: int) -> np.ndarray:
    """Per-sub-round Chebyshev weights for k gossip sub-rounds (f32, (k,)).

    Classical Chebyshev (semi-iterative) acceleration of the fixed mixing
    matrix M with lambda(M) = lam: write p_j(M) = T_j(M/lam) / T_j(1/lam)
    (T_j the Chebyshev polynomial), so p_j(1) = 1 (consensus preserved) and
    |p_j| <= 1/T_j(1/lam) on [-lam, lam] — the square-root-of-kappa speedup
    over plain M^j. The three-term T recurrence turns into the executor's
    second-order sub-round recurrence

        x^(j+1) = omega[j] * (M x^(j) - x^(j-1)) + x^(j-1),

    with x^(-1) := x^(0), where ``omega[0] == 1`` exactly (the first
    sub-round IS the plain mix — how the sub_rounds=1 cell stays the sync
    engine) and the rest follow omega_{j+1} = 1 / (1 - (lam^2/4) omega_j)
    seeded at omega_1 = 2 (the T-ratio convention; omega climbs from
    2/(2 - lam^2) toward 2/(1 + sqrt(1 - lam^2))).

    ``lam`` outside [0, 1) (a disconnected overlay reports lam = 1.0)
    degenerates to all-ones: k plain gossip rounds, never a blow-up.
    """
    if k < 1:
        raise ValueError(f"sub_rounds k must be >= 1, got {k}")
    lam = float(lam)
    out = np.ones(k, dtype=np.float32)
    if not 0.0 <= lam < 1.0:
        return out
    w = 2.0  # omega_1 in the T-ratio recurrence; out[0] stays the plain mix
    for j in range(1, k):
        w = 1.0 / (1.0 - 0.25 * lam * lam * w)
        out[j] = w
    return out


def chebyshev_lambda(lam: float, k: int) -> float:
    """Effective contraction of k Chebyshev sub-rounds: 1 / T_k(1/lam).

    Compare against plain repetition's lam**k — for gap-limited overlays
    (lam -> 1) the ratio approaches the square-root-of-kappa speedup.
    """
    if k < 1:
        raise ValueError(f"sub_rounds k must be >= 1, got {k}")
    if lam <= 0.0:
        return 0.0
    if lam >= 1.0:
        return 1.0
    # T_k(x) = cosh(k * arccosh(x)) for x >= 1
    return 1.0 / math.cosh(k * math.acosh(1.0 / lam))


def ramanujan_bound(d: int) -> float:
    """Upper bound (3.2) on kappa(L) for a d-regular Ramanujan graph."""
    if d < 3:
        raise ValueError("Ramanujan bound needs d >= 3")
    s = 2.0 * math.sqrt(d - 1.0)
    return (d + s) / (d - s)


def ring_kappa_lower_bound(n: int) -> float:
    """Paper §3.1: kappa(L_ring) >= N^2 / pi^2 — quadratic blowup for rings."""
    return n * n / (math.pi * math.pi)


def mixing_time(lam: float, eps: float = 1e-3) -> float:
    """Rounds for gossip error contraction lam^t <= eps: t = ln(1/eps)/ln(1/lam)."""
    if lam <= 0:
        return 1.0
    if lam >= 1:
        return float("inf")
    return math.log(1.0 / eps) / math.log(1.0 / lam)


@dataclasses.dataclass(frozen=True)
class SpectralReport:
    """Everything the paper's theory says about one topology."""

    n: int
    degree_min: int
    degree_max: int
    n_edges: int
    connected: bool
    kappa: float
    theta_star: float
    lam: float            # lambda(M) of the Chow matrix at theta*
    c_lambda: float       # Thm 2.5 generalization constant
    mixing_time_1e3: float
    is_ramanujan: bool | None  # only meaningful for regular graphs

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def analyze(adj: np.ndarray) -> SpectralReport:
    """Full spectral report for an adjacency matrix."""
    adj = np.asarray(adj, dtype=np.float64)
    n = adj.shape[0]
    deg = adj.sum(axis=1).astype(int)
    ev_l = laplacian_spectrum(adj)
    connected = bool(ev_l[1] > 1e-9) if n > 1 else True
    if connected:
        kap = float(ev_l[-1] / ev_l[1])
        th = theta_star(kap)
        lam = chow_lambda(kap, th)
    else:
        kap, th, lam = float("inf"), 0.0, 1.0

    is_ram: bool | None = None
    if n > 2 and deg.min() == deg.max():
        d = int(deg[0])
        # adjacency eigenvalues: lambda_1(A) is the largest nontrivial one
        ev_a = np.linalg.eigvalsh(adj)
        nontrivial = max(abs(ev_a[0]), abs(ev_a[-2]))
        is_ram = bool(nontrivial <= 2.0 * math.sqrt(max(d - 1, 1)) + 1e-9)

    return SpectralReport(
        n=n,
        degree_min=int(deg.min()) if n else 0,
        degree_max=int(deg.max()) if n else 0,
        n_edges=int(adj.sum() // 2),
        connected=connected,
        kappa=kap,
        theta_star=th,
        lam=lam,
        c_lambda=c_lambda(lam),
        mixing_time_1e3=mixing_time(lam),
        is_ramanujan=is_ram,
    )
