"""Gossip executors: how a mixing round `w <- M w` actually runs.

Five executors, one semantics:

1. ``mix_dense``      — dense ``einsum('cd,d...->c...')`` over a stacked client
                        axis. The reference / oracle; also what a *naive* port
                        of the paper's simulator does on a TPU mesh (XLA turns
                        it into an all-gather of every client's parameters —
                        this is the paper-faithful baseline in §Perf).
2. ``mix_schedules``  — gather-based evaluation of the schedule decomposition
                        on a stacked client axis (simulator fast path; oracle
                        for the ppermute paths).
3. ``ppermute_mix``   — per-leaf shard_map path: one ``jax.lax.ppermute`` per
                        (schedule x pytree leaf) along the client mesh axes +
                        an unfused weighted sum. d single-hop exchanges per
                        leaf, no gather. Kept as the packed path's baseline.
4. ``ppermute_mix_packed`` — the production path: the parameter pytree is
                        packed into one lane-aligned ``(rows, 128)`` flat
                        buffer per dtype (:mod:`repro.core.packing`), so a
                        round is **d ppermutes total** (one per schedule,
                        independent of leaf count — fewer, larger,
                        overlappable collectives) and the weighted reduction
                        of self + d received buffers is **one HBM pass**
                        through the fused ``gossip_mix_2d`` Pallas kernel.
5. ``ppermute_mix_packed_quantized`` — packed + int8 payloads: the packed
                        buffer quantizes through the Pallas ``quantize_2d``
                        kernel (4x/2x fewer ICI bytes) and each received
                        buffer folds in via the fused ``dequant_accumulate_2d``
                        kernel. (``ppermute_mix_quantized`` is the per-leaf
                        jnp-level equivalent.)

A :class:`GossipSpec` is the static, hashable description baked into the
jitted step.

Failure awareness (paper §5.2) lives on the packed paths: the packed
executors (and the stacked :func:`mix_packed_stacked` simulator counterpart)
take an optional *traced* ``alive`` vector with :func:`mix_dense_masked`
semantics — dead clients neither send nor update, survivors renormalize over
their live in-degree. Because the mask is a step argument rather than spec
structure, straggler churn never retraces the jitted step (see
``alive_weight_table``); the per-leaf ppermute baselines and
``mix_schedules`` deliberately do NOT take a mask (use the packed paths).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.topology import Overlay

__all__ = [
    "GossipSpec",
    "make_gossip_spec",
    "alive_weight_table",
    "mix_dense",
    "mix_dense_masked",
    "mix_schedules",
    "mix_packed_stacked",
    "ppermute_mix",
    "ppermute_mix_quantized",
    "ppermute_mix_packed",
    "ppermute_mix_packed_quantized",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    """Static gossip description (hashable => usable as a jit static arg).

    Attributes:
      n_clients: number of clients on the gossip axis.
      perms: per schedule, a tuple of (src, dst) pairs for ppermute — i.e.
        data flows src -> dst, where dst's mixing row has weight edge_weight at
        column src. Fixed points are excluded here and folded into self_weights.
      recv_from: per schedule, tuple of length n_clients: recv_from[s][i] is
        the client whose params client i receives under schedule s (i itself
        for fixed points). Used by the stacked-gather executor.
      self_weights: per-client diagonal weight (w0 + edge_weight * #fixed).
      edge_weight: the uniform Chow edge weight c.
      lam: lambda(M) of the mixing matrix (for reports).
      live_masks: per schedule, tuple of 0/1 per client: 1 iff the client
        receives from a *different* client under that schedule (i.e. it is not
        a fixed point). Derived host-side from recv_from so the stacked-gather
        executor never recomputes ``idx != arange(n)`` per (leaf x schedule).
    """

    n_clients: int
    perms: tuple[tuple[tuple[int, int], ...], ...]
    recv_from: tuple[tuple[int, ...], ...]
    self_weights: tuple[float, ...]
    edge_weight: float
    lam: float
    live_masks: tuple[tuple[int, ...], ...] | None = None

    def __post_init__(self):
        if self.live_masks is None:
            masks = tuple(
                tuple(int(src != i) for i, src in enumerate(rf))
                for rf in self.recv_from)
            object.__setattr__(self, "live_masks", masks)

    @property
    def degree(self) -> int:
        return len(self.perms)


def make_gossip_spec(overlay: Overlay, theta: float | None = None) -> GossipSpec:
    """Bake an Overlay + Chow weights into a static GossipSpec."""
    w = overlay.chow_weights(theta)
    n = overlay.n
    perms = []
    recv_from = []
    fixed_counts = np.zeros(n, dtype=np.int64)
    for s in overlay.schedules:
        pairs = tuple(
            (int(s[i]), int(i)) for i in range(n) if int(s[i]) != i
        )  # i receives FROM s[i]: src=s[i], dst=i
        perms.append(pairs)
        recv_from.append(tuple(int(s[i]) for i in range(n)))
        fixed_counts += (s == np.arange(n)).astype(np.int64)
    self_w = tuple(float(w.self_weight + w.edge_weight * fixed_counts[i]) for i in range(n))
    return GossipSpec(
        n_clients=n,
        perms=tuple(perms),
        recv_from=tuple(recv_from),
        self_weights=self_w,
        edge_weight=float(w.edge_weight),
        lam=float(w.lam),
    )


# ----------------------------------------------------------------- executors
def mix_dense(tree: PyTree, m: jax.Array | np.ndarray) -> PyTree:
    """Reference: out_c = sum_d M[c, d] x_d over the leading (client) axis."""
    m = jnp.asarray(m)

    def _mix(x):
        flat = x.reshape(x.shape[0], -1)
        out = jnp.einsum("cd,df->cf", m.astype(flat.dtype), flat)
        return out.reshape(x.shape)

    return jax.tree.map(_mix, tree)


def mix_dense_masked(tree: PyTree, m: jax.Array | np.ndarray,
                     alive: jax.Array | np.ndarray) -> PyTree:
    """Failure-aware dense mixing (paper §5.2 semantics).

    Dead clients neither send nor update. Each surviving row renormalizes over
    its alive in-neighbors (incl. itself); dead rows keep their parameters.
    """
    m = jnp.asarray(m, dtype=jnp.float32)
    alive = jnp.asarray(alive, dtype=jnp.float32)
    masked = m * alive[None, :]  # zero dead senders
    row = masked.sum(axis=1, keepdims=True)
    renorm = masked / jnp.maximum(row, 1e-12)
    # dead receivers: identity row (they keep their params)
    eye = jnp.eye(m.shape[0], dtype=jnp.float32)
    eff = alive[:, None] * renorm + (1.0 - alive[:, None]) * eye
    return mix_dense(tree, eff)


def alive_weight_table(spec: GossipSpec, alive: jax.Array) -> jax.Array:
    """Renormalized mixing weights under a (traced) alive mask: (n, S+1).

    Column 0 is the self weight, column 1+s the weight applied to the payload
    received under schedule s. Rows match ``mix_dense_masked`` exactly: dead
    senders are zeroed, each surviving row renormalizes over its alive
    in-neighborhood (incl. itself), and dead receivers get the identity row.
    ``alive`` is data, not structure — recomputing this table every round
    costs a few n x (S+1) vector ops and never retraces the step.
    """
    n = spec.n_clients
    alive = jnp.asarray(alive, jnp.float32)
    self_w = jnp.asarray(spec.self_weights, jnp.float32)
    cols = [spec.edge_weight * jnp.asarray(mask, jnp.float32)
            * jnp.take(alive, jnp.asarray(rf))
            for rf, mask in zip(spec.recv_from, spec.live_masks)]
    ws = (jnp.stack(cols, axis=1) if cols else jnp.zeros((n, 0), jnp.float32))
    inv = 1.0 / jnp.maximum(self_w + ws.sum(axis=1), 1e-12)
    w0 = alive * self_w * inv + (1.0 - alive)
    ws = (alive * inv)[:, None] * ws
    return jnp.concatenate([w0[:, None], ws], axis=1)


def _static_weight_table(spec: GossipSpec) -> jax.Array:
    """All-alive weight table (host-side constant): (n, S+1)."""
    w0 = np.asarray(spec.self_weights, np.float32)[:, None]
    if spec.degree == 0:
        return jnp.asarray(w0)
    ws = np.stack([spec.edge_weight * np.asarray(m, np.float32)
                   for m in spec.live_masks], axis=1)
    return jnp.asarray(np.concatenate([w0, ws], axis=1))


def mix_schedules(tree: PyTree, spec: GossipSpec) -> PyTree:
    """Stacked-axis executor of the schedule decomposition (gather-based).

    out = self_weights * x + c * sum_s [recv_from[s] != id] * x[recv_from[s]]
    — fixed points contribute nothing here because their weight is already
    folded into self_weights (same arithmetic as the ppermute path, so this
    serves as its oracle).
    """
    self_w = jnp.asarray(spec.self_weights)
    # per-schedule gather indices and live masks, built once (host-side spec
    # data), shared across every leaf instead of recomputed per (leaf x sched)
    gathers = [(jnp.asarray(rf), jnp.asarray(mask, jnp.float32))
               for rf, mask in zip(spec.recv_from, spec.live_masks)]

    def _mix(x):
        w = self_w.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
        out = w * x
        for idx, mask in gathers:
            live = mask.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
            out = out + jnp.asarray(spec.edge_weight, dtype=x.dtype) * live * jnp.take(
                x, idx, axis=0)
        return out

    return jax.tree.map(_mix, tree)


def mix_packed_stacked(tree: PyTree, spec: GossipSpec,
                       alive: jax.Array | None = None, *,
                       pack_spec: packing.PackSpec | None = None) -> PyTree:
    """Stacked-axis packed executor — the simulator counterpart of
    :func:`ppermute_mix_packed` and the mixing path of the elastic runtime.

    The client-stacked pytree packs (vmapped) into one ``(n, rows, 128)``
    flat buffer per dtype, each schedule becomes one gather on the stacked
    axis, and the weighted reduction runs as a single fused contraction over
    the ``(n, S+1, rows, 128)`` stack — the XLA analogue of the
    ``gossip_mix_2d`` kernel pass, with none of the per-leaf flatten work of
    :func:`mix_schedules`. With ``alive`` (a *traced* ``(n,)`` 0/1 vector)
    the reduction uses the renormalized masked weights of
    :func:`alive_weight_table`, so straggler-set changes are plain data and
    never retrace the enclosing jit.
    """
    if pack_spec is None:
        pack_spec = packing.make_pack_spec(jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree))
    w = (_static_weight_table(spec) if alive is None
         else alive_weight_table(spec, alive))
    gathers = [jnp.asarray(rf) for rf in spec.recv_from]
    bufs = jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)
    out_bufs = []
    for buf in bufs:
        stack = jnp.stack([buf] + [jnp.take(buf, idx, axis=0)
                                   for idx in gathers], axis=1)
        out = jnp.einsum("nk,nk...->n...", w, stack.astype(jnp.float32))
        out_bufs.append(out.astype(buf.dtype))
    return jax.vmap(lambda bs: packing.unpack_tree(bs, pack_spec))(
        tuple(out_bufs))


def _axis_size(name: str) -> jax.Array | int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)  # pre-0.4.38 spelling; folds to a constant


def _client_index(axis_names: str | tuple[str, ...]) -> jax.Array:
    """Flattened client index over (possibly) multiple mesh axes, row-major."""
    if isinstance(axis_names, str):
        return jax.lax.axis_index(axis_names)
    idx = jax.lax.axis_index(axis_names[0])
    for name in axis_names[1:]:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def ppermute_mix(tree: PyTree, spec: GossipSpec,
                 axis_names: str | tuple[str, ...]) -> PyTree:
    """Production gossip: one collective-permute per schedule (call in shard_map).

    Every leaf holds the *local shard* of the local client's value; the client
    axis is the mesh axis/axes in ``axis_names``. All ppermutes are issued
    before any sums so XLA can overlap them.
    """
    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx]

    def _mix(x):
        received = [
            jax.lax.ppermute(x, axis_names, perm=list(pairs))
            for pairs in spec.perms
            if len(pairs) > 0
        ]
        out = self_w.astype(x.dtype) * x
        c = jnp.asarray(spec.edge_weight, dtype=x.dtype)
        for r in received:
            out = out + c * r
        return out

    return jax.tree.map(_mix, tree)


def ppermute_mix_quantized(tree: PyTree, spec: GossipSpec,
                           axis_names: str | tuple[str, ...]) -> PyTree:
    """Beyond-paper: gossip with int8-quantized payloads (4x/2x fewer ICI bytes).

    Each leaf is symmetrically quantized per-tensor to int8 with an f32 scale;
    neighbors dequantize before the weighted sum. The *local* term stays full
    precision, so quantization error only enters through the (small) edge
    weights.
    """
    from repro.kernels.quant_gossip import ops as qops

    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx]

    def _mix(x):
        q, scale = qops.quantize_int8(x)
        received = []
        for pairs in spec.perms:
            if len(pairs) == 0:
                continue
            rq = jax.lax.ppermute(q, axis_names, perm=list(pairs))
            rs = jax.lax.ppermute(scale, axis_names, perm=list(pairs))
            received.append(qops.dequantize_int8(rq, rs, x.dtype))
        out = self_w.astype(x.dtype) * x
        c = jnp.asarray(spec.edge_weight, dtype=x.dtype)
        for r in received:
            out = out + c * r
        return out

    return jax.tree.map(_mix, tree)


# ------------------------------------------------------- packed executors
def _live_schedules(spec: GossipSpec):
    """(perm pairs, recv_from, live_mask) for schedules with any exchange."""
    return [(list(pairs), rf, mask)
            for pairs, rf, mask in zip(spec.perms, spec.recv_from,
                                       spec.live_masks)
            if len(pairs) > 0]


def _local_raw_weights(spec: GossipSpec, idx: jax.Array,
                       n_live: int) -> jax.Array:
    """This client's *unnormalized* Chow weights (w0, c, ..., c): (d+1,)."""
    self_w = jnp.asarray(spec.self_weights)[idx].astype(jnp.float32)
    return jnp.concatenate([
        self_w[None], jnp.full((n_live,), spec.edge_weight, jnp.float32)])


def _local_alive_vec(spec: GossipSpec, alive: jax.Array, idx: jax.Array,
                     live) -> jax.Array:
    """Per-contributor alive weights for the masked fused reduction: (d+1,).

    Entry 0 is this client's own liveness; entry 1+k the k-th schedule's
    sender liveness (zero at fixed points). Renormalization over the live
    in-degree happens inside the fused kernel. The sender's liveness is a
    *gather from the replicated alive vector* via the static recv_from table
    — masking dead senders costs no extra collectives.
    """
    alive = jnp.asarray(alive, jnp.float32)
    srcs = [alive[jnp.asarray(rf)[idx]] * jnp.asarray(mask, jnp.float32)[idx]
            for _, rf, mask in live]
    return jnp.stack([alive[idx]] + srcs)


def ppermute_mix_packed(tree: PyTree, spec: GossipSpec,
                        axis_names: str | tuple[str, ...], *,
                        pack_spec: packing.PackSpec | None = None,
                        mix_impl: str = "auto",
                        alive: jax.Array | None = None) -> PyTree:
    """Packed production gossip: d collectives/round, one fused HBM reduction.

    The client-local pytree packs into one lane-aligned flat buffer per dtype
    (:mod:`repro.core.packing`); each schedule then permutes the *whole*
    buffer in a single ``lax.ppermute`` — d collectives per round regardless
    of leaf count, vs d x n_leaves for :func:`ppermute_mix`. Self + the d
    received buffers stack to ``(d+1, rows, 128)`` and reduce in **one** HBM
    pass through the fused ``gossip_mix_2d`` Pallas kernel (interpret/ref off
    TPU). Fixed-point schedules deliver zeros (ppermute semantics), which the
    kernel's weighted sum absorbs — same arithmetic as the per-leaf path.

    ``alive`` (a traced, replicated ``(n_clients,)`` 0/1 vector) makes the
    round failure-aware with :func:`mix_dense_masked` semantics: dead senders
    are masked out of the reduction (their weight gathers to zero from the
    replicated vector — no extra collectives), each survivor renormalizes
    over its live in-degree inside the fused kernel, and a dead client keeps
    its own parameters. Because ``alive`` is data, straggler churn never
    retraces the step.

    Pass ``pack_spec`` (built host-side from shape structs) to bake the
    layout into the jitted step; it is derived from ``tree`` otherwise.
    """
    from repro.kernels.gossip_mix import ops as mix_ops

    if pack_spec is None:
        pack_spec = packing.make_pack_spec(tree)
    idx = _client_index(axis_names)
    live = _live_schedules(spec)
    perms = [p for p, _, _ in live]
    weights = _local_raw_weights(spec, idx, len(perms))
    alive_vec = (None if alive is None
                 else _local_alive_vec(spec, alive, idx, live))

    out_bufs = []
    for buf in packing.pack_tree(tree, pack_spec):
        # all ppermutes issued before the reduction so XLA can overlap them
        received = [jax.lax.ppermute(buf, axis_names, perm=p) for p in perms]
        stack = jnp.stack([buf] + received)
        out_bufs.append(mix_ops.gossip_mix_packed(
            stack, weights, alive_vec, block_rows=pack_spec.block_rows,
            impl=mix_impl))
    return packing.unpack_tree(tuple(out_bufs), pack_spec)


def ppermute_mix_packed_quantized(tree: PyTree, spec: GossipSpec,
                                  axis_names: str | tuple[str, ...], *,
                                  pack_spec: packing.PackSpec | None = None,
                                  impl: str = "auto",
                                  alive: jax.Array | None = None) -> PyTree:
    """Packed gossip with int8 wire payloads (4x/2x fewer ICI bytes).

    The packed buffer quantizes once through the Pallas ``quantize_2d`` kernel
    (per-buffer symmetric scale); each schedule permutes the int8 buffer + its
    f32 scale, and every received payload folds into the accumulator through
    the fused ``dequant_accumulate_2d`` kernel (dequant + scale + add in one
    HBM pass per neighbor). The local term stays full precision, so the int8
    error only enters through the (small) edge weights. Note the scale is
    per-buffer rather than per-leaf, so the error bound is governed by the
    buffer-wide amax; and each schedule ships *two* collectives (int8 buffer
    + its 4-byte f32 scale), i.e. 2d per round — still leaf-count-independent,
    but folding the scale into the shipped buffer is an open follow-up.

    ``alive`` has :func:`mix_dense_masked` semantics, as in
    :func:`ppermute_mix_packed`: the renormalizing denominator is a handful
    of scalar ops, the self term is rescaled up front, and each sender's
    (renormalized) alive weight rides into its fused dequant-accumulate pass
    — the masked round does the same HBM traffic as the unmasked one.
    """
    from repro.kernels.quant_gossip import ops as qops

    if pack_spec is None:
        pack_spec = packing.make_pack_spec(tree)
    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx].astype(jnp.float32)
    live = _live_schedules(spec)
    perms = [p for p, _, _ in live]
    c = float(spec.edge_weight)
    if alive is None:
        self_scale = self_w
        recv_alive = [None] * len(perms)
    else:
        alive_vec = _local_alive_vec(spec, alive, idx, live)
        a_self, src_a = alive_vec[0], alive_vec[1:]
        inv = 1.0 / jnp.maximum(self_w + c * jnp.sum(src_a), 1e-12)
        self_scale = a_self * self_w * inv + (1.0 - a_self)
        recv_alive = [a_self * src_a[k] * inv for k in range(len(perms))]

    out_bufs = []
    for buf in packing.pack_tree(tree, pack_spec):
        q, scale = qops.quantize_packed(buf, block_rows=pack_spec.block_rows,
                                        impl=impl)
        acc = self_scale.astype(buf.dtype) * buf
        for p, a in zip(perms, recv_alive):
            rq = jax.lax.ppermute(q, axis_names, perm=p)
            rs = jax.lax.ppermute(scale, axis_names, perm=p)
            acc = qops.dequant_accumulate_packed(
                rq, rs, c, acc, a, block_rows=pack_spec.block_rows, impl=impl)
        out_bufs.append(acc)
    return packing.unpack_tree(tuple(out_bufs), pack_spec)
