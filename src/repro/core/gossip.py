"""Gossip semantics: specs, dense oracles, and the legacy executor surface.

This module owns the *meaning* of a mixing round `w <- M w`:

* :class:`GossipSpec` — the static, hashable round description baked into
  the jitted step (schedules as ppermute pairs + recv_from gather tables +
  Chow weights);
* the dense oracles (``mix_dense``, ``mix_dense_masked``,
  ``mix_dense_gated``, ``mix_dense_delayed``) and the gather reference
  ``mix_schedules`` — the ground truth every executor is tested against;
* the ONE shared weight path (:func:`alive_weight_table` /
  :func:`gated_mixing_matrix` and the per-client local forms
  ``_local_raw_weights`` / ``_local_contrib_vec``) that turns traced
  ``alive`` masks and per-schedule ``gates`` into renormalized mixing
  weights for every variant.

The executors themselves are assembled by :mod:`repro.core.engine` from
three orthogonal layers — WireCodec (f32 / int8 / int8_block wire format)
x timing (sync / one-round-delayed pipeline) x substrate (shard_map
ppermute island / stacked simulator / per-leaf baseline / dense) — and the
seven pre-engine entry points below (``ppermute_mix``,
``ppermute_mix_quantized``, ``ppermute_mix_packed``,
``ppermute_mix_packed_quantized``, ``ppermute_mix_packed_delayed``,
``mix_packed_stacked``, ``mix_packed_stacked_delayed``) are thin aliases
that each name one engine cell. New compositions (e.g. pipelined +
quantized: ``delay=1 x int8``) need no new executor code — build them with
``engine.build_gossip_executor`` directly.

Failure awareness (paper §5.2) lives on the packed paths: the packed
executors (and the stacked :func:`mix_packed_stacked` simulator counterpart)
take an optional *traced* ``alive`` vector with :func:`mix_dense_masked`
semantics — dead clients neither send nor update, survivors renormalize over
their live in-degree. Because the mask is a step argument rather than spec
structure, straggler churn never retraces the jitted step (see
``alive_weight_table``); the per-leaf ppermute baselines and
``mix_schedules`` deliberately do NOT take a mask (use the packed paths).

Time-varying overlays (the overlay lab, :mod:`repro.overlay.plan`) ride the
same design: the packed executors take an optional traced ``gates`` vector —
one float per *schedule* — that multiplies each schedule's edge weight before
the very same renormalization. A gate of 0 removes the schedule from the
round's mixing matrix (its ppermute still runs and contributes weight zero),
so one-peer rotation, random schedule subsets, and throttled rounds are all
plain data through one executable. Gates compose with ``alive``: contributor
weight = gate[schedule] x alive[sender]. For 0/1 gates the fused reduction
matches :func:`mix_dense_gated` bit-for-bit in f32 on one-peer rounds (see
its docstring for the exact scope; 0/1 factors are exact in floating point).

Pipelined (one-round-delayed) gossip rides on top of the packed engine: the
``*_delayed`` executors mix this round's *fresh* local-step output with the
**previous** round's packed snapshot, carried across rounds as donated step
state. Because the snapshot is a step *input*, its d ppermutes have no data
dependency on the local-step scan and XLA's latency-hiding scheduler can run
the wire transfer under the whole scan — per-round wall-clock becomes
max(compute, comm) instead of compute + comm (asynchronous decentralized SGD
in the style of overlap-SGP). :func:`mix_dense_delayed` is the dense oracle
pinning the semantics; ``gossip_delay=0`` keeps the synchronous executors
untouched (bit-identical).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.topology import Overlay

__all__ = [
    "GossipSpec",
    "make_gossip_spec",
    "BlockedSpec",
    "make_blocked_spec",
    "alive_weight_table",
    "raw_contrib_tables",
    "gated_mixing_matrix",
    "mix_dense",
    "mix_dense_masked",
    "mix_dense_gated",
    "mix_dense_delayed",
    "mix_schedules",
    "mix_packed_stacked",
    "mix_packed_stacked_delayed",
    "pack_state_stacked",
    "ppermute_mix",
    "ppermute_mix_quantized",
    "ppermute_mix_packed",
    "ppermute_mix_packed_delayed",
    "ppermute_mix_packed_quantized",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    """Static gossip description (hashable => usable as a jit static arg).

    Attributes:
      n_clients: number of clients on the gossip axis.
      perms: per schedule, a tuple of (src, dst) pairs for ppermute — i.e.
        data flows src -> dst, where dst's mixing row has weight edge_weight at
        column src. Fixed points are excluded here and folded into self_weights.
      recv_from: per schedule, tuple of length n_clients: recv_from[s][i] is
        the client whose params client i receives under schedule s (i itself
        for fixed points). Used by the stacked-gather executor.
      self_weights: per-client diagonal weight (w0 + edge_weight * #fixed).
      edge_weight: the uniform Chow edge weight c.
      lam: lambda(M) of the mixing matrix (for reports).
      live_masks: per schedule, tuple of 0/1 per client: 1 iff the client
        receives from a *different* client under that schedule (i.e. it is not
        a fixed point). Derived host-side from recv_from so the stacked-gather
        executor never recomputes ``idx != arange(n)`` per (leaf x schedule).
    """

    n_clients: int
    perms: tuple[tuple[tuple[int, int], ...], ...]
    recv_from: tuple[tuple[int, ...], ...]
    self_weights: tuple[float, ...]
    edge_weight: float
    lam: float
    live_masks: tuple[tuple[int, ...], ...] | None = None

    def __post_init__(self):
        if self.live_masks is None:
            masks = tuple(
                tuple(int(src != i) for i, src in enumerate(rf))
                for rf in self.recv_from)
            object.__setattr__(self, "live_masks", masks)

    @property
    def degree(self) -> int:
        return len(self.perms)

    def fixed_masks_np(self) -> np.ndarray:
        """(S, n) 0/1: schedule s has a fixed point at client i (host-side)."""
        if self.degree == 0:
            return np.zeros((0, self.n_clients), np.float32)
        return 1.0 - np.asarray(self.live_masks, np.float32)

    def base_self_weights_np(self) -> np.ndarray:
        """(n,) self weights *without* the fixed-point edge folding — the w0
        each gated fixed point's c must be re-added to (gate pathway)."""
        fixed_counts = self.fixed_masks_np().sum(axis=0)
        return (np.asarray(self.self_weights, np.float32)
                - np.float32(self.edge_weight) * fixed_counts)


def make_gossip_spec(overlay: Overlay, theta: float | None = None) -> GossipSpec:
    """Bake an Overlay + Chow weights into a static GossipSpec."""
    w = overlay.chow_weights(theta)
    n = overlay.n
    perms = []
    recv_from = []
    fixed_counts = np.zeros(n, dtype=np.int64)
    for s in overlay.schedules:
        pairs = tuple(
            (int(s[i]), int(i)) for i in range(n) if int(s[i]) != i
        )  # i receives FROM s[i]: src=s[i], dst=i
        perms.append(pairs)
        recv_from.append(tuple(int(s[i]) for i in range(n)))
        fixed_counts += (s == np.arange(n)).astype(np.int64)
    self_w = tuple(float(w.self_weight + w.edge_weight * fixed_counts[i]) for i in range(n))
    return GossipSpec(
        n_clients=n,
        perms=tuple(perms),
        recv_from=tuple(recv_from),
        self_weights=self_w,
        edge_weight=float(w.edge_weight),
        lam=float(w.lam),
    )


# ---------------------------------------------------- blocked schedule split
@dataclasses.dataclass(frozen=True)
class BlockedSpec:
    """Static plan for the ``blocked`` substrate: n = n_devices x block
    clients, client ``i`` living on device ``i // block`` at stacked row
    ``i % block`` (hashable => usable as a jit static arg).

    Each overlay schedule is partitioned at build time into its intra-block
    part (a gather on the device-local stacked axis — free) and its
    cross-block part. A cross-block schedule's device-level demand graph
    ("device d needs device s's wire block") decomposes into *partial device
    permutations*; each becomes ONE ``ppermute`` of the whole per-device
    ``(block, rows, 128)`` wire buffer. The unit of transfer is the block,
    not the client: a schedule whose cross edges touch one neighbor device
    costs exactly one collective regardless of how many of its B clients
    cross (on a 2-device mesh every cross schedule is a single swap, so the
    collective count in HLO equals the number of cross-block schedules).

    Attributes:
      block: B, clients per device.
      n_devices: n_clients // block.
      transfers: flat tuple over ALL schedules' partial permutations —
        ``transfers[t]`` is the ppermute pair list ``((src_dev, dst_dev),
        ...)``. Not deduplicated across schedules (XLA CSE merges identical
        ppermutes of the same wire post-lowering; keeping them per-schedule
        keeps the slot bookkeeping local).
      schedule_transfers: per schedule, the global transfer ids it owns
        (empty for intra-block schedules).
      gather_flat: (S, n) int: for schedule s and client i, the flat index
        ``slot * block + src_row`` into the candidate stack
        ``concat([local_wire] + received_wires)`` reshaped to
        ``((T+1) * block, rows, 128)`` — slot 0 is the device's own wire,
        slot t+1 the block received by global transfer t.
    """

    block: int
    n_devices: int
    transfers: tuple[tuple[tuple[int, int], ...], ...]
    schedule_transfers: tuple[tuple[int, ...], ...]
    gather_flat: tuple[tuple[int, ...], ...]

    @property
    def n_transfers(self) -> int:
        return len(self.transfers)

    @property
    def cross_schedules(self) -> int:
        """How many schedules have at least one cross-block edge."""
        return sum(1 for t in self.schedule_transfers if t)


def _partition_demand(edges: list[tuple[int, int]]
                      ) -> list[tuple[tuple[int, int], ...]]:
    """Greedy split of a device-level demand edge set into partial
    permutations (no device sends or receives twice within one part)."""
    parts: list[list[tuple[int, int]]] = []
    for s, d in sorted(edges):
        for part in parts:
            if all(s != ps and d != pd for ps, pd in part):
                part.append((s, d))
                break
        else:
            parts.append([(s, d)])
    return [tuple(p) for p in parts]


def make_blocked_spec(spec: GossipSpec, block: int) -> BlockedSpec:
    """Partition a GossipSpec's schedules for B-clients-per-device execution.

    Host-side, O(S * n). Requires ``block`` to divide ``n_clients``; the
    resulting plan assumes row-major client placement (client i on device
    ``i // block``), which is what a ``P("clients")`` sharding of the stacked
    axis produces under shard_map.
    """
    n, b = spec.n_clients, int(block)
    if b < 1 or n % b:
        raise ValueError(
            f"blocked substrate needs block >= 1 dividing n_clients; got "
            f"block={block} for n_clients={n}")
    transfers: list[tuple[tuple[int, int], ...]] = []
    schedule_transfers: list[tuple[int, ...]] = []
    gather_flat: list[tuple[int, ...]] = []
    for rf in spec.recv_from:
        demand = sorted({(src // b, i // b)
                         for i, src in enumerate(rf) if src // b != i // b})
        parts = _partition_demand(list(demand))
        ids = tuple(range(len(transfers), len(transfers) + len(parts)))
        # slot of each cross (src_dev, dst_dev) pair within THIS schedule
        slot_of = {pair: 1 + ids[t] for t, part in enumerate(parts)
                   for pair in part}
        row = []
        for i, src in enumerate(rf):
            pair = (src // b, i // b)
            slot = 0 if pair[0] == pair[1] else slot_of[pair]
            row.append(slot * b + src % b)
        transfers.extend(parts)
        schedule_transfers.append(ids)
        gather_flat.append(tuple(row))
    return BlockedSpec(
        block=b,
        n_devices=n // b,
        transfers=tuple(transfers),
        schedule_transfers=tuple(schedule_transfers),
        gather_flat=tuple(gather_flat),
    )


# ----------------------------------------------------------------- executors
def mix_dense(tree: PyTree, m: jax.Array | np.ndarray) -> PyTree:
    """Reference: out_c = sum_d M[c, d] x_d over the leading (client) axis."""
    m = jnp.asarray(m)

    def _mix(x):
        flat = x.reshape(x.shape[0], -1)
        out = jnp.einsum("cd,df->cf", m.astype(flat.dtype), flat)
        return out.reshape(x.shape)

    return jax.tree.map(_mix, tree)


def mix_dense_masked(tree: PyTree, m: jax.Array | np.ndarray,
                     alive: jax.Array | np.ndarray) -> PyTree:
    """Failure-aware dense mixing (paper §5.2 semantics).

    Dead clients neither send nor update. Each surviving row renormalizes over
    its alive in-neighbors (incl. itself); dead rows keep their parameters.
    """
    m = jnp.asarray(m, dtype=jnp.float32)
    alive = jnp.asarray(alive, dtype=jnp.float32)
    masked = m * alive[None, :]  # zero dead senders
    row = masked.sum(axis=1, keepdims=True)
    renorm = masked / jnp.maximum(row, 1e-12)
    # dead receivers: identity row (they keep their params)
    eye = jnp.eye(m.shape[0], dtype=jnp.float32)
    eff = alive[:, None] * renorm + (1.0 - alive[:, None]) * eye
    return mix_dense(tree, eff)


def alive_weight_table(spec: GossipSpec, alive: jax.Array | None,
                       gates: jax.Array | None = None) -> jax.Array:
    """Renormalized mixing weights under (traced) alive + gate vectors:
    (n, S+1).

    Column 0 is the self weight, column 1+s the weight applied to the payload
    received under schedule s. Rows match ``mix_dense_gated`` exactly: each
    schedule's edge weight is scaled by its gate, dead senders are zeroed,
    each surviving row renormalizes over its gated alive in-neighborhood
    (incl. itself), and dead receivers get the identity row. A gated fixed
    point re-enters the self weight through the gate (the full-permutation
    convention: gate g_s scales P_s including its diagonal), so gating a
    schedule off is exactly removing it from the overlay. Both vectors are
    data, not structure — recomputing this table every round costs a few
    n x (S+1) vector ops and never retraces the step.
    """
    n, s_count = spec.n_clients, spec.degree
    alive = (jnp.ones(n, jnp.float32) if alive is None
             else jnp.asarray(alive, jnp.float32))
    if gates is None:
        self_w = jnp.asarray(spec.self_weights, jnp.float32)
        gates = jnp.ones(s_count, jnp.float32)
    else:
        gates = jnp.asarray(gates, jnp.float32)
        fixed = jnp.asarray(spec.fixed_masks_np())
        # clamp: dense overlays can have a *negative* Chow self weight
        # (w0 = 1 - c*S < 0 when lam_max(L) < 2S/(1+theta)); a gated subset
        # of such a row has no valid renormalization, so the gated path
        # projects onto the nonnegative (lazy) variant
        self_w = jnp.maximum(
            jnp.asarray(spec.base_self_weights_np())
            + spec.edge_weight * jnp.sum(gates[:, None] * fixed, axis=0), 0.0)
    cols = [spec.edge_weight * gates[s] * jnp.asarray(mask, jnp.float32)
            * jnp.take(alive, jnp.asarray(rf))
            for s, (rf, mask) in enumerate(zip(spec.recv_from,
                                               spec.live_masks))]
    ws = (jnp.stack(cols, axis=1) if cols else jnp.zeros((n, 0), jnp.float32))
    wa = jnp.concatenate([(self_w * alive)[:, None], ws], axis=1)
    tot = jnp.sum(wa, axis=1)
    # rows with no renormalizable mass (everything gated off / clamped
    # away) fall back to the identity INSTEAD of the renormalized weights
    # (inv is zeroed, not eps-clamped, so near-zero fractional mass cannot
    # leak a second, non-stochastic copy of the row on top of the fallback)
    ok = tot > 1e-12
    inv = jnp.where(ok, 1.0 / jnp.maximum(tot, 1e-12), 0.0)
    eff = alive[:, None] * wa * inv[:, None]
    fallback = (1.0 - alive) + alive * (1.0 - ok)
    return eff.at[:, 0].add(fallback)


def raw_contrib_tables(spec: GossipSpec, alive: jax.Array | None,
                       gates: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Stacked-substrate mirror of ``_local_raw_weights`` /
    ``_local_contrib_vec``: the pre-renormalization pieces of
    :func:`alive_weight_table`, vectorized over clients.

    Returns ``(raw, contrib)``, both (n, S+1). ``raw`` holds the unnormalized
    Chow weights — column 0 the (gated-clamped) self weight, columns 1+s the
    uniform edge weight c. ``contrib`` holds the per-contributor
    participation weights — column 0 the client's own liveness, column 1+s
    ``gate_s x live_mask_s x sender-liveness`` (zero at fixed points, so a
    schedule that delivers nothing is invisible). The trimmed-mean screen
    consumes these directly: ``contrib > 0`` decides who enters the order
    statistics and ``max(raw, 0) * contrib`` weighs the survivors; the
    renormalized product reproduces :func:`alive_weight_table` rows (minus
    the identity-fallback fold, which screens re-apply themselves).
    """
    n, s_count = spec.n_clients, spec.degree
    alive_v = (jnp.ones(n, jnp.float32) if alive is None
               else jnp.asarray(alive, jnp.float32))
    if gates is None:
        self_w = jnp.asarray(spec.self_weights, jnp.float32)
        gates_v = jnp.ones(s_count, jnp.float32)
    else:
        gates_v = jnp.asarray(gates, jnp.float32)
        fixed = jnp.asarray(spec.fixed_masks_np())
        # same clamp as alive_weight_table: a gated subset of a negative-w0
        # row projects onto the nonnegative (lazy) variant
        self_w = jnp.maximum(
            jnp.asarray(spec.base_self_weights_np())
            + spec.edge_weight * jnp.sum(gates_v[:, None] * fixed, axis=0),
            0.0)
    raw = jnp.concatenate(
        [self_w[:, None],
         jnp.full((n, s_count), spec.edge_weight, jnp.float32)], axis=1)
    cols = [gates_v[s] * jnp.asarray(mask, jnp.float32)
            * jnp.take(alive_v, jnp.asarray(rf))
            for s, (rf, mask) in enumerate(zip(spec.recv_from,
                                               spec.live_masks))]
    contrib = jnp.concatenate(
        [alive_v[:, None]] + [c[:, None] for c in cols], axis=1)
    return raw, contrib


def gated_mixing_matrix(spec: GossipSpec, gates: jax.Array | None = None,
                        alive: jax.Array | None = None) -> jax.Array:
    """Effective (row-stochastic) n x n mixing matrix under gates + alive.

    The dense oracle for the gated/masked packed executors: rows are the
    :func:`alive_weight_table` weights scattered to their sender columns, so
    for 0/1 gates and masks the scalar weights match the fused kernels'
    renormalization bit-for-bit in f32 (same op order, and 0/1 factors are
    exact). Traceable — ``gates``/``alive`` stay step data under jit.
    """
    n = spec.n_clients
    table = alive_weight_table(spec, alive, gates)
    m = jnp.zeros((n, n), jnp.float32)
    idx = jnp.arange(n)
    m = m.at[idx, idx].set(table[:, 0])
    for s, rf in enumerate(spec.recv_from):
        m = m.at[idx, jnp.asarray(rf)].add(table[:, 1 + s])
    return m


def mix_dense_gated(tree: PyTree, spec: GossipSpec,
                    gates: jax.Array | None = None,
                    alive: jax.Array | None = None) -> PyTree:
    """Dense reference for time-varying (gated) + failure-masked mixing.

    The reduction is an explicit multiply-then-sum (not a dot/einsum, whose
    FMA accumulation rounds differently), so with 0/1 gates and masks the
    packed executors reproduce this oracle **bit-for-bit in f32 whenever a
    row has at most two live contributors** (one-peer rotation: self + one
    sender; the remaining terms are exact zeros and f32 addition is
    commutative). With three or more live contributors the dense row (sender
    order) and the packed stack (schedule order) sum in different orders and
    may differ in the last ulp — compare with allclose there.
    """
    m = gated_mixing_matrix(spec, gates, alive)

    def _mix(x):
        flat = x.reshape(x.shape[0], -1).astype(jnp.float32)
        out = jnp.sum(m[:, :, None] * flat[None, :, :], axis=1)
        return out.astype(x.dtype).reshape(x.shape)

    return jax.tree.map(_mix, tree)


def mix_dense_delayed(fresh: PyTree, delayed: PyTree, spec: GossipSpec,
                      gates: jax.Array | None = None,
                      alive: jax.Array | None = None) -> PyTree:
    """Dense oracle for one-round-delayed (pipelined) gossip.

    Row i combines its own **fresh** value (this round's post-local-step
    params) with its neighbors' **delayed** values (their post-local-step
    params from the *previous* round, the in-flight snapshot)::

        out_i = w_i0 * fresh_i + sum_s w_i,1+s * delayed[recv_from[s][i]]

    with the exact :func:`alive_weight_table` weights — the self column
    (incl. folded fixed-point edge weight) always applies to the fresh value,
    matching the packed executors where fixed-point schedules deliver zeros.
    With ``delayed == fresh`` this is the synchronous gated/masked mixing,
    so delay is purely a data-staleness change, never a weight change. The
    reduction is an explicit multiply-then-sum in schedule order, so for 0/1
    gates/masks it matches the packed delayed executors with the same
    bit-for-bit scope as :func:`mix_dense_gated`.
    """
    table = alive_weight_table(spec, alive, gates)
    gathers = [jnp.asarray(rf) for rf in spec.recv_from]

    def _mix(xf, xd):
        ff = xf.reshape(xf.shape[0], -1).astype(jnp.float32)
        fd = xd.reshape(xd.shape[0], -1).astype(jnp.float32)
        out = table[:, 0][:, None] * ff
        for s, idx in enumerate(gathers):
            out = out + table[:, 1 + s][:, None] * jnp.take(fd, idx, axis=0)
        return out.astype(xf.dtype).reshape(xf.shape)

    return jax.tree.map(_mix, fresh, delayed)


def _static_weight_table(spec: GossipSpec) -> jax.Array:
    """All-alive weight table (host-side constant): (n, S+1)."""
    w0 = np.asarray(spec.self_weights, np.float32)[:, None]
    if spec.degree == 0:
        return jnp.asarray(w0)
    ws = np.stack([spec.edge_weight * np.asarray(m, np.float32)
                   for m in spec.live_masks], axis=1)
    return jnp.asarray(np.concatenate([w0, ws], axis=1))


def mix_schedules(tree: PyTree, spec: GossipSpec) -> PyTree:
    """Stacked-axis executor of the schedule decomposition (gather-based).

    out = self_weights * x + c * sum_s [recv_from[s] != id] * x[recv_from[s]]
    — fixed points contribute nothing here because their weight is already
    folded into self_weights (same arithmetic as the ppermute path, so this
    serves as its oracle).
    """
    self_w = jnp.asarray(spec.self_weights)
    # per-schedule gather indices and live masks, built once (host-side spec
    # data), shared across every leaf instead of recomputed per (leaf x sched)
    gathers = [(jnp.asarray(rf), jnp.asarray(mask, jnp.float32))
               for rf, mask in zip(spec.recv_from, spec.live_masks)]

    def _mix(x):
        w = self_w.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
        out = w * x
        for idx, mask in gathers:
            live = mask.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
            out = out + jnp.asarray(spec.edge_weight, dtype=x.dtype) * live * jnp.take(
                x, idx, axis=0)
        return out

    return jax.tree.map(_mix, tree)


def mix_packed_stacked(tree: PyTree, spec: GossipSpec,
                       alive: jax.Array | None = None, *,
                       gates: jax.Array | None = None,
                       pack_spec: packing.PackSpec | None = None) -> PyTree:
    """Stacked-axis packed executor — the simulator counterpart of
    :func:`ppermute_mix_packed` and the mixing path of the elastic runtime.

    The client-stacked pytree packs (vmapped) into one ``(n, rows, 128)``
    flat buffer per dtype, each schedule becomes one gather on the stacked
    axis, and the weighted reduction runs as a single fused contraction over
    the ``(n, S+1, rows, 128)`` stack — the XLA analogue of the
    ``gossip_mix_2d`` kernel pass, with none of the per-leaf flatten work of
    :func:`mix_schedules`. With ``alive`` (a *traced* ``(n,)`` 0/1 vector)
    the reduction uses the renormalized masked weights of
    :func:`alive_weight_table`, so straggler-set changes are plain data and
    never retrace the enclosing jit; ``gates`` (a traced per-schedule float
    vector, :mod:`repro.overlay.plan`) makes the round time-varying the same
    way — one-peer rotation and schedule subsets are weight changes, not new
    executables.

    Engine cell: ``stacked x f32 x sync`` (:mod:`repro.core.engine`).
    """
    from repro.core import engine as engine_lib

    ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="stacked", codec="f32"),
        spec, pack_spec=pack_spec)
    return ex(tree, alive=alive, gates=gates)


def _stacked_pack_spec(tree: PyTree) -> packing.PackSpec:
    """PackSpec of the client-stacked tree's per-client slice."""
    return packing.make_stacked_pack_spec(tree)


def pack_state_stacked(tree: PyTree,
                       pack_spec: packing.PackSpec | None = None
                       ) -> tuple[jax.Array, ...]:
    """Pack a client-stacked pytree into per-dtype ``(n, rows, 128)``
    snapshot buffers — the in-flight state of the delayed (pipelined) gossip
    round. Used once to prime the pipeline (round 0 mixes the *initial*
    params as its delayed snapshot) and by the delayed executors every round.
    The layout depends only on the parameter structure, never on the
    topology, so a splice repair remaps the snapshot by the same ``old2new``
    row permutation as the params (see ``launch/elastic.py``)."""
    if pack_spec is None:
        pack_spec = _stacked_pack_spec(tree)
    return jax.vmap(lambda t: packing.pack_tree(t, pack_spec))(tree)


def mix_packed_stacked_delayed(tree: PyTree,
                               snapshot: tuple[jax.Array, ...],
                               spec: GossipSpec,
                               alive: jax.Array | None = None, *,
                               gates: jax.Array | None = None,
                               pack_spec: packing.PackSpec | None = None
                               ) -> tuple[PyTree, tuple[jax.Array, ...]]:
    """Stacked-axis pipelined gossip: the simulator / elastic-runtime
    counterpart of :func:`ppermute_mix_packed_delayed`.

    ``tree`` is this round's fresh post-local-step state; ``snapshot`` is the
    previous round's :func:`pack_state_stacked` output (what is "on the
    wire"). Each schedule gathers from the *snapshot* while the self term
    stays fresh — :func:`mix_dense_delayed` semantics, with the same
    alive/gates weight table as the synchronous path. Returns the mixed tree
    and the new snapshot (this round's packed fresh state), to be carried as
    step state. With ``snapshot == pack_state_stacked(tree)`` the result is
    bit-identical to :func:`mix_packed_stacked` (same stack, same einsum).

    Engine cell: ``stacked x f32 x delayed`` (:mod:`repro.core.engine`).
    """
    from repro.core import engine as engine_lib

    ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="stacked", codec="f32",
                                      delay=1),
        spec, pack_spec=pack_spec)
    return ex(tree, state=snapshot, alive=alive, gates=gates)


def _axis_size(name: str) -> jax.Array | int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)  # pre-0.4.38 spelling; folds to a constant


def _client_index(axis_names: str | tuple[str, ...]) -> jax.Array:
    """Flattened client index over (possibly) multiple mesh axes, row-major."""
    if isinstance(axis_names, str):
        return jax.lax.axis_index(axis_names)
    idx = jax.lax.axis_index(axis_names[0])
    for name in axis_names[1:]:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def ppermute_mix(tree: PyTree, spec: GossipSpec,
                 axis_names: str | tuple[str, ...]) -> PyTree:
    """Production gossip: one collective-permute per schedule (call in shard_map).

    Every leaf holds the *local shard* of the local client's value; the client
    axis is the mesh axis/axes in ``axis_names``. All ppermutes are issued
    before any sums so XLA can overlap them.

    Engine cell: ``per_leaf x f32 x sync`` (:mod:`repro.core.engine`).
    """
    from repro.core import engine as engine_lib

    ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="per_leaf", codec="f32"),
        spec, axis_names=axis_names)
    return ex(tree)


def ppermute_mix_quantized(tree: PyTree, spec: GossipSpec,
                           axis_names: str | tuple[str, ...]) -> PyTree:
    """Beyond-paper: gossip with int8-quantized payloads (4x/2x fewer ICI bytes).

    Each leaf is symmetrically quantized per-tensor to int8 with an f32 scale;
    neighbors dequantize before the weighted sum. The *local* term stays full
    precision, so quantization error only enters through the (small) edge
    weights.

    Engine cell: ``per_leaf x int8 x sync`` (:mod:`repro.core.engine`).
    """
    from repro.core import engine as engine_lib

    ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="per_leaf", codec="int8"),
        spec, axis_names=axis_names)
    return ex(tree)


# ------------------------------------------------------- packed executors
def _live_schedules(spec: GossipSpec):
    """(schedule idx, perm pairs, recv_from, live_mask) for schedules with
    any exchange (the index keys this schedule's entry in a gate vector)."""
    return [(s, list(pairs), rf, mask)
            for s, (pairs, rf, mask) in enumerate(zip(spec.perms,
                                                      spec.recv_from,
                                                      spec.live_masks))
            if len(pairs) > 0]


def _local_raw_weights(spec: GossipSpec, idx: jax.Array, n_live: int,
                       gates: jax.Array | None = None) -> jax.Array:
    """This client's *unnormalized* Chow weights (w0, c, ..., c): (d+1,).

    With ``gates``, the self weight follows the full-permutation convention:
    each schedule's fixed-point contribution c re-scales by its gate, so
    gating a schedule off removes it from the mixing matrix entirely.
    """
    if gates is None:
        self_w = jnp.asarray(spec.self_weights)[idx].astype(jnp.float32)
    else:
        fixed = jnp.asarray(spec.fixed_masks_np())
        # clamped like alive_weight_table: a gated subset of a negative-w0
        # row projects onto the nonnegative (lazy) variant
        self_w = jnp.maximum(
            jnp.asarray(spec.base_self_weights_np())[idx]
            + spec.edge_weight
            * jnp.sum(jnp.asarray(gates, jnp.float32) * fixed[:, idx]), 0.0)
    return jnp.concatenate([
        self_w[None], jnp.full((n_live,), spec.edge_weight, jnp.float32)])


def _local_contrib_vec(spec: GossipSpec, idx: jax.Array, live,
                       alive: jax.Array | None,
                       gates: jax.Array | None) -> jax.Array:
    """Per-contributor weights for the renormalized fused reduction: (d+1,).

    Entry 0 is this client's own liveness; entry 1+k the k-th schedule's
    gate x sender-liveness (zero at fixed points). Renormalization over the
    gated live in-degree happens inside the fused kernel. The sender's
    liveness is a *gather from the replicated alive vector* via the static
    recv_from table, and the gate a gather from the replicated gate vector —
    neither costs extra collectives.
    """
    one = jnp.float32(1.0)
    alive = None if alive is None else jnp.asarray(alive, jnp.float32)
    gates = None if gates is None else jnp.asarray(gates, jnp.float32)
    srcs = []
    for s, _, rf, mask in live:
        v = jnp.asarray(mask, jnp.float32)[idx]
        if gates is not None:
            v = gates[s] * v
        if alive is not None:
            v = v * alive[jnp.asarray(rf)[idx]]
        srcs.append(v)
    return jnp.stack([one if alive is None else alive[idx]] + srcs)


def ppermute_mix_packed(tree: PyTree, spec: GossipSpec,
                        axis_names: str | tuple[str, ...], *,
                        pack_spec: packing.PackSpec | None = None,
                        mix_impl: str = "auto",
                        alive: jax.Array | None = None,
                        gates: jax.Array | None = None) -> PyTree:
    """Packed production gossip: d collectives/round, one fused HBM reduction.

    The client-local pytree packs into one lane-aligned flat buffer per dtype
    (:mod:`repro.core.packing`); each schedule then permutes the *whole*
    buffer in a single ``lax.ppermute`` — d collectives per round regardless
    of leaf count, vs d x n_leaves for :func:`ppermute_mix`. Self + the d
    received buffers stack to ``(d+1, rows, 128)`` and reduce in **one** HBM
    pass through the fused ``gossip_mix_2d`` Pallas kernel (interpret/ref off
    TPU). Fixed-point schedules deliver zeros (ppermute semantics), which the
    kernel's weighted sum absorbs — same arithmetic as the per-leaf path.

    ``alive`` (a traced, replicated ``(n_clients,)`` 0/1 vector) makes the
    round failure-aware with :func:`mix_dense_masked` semantics: dead senders
    are masked out of the reduction (their weight gathers to zero from the
    replicated vector — no extra collectives), each survivor renormalizes
    over its live in-degree inside the fused kernel, and a dead client keeps
    its own parameters. Because ``alive`` is data, straggler churn never
    retraces the step.

    ``gates`` (a traced, replicated per-schedule float vector,
    :mod:`repro.overlay.plan`) makes the round *time-varying* through the
    identical mechanism: each schedule's contributor weight scales by its
    gate before the in-kernel renormalization, so one-peer rotation,
    schedule subsets, and throttled rounds reuse this one executable with
    zero retraces. All d ppermutes still run — a gated-off schedule's
    payload lands with weight exactly 0 — keeping liveness AND the round
    plan out of trace structure.

    Pass ``pack_spec`` (built host-side from shape structs) to bake the
    layout into the jitted step; it is derived from ``tree`` otherwise.

    Engine cell: ``shard_map x f32 x sync`` (:mod:`repro.core.engine`) —
    pinned to lower to HLO textually identical to the pre-refactor body.
    """
    from repro.core import engine as engine_lib

    ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="shard_map", codec="f32",
                                      mix_impl=mix_impl),
        spec, axis_names=axis_names, pack_spec=pack_spec)
    return ex(tree, alive=alive, gates=gates)


def ppermute_mix_packed_delayed(tree: PyTree,
                                state_bufs: tuple[jax.Array, ...],
                                spec: GossipSpec,
                                axis_names: str | tuple[str, ...], *,
                                pack_spec: packing.PackSpec | None = None,
                                mix_impl: str = "auto",
                                alive: jax.Array | None = None,
                                gates: jax.Array | None = None
                                ) -> tuple[PyTree, tuple[jax.Array, ...]]:
    """Pipelined packed gossip (``gossip_delay=1``): d collectives/round on
    the *previous* round's snapshot, overlapped with this round's compute.

    ``state_bufs`` is the carried in-flight state: the per-device packed
    buffers of last round's post-local-step shard tree (this function's
    second return value, primed with the initial params). Each schedule
    ppermutes the **snapshot**, not the fresh buffer — the permutes' operand
    is a step *input*, so they have no data dependency on the local-step
    scan that produced ``tree`` and XLA's async collectives
    (permute-start/permute-done) run the wire transfer under the scan. The
    fused ``gossip_mix_2d`` reduction then combines the fresh self buffer
    with the d delayed received buffers using the *identical* raw-weight /
    alive / gates operands as :func:`ppermute_mix_packed` — delay changes
    which round's bytes are on the wire, never the mixing weights
    (:func:`mix_dense_delayed` is the oracle). Feeding
    ``state_bufs == pack_tree(tree)`` reproduces the synchronous executor
    bit-for-bit, which is the delay=0 regression anchor.

    Returns ``(mixed tree, new state_bufs)`` where the new state is this
    round's fresh packed buffers (what round t+1 will mix).

    Engine cell: ``shard_map x f32 x delayed`` (:mod:`repro.core.engine`).
    """
    from repro.core import engine as engine_lib

    ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(substrate="shard_map", codec="f32",
                                      delay=1, mix_impl=mix_impl),
        spec, axis_names=axis_names, pack_spec=pack_spec)
    return ex(tree, state=state_bufs, alive=alive, gates=gates)


def ppermute_mix_packed_quantized(tree: PyTree, spec: GossipSpec,
                                  axis_names: str | tuple[str, ...], *,
                                  pack_spec: packing.PackSpec | None = None,
                                  impl: str = "auto",
                                  alive: jax.Array | None = None,
                                  gates: jax.Array | None = None,
                                  block_scales: bool = True) -> PyTree:
    """Packed gossip with int8 wire payloads (4x/2x fewer ICI bytes).

    The packed buffer quantizes once through the Pallas quantize kernel,
    and the f32 scales are **folded into the shipped int8 buffer** as
    trailing lane rows (:func:`~repro.kernels.quant_gossip.ops.
    fold_scales_into_wire`), so each schedule ships exactly **one**
    collective — d per round, down from the 2d payload+scale pairs this
    path used to issue. Every received wire buffer splits back into
    (int8 payload, scales) with static slices and folds into the
    accumulator through the fused ``dequant_accumulate_2d`` kernel family
    (dequant + scale + add in one HBM pass per neighbor). The local term
    stays full precision, so the int8 error only enters through the
    (small) edge weights.

    ``block_scales`` (default) quantizes with **one scale per row-block
    kernel tile** instead of per buffer: a tile of small-magnitude
    parameters (norm gains, biases) no longer inherits the quantization
    step of the buffer-wide amax, which closes the PR-1 follow-up. The
    scales ride the same wire buffer (32 per lane row), so the collective
    count is unchanged; ``block_scales=False`` keeps the PR-3 per-buffer
    format.

    ``alive`` has :func:`mix_dense_masked` semantics and ``gates``
    (per-schedule floats) the time-varying semantics, both exactly as in
    :func:`ppermute_mix_packed`: the renormalizing denominator is a handful
    of scalar ops, the self term is rescaled up front, and each sender's
    renormalized gate x alive weight rides into its fused
    dequant-accumulate pass — masked or gated rounds do the same HBM
    traffic as plain ones.

    Engine cell: ``shard_map x int8_block x sync`` (``int8`` with
    ``block_scales=False``; :mod:`repro.core.engine`).
    """
    from repro.core import engine as engine_lib

    ex = engine_lib.build_gossip_executor(
        engine_lib.GossipEngineConfig(
            substrate="shard_map",
            codec="int8_block" if block_scales else "int8", mix_impl=impl),
        spec, axis_names=axis_names, pack_spec=pack_spec)
    return ex(tree, alive=alive, gates=gates)
