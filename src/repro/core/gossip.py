"""Gossip executors: how a mixing round `w <- M w` actually runs.

Three executors, one semantics:

1. ``mix_dense``      — dense ``einsum('cd,d...->c...')`` over a stacked client
                        axis. The reference / oracle; also what a *naive* port
                        of the paper's simulator does on a TPU mesh (XLA turns
                        it into an all-gather of every client's parameters —
                        this is the paper-faithful baseline in §Perf).
2. ``mix_schedules``  — gather-based evaluation of the schedule decomposition
                        on a stacked client axis (simulator fast path; oracle
                        for the ppermute path).
3. ``ppermute_mix``   — the production path: inside ``shard_map``, one
                        ``jax.lax.ppermute`` per schedule along the client mesh
                        axes + a weighted sum. d single-hop neighbor exchanges,
                        no gather, overlappable with compute.

A :class:`GossipSpec` is the static, hashable description baked into the
jitted step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Overlay

__all__ = [
    "GossipSpec",
    "make_gossip_spec",
    "mix_dense",
    "mix_dense_masked",
    "mix_schedules",
    "ppermute_mix",
    "ppermute_mix_quantized",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    """Static gossip description (hashable => usable as a jit static arg).

    Attributes:
      n_clients: number of clients on the gossip axis.
      perms: per schedule, a tuple of (src, dst) pairs for ppermute — i.e.
        data flows src -> dst, where dst's mixing row has weight edge_weight at
        column src. Fixed points are excluded here and folded into self_weights.
      recv_from: per schedule, tuple of length n_clients: recv_from[s][i] is
        the client whose params client i receives under schedule s (i itself
        for fixed points). Used by the stacked-gather executor.
      self_weights: per-client diagonal weight (w0 + edge_weight * #fixed).
      edge_weight: the uniform Chow edge weight c.
      lam: lambda(M) of the mixing matrix (for reports).
    """

    n_clients: int
    perms: tuple[tuple[tuple[int, int], ...], ...]
    recv_from: tuple[tuple[int, ...], ...]
    self_weights: tuple[float, ...]
    edge_weight: float
    lam: float

    @property
    def degree(self) -> int:
        return len(self.perms)


def make_gossip_spec(overlay: Overlay, theta: float | None = None) -> GossipSpec:
    """Bake an Overlay + Chow weights into a static GossipSpec."""
    w = overlay.chow_weights(theta)
    n = overlay.n
    perms = []
    recv_from = []
    fixed_counts = np.zeros(n, dtype=np.int64)
    for s in overlay.schedules:
        pairs = tuple(
            (int(s[i]), int(i)) for i in range(n) if int(s[i]) != i
        )  # i receives FROM s[i]: src=s[i], dst=i
        perms.append(pairs)
        recv_from.append(tuple(int(s[i]) for i in range(n)))
        fixed_counts += (s == np.arange(n)).astype(np.int64)
    self_w = tuple(float(w.self_weight + w.edge_weight * fixed_counts[i]) for i in range(n))
    return GossipSpec(
        n_clients=n,
        perms=tuple(perms),
        recv_from=tuple(recv_from),
        self_weights=self_w,
        edge_weight=float(w.edge_weight),
        lam=float(w.lam),
    )


# ----------------------------------------------------------------- executors
def mix_dense(tree: PyTree, m: jax.Array | np.ndarray) -> PyTree:
    """Reference: out_c = sum_d M[c, d] x_d over the leading (client) axis."""
    m = jnp.asarray(m)

    def _mix(x):
        flat = x.reshape(x.shape[0], -1)
        out = jnp.einsum("cd,df->cf", m.astype(flat.dtype), flat)
        return out.reshape(x.shape)

    return jax.tree.map(_mix, tree)


def mix_dense_masked(tree: PyTree, m: jax.Array | np.ndarray,
                     alive: jax.Array | np.ndarray) -> PyTree:
    """Failure-aware dense mixing (paper §5.2 semantics).

    Dead clients neither send nor update. Each surviving row renormalizes over
    its alive in-neighbors (incl. itself); dead rows keep their parameters.
    """
    m = jnp.asarray(m, dtype=jnp.float32)
    alive = jnp.asarray(alive, dtype=jnp.float32)
    masked = m * alive[None, :]  # zero dead senders
    row = masked.sum(axis=1, keepdims=True)
    renorm = masked / jnp.maximum(row, 1e-12)
    # dead receivers: identity row (they keep their params)
    eye = jnp.eye(m.shape[0], dtype=jnp.float32)
    eff = alive[:, None] * renorm + (1.0 - alive[:, None]) * eye
    return mix_dense(tree, eff)


def mix_schedules(tree: PyTree, spec: GossipSpec) -> PyTree:
    """Stacked-axis executor of the schedule decomposition (gather-based).

    out = self_weights * x + c * sum_s [recv_from[s] != id] * x[recv_from[s]]
    — fixed points contribute nothing here because their weight is already
    folded into self_weights (same arithmetic as the ppermute path, so this
    serves as its oracle).
    """
    self_w = jnp.asarray(spec.self_weights)
    n = spec.n_clients

    def _mix(x):
        w = self_w.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
        out = w * x
        for rf in spec.recv_from:
            idx = jnp.asarray(rf)
            live = (idx != jnp.arange(n)).astype(x.dtype)
            live = live.reshape((-1,) + (1,) * (x.ndim - 1))
            out = out + jnp.asarray(spec.edge_weight, dtype=x.dtype) * live * jnp.take(
                x, idx, axis=0)
        return out

    return jax.tree.map(_mix, tree)


def _client_index(axis_names: str | tuple[str, ...]) -> jax.Array:
    """Flattened client index over (possibly) multiple mesh axes, row-major."""
    if isinstance(axis_names, str):
        return jax.lax.axis_index(axis_names)
    idx = jax.lax.axis_index(axis_names[0])
    for name in axis_names[1:]:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def ppermute_mix(tree: PyTree, spec: GossipSpec,
                 axis_names: str | tuple[str, ...]) -> PyTree:
    """Production gossip: one collective-permute per schedule (call in shard_map).

    Every leaf holds the *local shard* of the local client's value; the client
    axis is the mesh axis/axes in ``axis_names``. All ppermutes are issued
    before any sums so XLA can overlap them.
    """
    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx]

    def _mix(x):
        received = [
            jax.lax.ppermute(x, axis_names, perm=list(pairs))
            for pairs in spec.perms
            if len(pairs) > 0
        ]
        out = self_w.astype(x.dtype) * x
        c = jnp.asarray(spec.edge_weight, dtype=x.dtype)
        for r in received:
            out = out + c * r
        return out

    return jax.tree.map(_mix, tree)


def ppermute_mix_quantized(tree: PyTree, spec: GossipSpec,
                           axis_names: str | tuple[str, ...]) -> PyTree:
    """Beyond-paper: gossip with int8-quantized payloads (4x/2x fewer ICI bytes).

    Each leaf is symmetrically quantized per-tensor to int8 with an f32 scale;
    neighbors dequantize before the weighted sum. The *local* term stays full
    precision, so quantization error only enters through the (small) edge
    weights.
    """
    from repro.kernels.quant_gossip import ops as qops

    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx]

    def _mix(x):
        q, scale = qops.quantize_int8(x)
        received = []
        for pairs in spec.perms:
            if len(pairs) == 0:
                continue
            rq = jax.lax.ppermute(q, axis_names, perm=list(pairs))
            rs = jax.lax.ppermute(scale, axis_names, perm=list(pairs))
            received.append(qops.dequantize_int8(rq, rs, x.dtype))
        out = self_w.astype(x.dtype) * x
        c = jnp.asarray(spec.edge_weight, dtype=x.dtype)
        for r in received:
            out = out + c * r
        return out

    return jax.tree.map(_mix, tree)
