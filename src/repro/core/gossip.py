"""Gossip executors: how a mixing round `w <- M w` actually runs.

Five executors, one semantics:

1. ``mix_dense``      — dense ``einsum('cd,d...->c...')`` over a stacked client
                        axis. The reference / oracle; also what a *naive* port
                        of the paper's simulator does on a TPU mesh (XLA turns
                        it into an all-gather of every client's parameters —
                        this is the paper-faithful baseline in §Perf).
2. ``mix_schedules``  — gather-based evaluation of the schedule decomposition
                        on a stacked client axis (simulator fast path; oracle
                        for the ppermute paths).
3. ``ppermute_mix``   — per-leaf shard_map path: one ``jax.lax.ppermute`` per
                        (schedule x pytree leaf) along the client mesh axes +
                        an unfused weighted sum. d single-hop exchanges per
                        leaf, no gather. Kept as the packed path's baseline.
4. ``ppermute_mix_packed`` — the production path: the parameter pytree is
                        packed into one lane-aligned ``(rows, 128)`` flat
                        buffer per dtype (:mod:`repro.core.packing`), so a
                        round is **d ppermutes total** (one per schedule,
                        independent of leaf count — fewer, larger,
                        overlappable collectives) and the weighted reduction
                        of self + d received buffers is **one HBM pass**
                        through the fused ``gossip_mix_2d`` Pallas kernel.
5. ``ppermute_mix_packed_quantized`` — packed + int8 payloads: the packed
                        buffer quantizes through the Pallas ``quantize_2d``
                        kernel (4x/2x fewer ICI bytes) and each received
                        buffer folds in via the fused ``dequant_accumulate_2d``
                        kernel. (``ppermute_mix_quantized`` is the per-leaf
                        jnp-level equivalent.)

A :class:`GossipSpec` is the static, hashable description baked into the
jitted step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.topology import Overlay

__all__ = [
    "GossipSpec",
    "make_gossip_spec",
    "mix_dense",
    "mix_dense_masked",
    "mix_schedules",
    "ppermute_mix",
    "ppermute_mix_quantized",
    "ppermute_mix_packed",
    "ppermute_mix_packed_quantized",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    """Static gossip description (hashable => usable as a jit static arg).

    Attributes:
      n_clients: number of clients on the gossip axis.
      perms: per schedule, a tuple of (src, dst) pairs for ppermute — i.e.
        data flows src -> dst, where dst's mixing row has weight edge_weight at
        column src. Fixed points are excluded here and folded into self_weights.
      recv_from: per schedule, tuple of length n_clients: recv_from[s][i] is
        the client whose params client i receives under schedule s (i itself
        for fixed points). Used by the stacked-gather executor.
      self_weights: per-client diagonal weight (w0 + edge_weight * #fixed).
      edge_weight: the uniform Chow edge weight c.
      lam: lambda(M) of the mixing matrix (for reports).
      live_masks: per schedule, tuple of 0/1 per client: 1 iff the client
        receives from a *different* client under that schedule (i.e. it is not
        a fixed point). Derived host-side from recv_from so the stacked-gather
        executor never recomputes ``idx != arange(n)`` per (leaf x schedule).
    """

    n_clients: int
    perms: tuple[tuple[tuple[int, int], ...], ...]
    recv_from: tuple[tuple[int, ...], ...]
    self_weights: tuple[float, ...]
    edge_weight: float
    lam: float
    live_masks: tuple[tuple[int, ...], ...] | None = None

    def __post_init__(self):
        if self.live_masks is None:
            masks = tuple(
                tuple(int(src != i) for i, src in enumerate(rf))
                for rf in self.recv_from)
            object.__setattr__(self, "live_masks", masks)

    @property
    def degree(self) -> int:
        return len(self.perms)


def make_gossip_spec(overlay: Overlay, theta: float | None = None) -> GossipSpec:
    """Bake an Overlay + Chow weights into a static GossipSpec."""
    w = overlay.chow_weights(theta)
    n = overlay.n
    perms = []
    recv_from = []
    fixed_counts = np.zeros(n, dtype=np.int64)
    for s in overlay.schedules:
        pairs = tuple(
            (int(s[i]), int(i)) for i in range(n) if int(s[i]) != i
        )  # i receives FROM s[i]: src=s[i], dst=i
        perms.append(pairs)
        recv_from.append(tuple(int(s[i]) for i in range(n)))
        fixed_counts += (s == np.arange(n)).astype(np.int64)
    self_w = tuple(float(w.self_weight + w.edge_weight * fixed_counts[i]) for i in range(n))
    return GossipSpec(
        n_clients=n,
        perms=tuple(perms),
        recv_from=tuple(recv_from),
        self_weights=self_w,
        edge_weight=float(w.edge_weight),
        lam=float(w.lam),
    )


# ----------------------------------------------------------------- executors
def mix_dense(tree: PyTree, m: jax.Array | np.ndarray) -> PyTree:
    """Reference: out_c = sum_d M[c, d] x_d over the leading (client) axis."""
    m = jnp.asarray(m)

    def _mix(x):
        flat = x.reshape(x.shape[0], -1)
        out = jnp.einsum("cd,df->cf", m.astype(flat.dtype), flat)
        return out.reshape(x.shape)

    return jax.tree.map(_mix, tree)


def mix_dense_masked(tree: PyTree, m: jax.Array | np.ndarray,
                     alive: jax.Array | np.ndarray) -> PyTree:
    """Failure-aware dense mixing (paper §5.2 semantics).

    Dead clients neither send nor update. Each surviving row renormalizes over
    its alive in-neighbors (incl. itself); dead rows keep their parameters.
    """
    m = jnp.asarray(m, dtype=jnp.float32)
    alive = jnp.asarray(alive, dtype=jnp.float32)
    masked = m * alive[None, :]  # zero dead senders
    row = masked.sum(axis=1, keepdims=True)
    renorm = masked / jnp.maximum(row, 1e-12)
    # dead receivers: identity row (they keep their params)
    eye = jnp.eye(m.shape[0], dtype=jnp.float32)
    eff = alive[:, None] * renorm + (1.0 - alive[:, None]) * eye
    return mix_dense(tree, eff)


def mix_schedules(tree: PyTree, spec: GossipSpec) -> PyTree:
    """Stacked-axis executor of the schedule decomposition (gather-based).

    out = self_weights * x + c * sum_s [recv_from[s] != id] * x[recv_from[s]]
    — fixed points contribute nothing here because their weight is already
    folded into self_weights (same arithmetic as the ppermute path, so this
    serves as its oracle).
    """
    self_w = jnp.asarray(spec.self_weights)
    # per-schedule gather indices and live masks, built once (host-side spec
    # data), shared across every leaf instead of recomputed per (leaf x sched)
    gathers = [(jnp.asarray(rf), jnp.asarray(mask, jnp.float32))
               for rf, mask in zip(spec.recv_from, spec.live_masks)]

    def _mix(x):
        w = self_w.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
        out = w * x
        for idx, mask in gathers:
            live = mask.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
            out = out + jnp.asarray(spec.edge_weight, dtype=x.dtype) * live * jnp.take(
                x, idx, axis=0)
        return out

    return jax.tree.map(_mix, tree)


def _axis_size(name: str) -> jax.Array | int:
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)  # pre-0.4.38 spelling; folds to a constant


def _client_index(axis_names: str | tuple[str, ...]) -> jax.Array:
    """Flattened client index over (possibly) multiple mesh axes, row-major."""
    if isinstance(axis_names, str):
        return jax.lax.axis_index(axis_names)
    idx = jax.lax.axis_index(axis_names[0])
    for name in axis_names[1:]:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def ppermute_mix(tree: PyTree, spec: GossipSpec,
                 axis_names: str | tuple[str, ...]) -> PyTree:
    """Production gossip: one collective-permute per schedule (call in shard_map).

    Every leaf holds the *local shard* of the local client's value; the client
    axis is the mesh axis/axes in ``axis_names``. All ppermutes are issued
    before any sums so XLA can overlap them.
    """
    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx]

    def _mix(x):
        received = [
            jax.lax.ppermute(x, axis_names, perm=list(pairs))
            for pairs in spec.perms
            if len(pairs) > 0
        ]
        out = self_w.astype(x.dtype) * x
        c = jnp.asarray(spec.edge_weight, dtype=x.dtype)
        for r in received:
            out = out + c * r
        return out

    return jax.tree.map(_mix, tree)


def ppermute_mix_quantized(tree: PyTree, spec: GossipSpec,
                           axis_names: str | tuple[str, ...]) -> PyTree:
    """Beyond-paper: gossip with int8-quantized payloads (4x/2x fewer ICI bytes).

    Each leaf is symmetrically quantized per-tensor to int8 with an f32 scale;
    neighbors dequantize before the weighted sum. The *local* term stays full
    precision, so quantization error only enters through the (small) edge
    weights.
    """
    from repro.kernels.quant_gossip import ops as qops

    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx]

    def _mix(x):
        q, scale = qops.quantize_int8(x)
        received = []
        for pairs in spec.perms:
            if len(pairs) == 0:
                continue
            rq = jax.lax.ppermute(q, axis_names, perm=list(pairs))
            rs = jax.lax.ppermute(scale, axis_names, perm=list(pairs))
            received.append(qops.dequantize_int8(rq, rs, x.dtype))
        out = self_w.astype(x.dtype) * x
        c = jnp.asarray(spec.edge_weight, dtype=x.dtype)
        for r in received:
            out = out + c * r
        return out

    return jax.tree.map(_mix, tree)


# ------------------------------------------------------- packed executors
def ppermute_mix_packed(tree: PyTree, spec: GossipSpec,
                        axis_names: str | tuple[str, ...], *,
                        pack_spec: packing.PackSpec | None = None,
                        mix_impl: str = "auto") -> PyTree:
    """Packed production gossip: d collectives/round, one fused HBM reduction.

    The client-local pytree packs into one lane-aligned flat buffer per dtype
    (:mod:`repro.core.packing`); each schedule then permutes the *whole*
    buffer in a single ``lax.ppermute`` — d collectives per round regardless
    of leaf count, vs d x n_leaves for :func:`ppermute_mix`. Self + the d
    received buffers stack to ``(d+1, rows, 128)`` and reduce in **one** HBM
    pass through the fused ``gossip_mix_2d`` Pallas kernel (interpret/ref off
    TPU). Fixed-point schedules deliver zeros (ppermute semantics), which the
    kernel's weighted sum absorbs — same arithmetic as the per-leaf path.

    Pass ``pack_spec`` (built host-side from shape structs) to bake the
    layout into the jitted step; it is derived from ``tree`` otherwise.
    """
    from repro.kernels.gossip_mix import ops as mix_ops

    if pack_spec is None:
        pack_spec = packing.make_pack_spec(tree)
    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx].astype(jnp.float32)
    perms = [list(pairs) for pairs in spec.perms if len(pairs) > 0]

    out_bufs = []
    for buf in packing.pack_tree(tree, pack_spec):
        # all ppermutes issued before the reduction so XLA can overlap them
        received = [jax.lax.ppermute(buf, axis_names, perm=p) for p in perms]
        stack = jnp.stack([buf] + received)
        weights = jnp.concatenate([
            self_w[None],
            jnp.full((len(received),), spec.edge_weight, jnp.float32)])
        out_bufs.append(mix_ops.gossip_mix_packed(
            stack, weights, block_rows=pack_spec.block_rows, impl=mix_impl))
    return packing.unpack_tree(tuple(out_bufs), pack_spec)


def ppermute_mix_packed_quantized(tree: PyTree, spec: GossipSpec,
                                  axis_names: str | tuple[str, ...], *,
                                  pack_spec: packing.PackSpec | None = None,
                                  impl: str = "auto") -> PyTree:
    """Packed gossip with int8 wire payloads (4x/2x fewer ICI bytes).

    The packed buffer quantizes once through the Pallas ``quantize_2d`` kernel
    (per-buffer symmetric scale); each schedule permutes the int8 buffer + its
    f32 scale, and every received payload folds into the accumulator through
    the fused ``dequant_accumulate_2d`` kernel (dequant + scale + add in one
    HBM pass per neighbor). The local term stays full precision, so the int8
    error only enters through the (small) edge weights. Note the scale is
    per-buffer rather than per-leaf, so the error bound is governed by the
    buffer-wide amax; and each schedule ships *two* collectives (int8 buffer
    + its 4-byte f32 scale), i.e. 2d per round — still leaf-count-independent,
    but folding the scale into the shipped buffer is an open follow-up.
    """
    from repro.kernels.quant_gossip import ops as qops

    if pack_spec is None:
        pack_spec = packing.make_pack_spec(tree)
    idx = _client_index(axis_names)
    self_w = jnp.asarray(spec.self_weights)[idx]
    perms = [list(pairs) for pairs in spec.perms if len(pairs) > 0]
    c = float(spec.edge_weight)

    out_bufs = []
    for buf in packing.pack_tree(tree, pack_spec):
        q, scale = qops.quantize_packed(buf, block_rows=pack_spec.block_rows,
                                        impl=impl)
        acc = self_w.astype(buf.dtype) * buf
        for p in perms:
            rq = jax.lax.ppermute(q, axis_names, perm=p)
            rs = jax.lax.ppermute(scale, axis_names, perm=p)
            acc = qops.dequant_accumulate_packed(
                rq, rs, c, acc, block_rows=pack_spec.block_rows, impl=impl)
        out_bufs.append(acc)
    return packing.unpack_tree(tuple(out_bufs), pack_spec)
