"""Client-failure modeling, straggler mitigation, and overlay repair (paper §4.1, §5.2).

Two layers of resilience, matching the paper's protocol:

1. *Transient* (per-round) failures / stragglers: a client misses one gossip
   round. Surviving neighbors renormalize their mixing weights over the alive
   in-neighborhood. The production path for this is the **packed gossip
   engine**: the alive mask is a *traced step argument* consumed by the
   packed executors / fused kernels (`gossip.ppermute_mix_packed(alive=...)`,
   `gossip.mix_packed_stacked`), so straggler churn never re-jits — liveness
   is data, not trace structure. (`alive_adjusted_spec`, which bakes the mask
   into a fresh GossipSpec and therefore costs one retrace per straggler-set
   change, is kept only as a host-side reference for the deprecated
   schedule-path executors; `mix_dense_masked` is the numerical oracle.)
2. *Permanent* failures: the two-hop splice repair (`Overlay.remove_nodes`)
   rebuilds the schedules; `repair_and_remap` additionally remaps any stacked
   client state so training resumes with the survivors, and returns the
   survivor index map (`old2new`) so callers can remap *their* per-client
   state (optimizer slots, data shards, health counters) consistently.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gossip as gossip_lib
from repro.core.topology import Overlay

__all__ = [
    "FailurePlan",
    "sample_failures",
    "alive_adjusted_spec",
    "repair_and_remap",
    "HealthTracker",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Deterministic failure script for experiments: round -> dead client ids."""

    n_clients: int
    events: tuple[tuple[int, tuple[int, ...]], ...]  # (round, dead ids) sorted

    def dead_at(self, rnd: int) -> set[int]:
        dead: set[int] = set()
        for r, ids in self.events:
            if r <= rnd:
                dead.update(ids)
        return dead

    def alive_mask(self, rnd: int) -> np.ndarray:
        mask = np.ones(self.n_clients, dtype=np.float32)
        for i in self.dead_at(rnd):
            mask[i] = 0.0
        return mask


def sample_failures(n_clients: int, drop_fraction: float, at_round: int,
                    seed: int = 0) -> FailurePlan:
    """Paper §5.2: drop `drop_fraction` of clients at a given round."""
    rng = np.random.default_rng(seed)
    k = int(round(drop_fraction * n_clients))
    dead = tuple(int(x) for x in rng.choice(n_clients, size=k, replace=False))
    return FailurePlan(n_clients=n_clients, events=((at_round, dead),))


def alive_adjusted_spec(spec: gossip_lib.GossipSpec,
                        alive: np.ndarray) -> gossip_lib.GossipSpec:
    """Rebuild a GossipSpec for one round with some clients down (straggler path).

    Dead clients are turned into fixed points of every schedule (they neither
    send nor receive); each surviving client renormalizes its weights over its
    alive in-neighborhood so rows still sum to 1. Symmetry is preserved because
    schedules stay closed under inverse after fixing the same points.
    """
    alive = np.asarray(alive).astype(bool)
    n = spec.n_clients
    new_perms = []
    new_recv = []
    in_weight = np.full(n, 0.0)
    for rf in spec.recv_from:
        rf = np.asarray(rf)
        keep = alive & alive[rf] & (rf != np.arange(n))
        pairs = tuple((int(rf[i]), int(i)) for i in range(n) if keep[i])
        new_perms.append(pairs)
        new_recv.append(tuple(int(rf[i]) if keep[i] else int(i) for i in range(n)))
        in_weight += keep.astype(np.float64) * spec.edge_weight
    base_self = np.asarray(spec.self_weights)
    # lost weight folded into self; then renormalize (rows already sum to 1 by
    # construction, but folding keeps it explicit and robust to fixed points)
    new_self = 1.0 - in_weight
    new_self = np.where(alive, new_self, 1.0)
    return gossip_lib.GossipSpec(
        n_clients=n,
        perms=tuple(new_perms),
        recv_from=tuple(new_recv),
        self_weights=tuple(float(x) for x in new_self),
        edge_weight=spec.edge_weight,
        lam=spec.lam,  # stale; exact lam of the masked matrix is reported offline
    )


def repair_and_remap(overlay: Overlay, dead: list[int],
                     stacked_state: PyTree | None = None
                     ) -> tuple[Overlay, gossip_lib.GossipSpec, PyTree | None,
                                np.ndarray]:
    """Permanent failure: two-hop splice + state remap for the survivors.

    Returns ``(repaired overlay, new GossipSpec, remapped state, old2new)``
    where ``old2new[old] = new compacted index`` for survivors and ``-1`` for
    the dead — the *real* survivor permutation, which callers must apply to
    any per-client state not passed in ``stacked_state`` (optimizer slots,
    data-shard assignments, health counters, ...). ``stacked_state`` may be
    any pytree whose leaves have the client axis leading (params alone, or
    e.g. a ``(params, opt_state)`` tuple — everything is remapped together).
    """
    repaired, old2new = overlay.remove_nodes(dead)
    spec = gossip_lib.make_gossip_spec(repaired)
    new_state = None
    if stacked_state is not None:
        alive_idx = np.asarray([i for i in range(overlay.n) if old2new[i] >= 0])
        new_state = jax.tree.map(lambda x: jnp.take(x, alive_idx, axis=0),
                                 stacked_state)
    return repaired, spec, new_state, old2new


class HealthTracker:
    """Minimal heartbeat bookkeeping for the elastic runtime.

    Production semantics: each client group posts a heartbeat per round; a
    client missing `straggler_rounds` rounds is treated as a straggler (weight
    renormalization), and one missing `failure_rounds` rounds is declared dead
    (triggering splice repair + re-jit). In the simulator the heartbeats come
    from the FailurePlan.
    """

    def __init__(self, n_clients: int, straggler_rounds: int = 1,
                 failure_rounds: int = 3):
        self.n = n_clients
        self.straggler_rounds = straggler_rounds
        self.failure_rounds = failure_rounds
        self.missed = np.zeros(n_clients, dtype=np.int64)

    def observe(self, alive_mask: np.ndarray) -> None:
        alive = np.asarray(alive_mask).astype(bool)
        self.missed = np.where(alive, 0, self.missed + 1)

    def stragglers(self) -> np.ndarray:
        return np.nonzero((self.missed >= self.straggler_rounds)
                          & (self.missed < self.failure_rounds))[0]

    def dead(self) -> np.ndarray:
        return np.nonzero(self.missed >= self.failure_rounds)[0]

    def alive_mask(self) -> np.ndarray:
        """0/1 gossip mask for this round: stragglers and dead are masked."""
        mask = np.ones(self.n, dtype=np.float32)
        mask[self.missed >= self.straggler_rounds] = 0.0
        return mask

    def remap(self, old2new: np.ndarray) -> "HealthTracker":
        """Tracker for the post-repair survivor indexing.

        Surviving clients *carry their in-flight missed-heartbeat counters*
        through the index compaction — a survivor that was already straggling
        when a neighbor died must stay a straggler, not be silently reset to
        healthy by the repair.
        """
        old2new = np.asarray(old2new)
        survivors = np.nonzero(old2new >= 0)[0]
        fresh = HealthTracker(len(survivors), self.straggler_rounds,
                              self.failure_rounds)
        fresh.missed[old2new[survivors]] = self.missed[survivors]
        return fresh
