"""Client-failure modeling, straggler mitigation, and overlay repair (paper §4.1, §5.2).

Two layers of resilience, matching the paper's protocol:

1. *Transient* (per-round) failures / stragglers: a client misses one gossip
   round. Surviving neighbors renormalize their mixing weights over the alive
   in-neighborhood. The production path for this is the **packed gossip
   engine**: the alive mask is a *traced step argument* consumed by the
   packed executors / fused kernels (`gossip.ppermute_mix_packed(alive=...)`,
   `gossip.mix_packed_stacked`), so straggler churn never re-jits — liveness
   is data, not trace structure (`mix_dense_masked` is the numerical oracle;
   the old design that baked the mask into a fresh per-round GossipSpec —
   one retrace per straggler-set change — is gone).
2. *Permanent* failures: the two-hop splice repair (`Overlay.remove_nodes`)
   rebuilds the schedules; `repair_and_remap` additionally remaps any stacked
   client state so training resumes with the survivors, and returns the
   survivor index map (`old2new`) so callers can remap *their* per-client
   state (optimizer slots, data shards, health counters) consistently.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gossip as gossip_lib
from repro.core.topology import Overlay

__all__ = [
    "ATTACK_MODES",
    "AttackPlan",
    "FailurePlan",
    "apply_attack",
    "sample_attackers",
    "sample_failures",
    "repair_and_remap",
    "HealthTracker",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    """Deterministic failure script for experiments: round -> dead client ids."""

    n_clients: int
    events: tuple[tuple[int, tuple[int, ...]], ...]  # (round, dead ids) sorted

    def dead_at(self, rnd: int) -> set[int]:
        dead: set[int] = set()
        for r, ids in self.events:
            if r <= rnd:
                dead.update(ids)
        return dead

    def alive_mask(self, rnd: int) -> np.ndarray:
        mask = np.ones(self.n_clients, dtype=np.float32)
        for i in self.dead_at(rnd):
            mask[i] = 0.0
        return mask


def sample_failures(n_clients: int, drop_fraction: float, at_round: int,
                    seed: int = 0) -> FailurePlan:
    """Paper §5.2: drop `drop_fraction` of clients at a given round."""
    rng = np.random.default_rng(seed)
    k = int(round(drop_fraction * n_clients))
    dead = tuple(int(x) for x in rng.choice(n_clients, size=k, replace=False))
    return FailurePlan(n_clients=n_clients, events=((at_round, dead),))


# ------------------------------------------------------- Byzantine attacks
ATTACK_MODES = ("sign_flip", "scale", "noise")


@dataclasses.dataclass(frozen=True)
class AttackPlan:
    """Deterministic Byzantine-attacker script, mirroring :class:`FailurePlan`:
    round -> {attacker ids, mode, magnitude}.

    Events are *cumulative* (an attacker stays compromised from its event's
    round on) and later events override earlier ones per id, so a script can
    escalate — e.g. scale at round 3, sign_flip at round 10. Modes:

    * ``"sign_flip"``: ship ``-magnitude * params`` (the classic poisoned
      update; magnitude 1 is the pure sign flip).
    * ``"scale"``: ship ``magnitude * params`` (a gradient-boost /
      model-replacement attack).
    * ``"noise"``: add ``magnitude``-std Gaussian noise to every leaf.

    The plan itself is host-side and static; what reaches the jitted step is
    only :meth:`round_vector` — a (2, n) f32 *data* operand (scale row,
    noise-std row) — so attacker churn retraces nothing, exactly like the
    alive mask. Honest clients carry (1, 0).
    """

    n_clients: int
    # (round, attacker ids, mode, magnitude), sorted by round
    events: tuple[tuple[int, tuple[int, ...], str, float], ...]

    def __post_init__(self):
        for _, _, mode, _ in self.events:
            if mode not in ATTACK_MODES:
                raise ValueError(f"unknown attack mode {mode!r}; available: "
                                 f"{', '.join(ATTACK_MODES)}")

    def attackers_at(self, rnd: int) -> set[int]:
        out: set[int] = set()
        for r, ids, _, _ in self.events:
            if r <= rnd:
                out.update(ids)
        return out

    def round_vector(self, rnd: int) -> np.ndarray:
        """(2, n) f32 attack operand for this round: row 0 the per-client
        multiplicative scale (1 = honest), row 1 the additive noise std."""
        vec = np.zeros((2, self.n_clients), dtype=np.float32)
        vec[0] = 1.0
        for r, ids, mode, mag in self.events:
            if r > rnd:
                continue
            for i in ids:
                if mode == "sign_flip":
                    vec[0, i], vec[1, i] = -float(mag), 0.0
                elif mode == "scale":
                    vec[0, i], vec[1, i] = float(mag), 0.0
                else:  # noise
                    vec[0, i], vec[1, i] = 1.0, float(mag)
        return vec


def sample_attackers(n_clients: int, f: int, mode: str = "sign_flip",
                     magnitude: float = 1.0, at_round: int = 0,
                     seed: int = 0) -> AttackPlan:
    """f random Byzantine clients from ``at_round`` on (the bench harness's
    standard scenario)."""
    rng = np.random.default_rng(seed)
    ids = tuple(int(x) for x in rng.choice(n_clients, size=f, replace=False))
    return AttackPlan(n_clients=n_clients,
                      events=((at_round, ids, mode, magnitude),))


def apply_attack(tree: PyTree, attack: jax.Array,
                 key: jax.Array) -> PyTree:
    """Apply the traced per-client attack operand to a client-stacked tree.

    ``attack`` is the (2, n) :meth:`AttackPlan.round_vector` operand and
    ``key`` a (2,) uint32 PRNG key (data, so the noise draw never retraces):
    ``leaf -> scale * leaf + noise_std * N(0, 1)`` with the scale/std rows
    broadcast over the per-client parameter axes. Honest rows (scale 1,
    std 0) pass through unchanged — an all-honest vector is a numerical
    no-op, which is what lets the byzantine=True step run attack-free
    rounds without a second trace.
    """
    attack = jnp.asarray(attack, jnp.float32)
    scale, noise = attack[0], attack[1]
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for li, leaf in enumerate(leaves):
        bshape = (-1,) + (1,) * (leaf.ndim - 1)
        lk = jax.random.fold_in(jax.random.wrap_key_data(
            jnp.asarray(key, jnp.uint32), impl="threefry2x32"), li)
        eps = jax.random.normal(lk, leaf.shape, jnp.float32)
        out.append((scale.reshape(bshape) * leaf.astype(jnp.float32)
                    + noise.reshape(bshape) * eps).astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def repair_and_remap(overlay: Overlay, dead: list[int],
                     stacked_state: PyTree | None = None
                     ) -> tuple[Overlay, gossip_lib.GossipSpec, PyTree | None,
                                np.ndarray]:
    """Permanent failure: two-hop splice + state remap for the survivors.

    Returns ``(repaired overlay, new GossipSpec, remapped state, old2new)``
    where ``old2new[old] = new compacted index`` for survivors and ``-1`` for
    the dead — the *real* survivor permutation, which callers must apply to
    any per-client state not passed in ``stacked_state`` (optimizer slots,
    data-shard assignments, health counters, ...). ``stacked_state`` may be
    any pytree whose leaves have the client axis leading (params alone, or
    e.g. a ``(params, opt_state)`` tuple — everything is remapped together).
    """
    repaired, old2new = overlay.remove_nodes(dead)
    spec = gossip_lib.make_gossip_spec(repaired)
    new_state = None
    if stacked_state is not None:
        alive_idx = np.asarray([i for i in range(overlay.n) if old2new[i] >= 0])
        new_state = jax.tree.map(lambda x: jnp.take(x, alive_idx, axis=0),
                                 stacked_state)
    return repaired, spec, new_state, old2new


class HealthTracker:
    """Minimal heartbeat bookkeeping for the elastic runtime.

    Production semantics: each client group posts a heartbeat per round; a
    client missing `straggler_rounds` rounds is treated as a straggler (weight
    renormalization), and one missing `failure_rounds` rounds is declared dead
    (triggering splice repair + re-jit). In the simulator the heartbeats come
    from the FailurePlan.
    """

    def __init__(self, n_clients: int, straggler_rounds: int = 1,
                 failure_rounds: int = 3, quarantine_rounds: int = 0):
        self.n = n_clients
        self.straggler_rounds = straggler_rounds
        self.failure_rounds = failure_rounds
        # Byzantine quarantine: a client clipped by >= 1 receiver on
        # `quarantine_rounds` distinct rounds is evicted like a dead client
        # (0 disables — heartbeat-only tracking)
        self.quarantine_rounds = quarantine_rounds
        self.missed = np.zeros(n_clients, dtype=np.int64)
        self.suspicion = np.zeros(n_clients, dtype=np.int64)

    def observe(self, alive_mask: np.ndarray) -> None:
        alive = np.asarray(alive_mask).astype(bool)
        self.missed = np.where(alive, 0, self.missed + 1)

    def observe_suspicion(self, clip_counts: np.ndarray) -> None:
        """Feed one round of norm-clip telemetry: ``clip_counts[i]`` =
        number of receivers that clipped sender i this round (the engine's
        ``with_stats`` output). Any round with at least one clipping
        receiver increments the sender's suspicion counter; the counter
        never self-resets — an attacker cannot launder suspicion by
        behaving between bursts. (Honest large-update transients do get a
        receiver or two occasionally; ``quarantine_rounds`` sets how many
        such rounds are tolerated before eviction.)"""
        counts = np.asarray(clip_counts)
        self.suspicion = self.suspicion + (counts > 0).astype(np.int64)

    def suspects(self) -> np.ndarray:
        """Clients over the quarantine threshold (empty when disabled)."""
        if self.quarantine_rounds <= 0:
            return np.zeros(0, dtype=np.int64)
        return np.nonzero(self.suspicion >= self.quarantine_rounds)[0]

    def stragglers(self) -> np.ndarray:
        return np.nonzero((self.missed >= self.straggler_rounds)
                          & (self.missed < self.failure_rounds))[0]

    def dead(self) -> np.ndarray:
        """Clients to evict: heartbeat-dead plus quarantined suspects (the
        caller routes both through the same splice repair)."""
        hb = self.missed >= self.failure_rounds
        if self.quarantine_rounds > 0:
            hb = hb | (self.suspicion >= self.quarantine_rounds)
        return np.nonzero(hb)[0]

    def alive_mask(self) -> np.ndarray:
        """0/1 gossip mask for this round: stragglers and dead are masked."""
        mask = np.ones(self.n, dtype=np.float32)
        mask[self.missed >= self.straggler_rounds] = 0.0
        if self.quarantine_rounds > 0:
            mask[self.suspicion >= self.quarantine_rounds] = 0.0
        return mask

    def remap(self, old2new: np.ndarray) -> "HealthTracker":
        """Tracker for the post-repair survivor indexing.

        Surviving clients *carry their in-flight missed-heartbeat AND
        suspicion counters* through the index compaction — a survivor that
        was already straggling (or half-way to quarantine) when a neighbor
        died must not be silently reset to healthy by the repair.
        """
        old2new = np.asarray(old2new)
        survivors = np.nonzero(old2new >= 0)[0]
        fresh = HealthTracker(len(survivors), self.straggler_rounds,
                              self.failure_rounds, self.quarantine_rounds)
        fresh.missed[old2new[survivors]] = self.missed[survivors]
        fresh.suspicion[old2new[survivors]] = self.suspicion[survivors]
        return fresh
