"""Mixing matrices (paper Definition 2.1) for arbitrary overlay adjacencies.

Schedule-decomposable overlays (ring / expander) should prefer
``Overlay.mixing_matrix`` / ``Overlay.chow_weights``; the builders here work on
any adjacency matrix and cover the paper's ER and fully-connected baselines.
"""
from __future__ import annotations

import numpy as np

from repro.core import spectral

__all__ = [
    "chow_matrix",
    "chebyshev_mix",
    "metropolis_hastings_matrix",
    "max_degree_matrix",
    "uniform_average_matrix",
    "validate_mixing_matrix",
]


def chow_matrix(adj: np.ndarray, theta: float | None = None) -> np.ndarray:
    """M = I - 2/((1+theta) lam_max(L)) L with theta defaulting to theta* = 1/kappa."""
    lap = spectral.laplacian(adj)
    ev = np.linalg.eigvalsh(lap)
    lam2, lam_max = float(ev[1]), float(ev[-1])
    if lam2 <= 1e-12:
        raise ValueError("graph is disconnected")
    if theta is None:
        theta = spectral.theta_star(lam_max / lam2)
    c = 2.0 / ((1.0 + theta) * lam_max)
    return np.eye(adj.shape[0]) - c * lap


def chebyshev_mix(x: np.ndarray, m: np.ndarray,
                  omegas: np.ndarray) -> np.ndarray:
    """Dense oracle for k Chebyshev gossip sub-rounds (host numpy, f64).

    ``x`` is the client-stacked value, shape ``(n, ...)``; ``m`` the (n, n)
    mixing matrix the executor effectively applies (pass
    :func:`repro.core.gossip.gated_mixing_matrix` to reproduce a masked /
    gated engine round); ``omegas`` the per-sub-round weights from
    :func:`repro.core.spectral.chebyshev_omegas`. Implements the executor's
    recurrence exactly, including the x^(-1) := x^(0) seed:

        x^(j+1) = omegas[j] * (m @ x^(j) - x^(j-1)) + x^(j-1)

    so ``chebyshev_mix(x, m, [1.0])`` is one plain ``m @ x`` round. This is
    the reference the engine's sub_rounds cells are tested against.
    """
    x = np.asarray(x, dtype=np.float64)
    m = np.asarray(m, dtype=np.float64)
    flat = x.reshape(x.shape[0], -1)
    x_prev = flat
    x_cur = flat
    for w in np.asarray(omegas, dtype=np.float64):
        x_next = w * (m @ x_cur - x_prev) + x_prev
        x_prev, x_cur = x_cur, x_next
    return x_cur.reshape(x.shape)


def metropolis_hastings_matrix(adj: np.ndarray) -> np.ndarray:
    """Metropolis-Hastings weights: m_ij = 1/(1+max(d_i,d_j)) on edges."""
    adj = np.asarray(adj, dtype=np.float64)
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    m = np.zeros((n, n))
    ii, jj = np.nonzero(adj)
    m[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    np.fill_diagonal(m, 1.0 - m.sum(axis=1))
    return m


def max_degree_matrix(adj: np.ndarray) -> np.ndarray:
    """Maximum-degree weights: m_ij = 1/(1+d_max) on edges."""
    adj = np.asarray(adj, dtype=np.float64)
    n = adj.shape[0]
    dmax = adj.sum(axis=1).max()
    m = adj / (1.0 + dmax)
    np.fill_diagonal(m, 1.0 - m.sum(axis=1))
    return m


def uniform_average_matrix(n: int) -> np.ndarray:
    """The fully-connected FedAvg aggregator: M = 11^T / N."""
    return np.full((n, n), 1.0 / n)


def validate_mixing_matrix(m: np.ndarray, adj: np.ndarray | None = None,
                           tol: float = 1e-8) -> None:
    """Assert Definition 2.1: graph pattern, symmetry, null space, spectrum.

    Raises AssertionError with a description on the first violated property.
    """
    m = np.asarray(m, dtype=np.float64)
    n = m.shape[0]
    assert m.shape == (n, n), "mixing matrix must be square"
    assert np.allclose(m, m.T, atol=tol), "mixing matrix must be symmetric"
    if adj is not None:
        off = ~np.eye(n, dtype=bool)
        zero_pat = (np.asarray(adj) == 0) & off
        assert np.all(np.abs(m[zero_pat]) <= tol), \
            "m_ij must be 0 off the edge set"
        edge_pat = (np.asarray(adj) > 0) & off
        assert np.all(m[edge_pat] > -tol), "m_ij must be >= 0 on edges"
    row = m.sum(axis=1)
    assert np.allclose(row, 1.0, atol=1e-6), "rows must sum to 1 (null-space prop)"
    ev = np.linalg.eigvalsh(m)
    assert ev[-1] <= 1.0 + 1e-6, "I - M must be PSD (eigenvalues <= 1)"
    assert ev[0] > -1.0 - 1e-9, "M + I must be PD (eigenvalues > -1)"
    # null{I-M} = span{1}: eigenvalue 1 must be simple for connected graphs
    assert np.sum(np.abs(ev - 1.0) < 1e-9) == 1, "eigenvalue 1 must be simple"
