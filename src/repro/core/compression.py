"""Gossip-payload / gradient compression (distributed-optimization substrate).

The paper deliberately runs *without* compression ("no tuned optimization and
data compression algorithms are used") — so compression is OFF in the
paper-faithful configuration and exercised only in the beyond-paper perf
configurations and tests.

Provided:
* symmetric per-tensor int8 quantization (used by the quantized gossip path;
  the Pallas kernel in `kernels/quant_gossip` is the TPU implementation, this
  module is the jnp substrate + error-feedback bookkeeping);
* top-k sparsification with error feedback (Stich et al. style) for gradient
  exchange experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "topk_sparsify",
    "ErrorFeedbackState",
    "ef_compress",
]

PyTree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: q = round(x/s), s = max|x|/127."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def topk_sparsify(x: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Keep the k largest-magnitude entries (flat); returns (values, flat idx)."""
    flat = x.reshape(-1)
    k = min(k, flat.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(flat.astype(jnp.float32)), k)
    return flat[idx], idx


@dataclasses.dataclass
class ErrorFeedbackState:
    """Residual memory for biased compressors (top-k)."""

    residual: PyTree

    @staticmethod
    def init(tree: PyTree) -> "ErrorFeedbackState":
        return ErrorFeedbackState(jax.tree.map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), tree))


def ef_compress(tree: PyTree, state: ErrorFeedbackState, k_fraction: float
                ) -> tuple[PyTree, ErrorFeedbackState]:
    """Error-feedback top-k: compress (x + residual), remember what was dropped.

    Returns the *dense decompressed* payload (what the receiver reconstructs)
    and the updated residual state — the dense form keeps the simulator simple
    while preserving the exact algorithmic semantics.
    """

    def one(x, r):
        y = x.astype(jnp.float32) + r
        k = max(1, int(k_fraction * y.size))
        vals, idx = topk_sparsify(y, k)
        dense = jnp.zeros(y.size, dtype=jnp.float32).at[idx].set(vals)
        dense = dense.reshape(y.shape)
        return dense.astype(x.dtype), y - dense

    flat_x, treedef = jax.tree.flatten(tree)
    flat_r = jax.tree.leaves(state.residual)
    outs = [one(x, r) for x, r in zip(flat_x, flat_r)]
    payload = jax.tree.unflatten(treedef, [o[0] for o in outs])
    resid = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return payload, ErrorFeedbackState(resid)
