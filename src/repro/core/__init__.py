"""Core contribution of the paper: expander-graph overlay networks + DFedAvgM.

Public API:

* `topology` — overlay builders (ring / ER / complete / d-regular expander via
  virtual ring spaces), join + two-hop failure repair.
* `spectral` — Laplacian spectra, kappa(L), theta*, lambda(M), C_lambda.
* `mixing`   — mixing matrices for arbitrary adjacencies + validity checks.
* `gossip`   — the gossip executors (dense / gather / per-leaf ppermute /
  packed ppermute / packed int8 ppermute).
* `packing`  — flat-buffer packing of parameter pytrees (PackSpec,
  pack_tree / unpack_tree) feeding the packed gossip hot path.
* `dfedavg`  — the DFedAvgM local solver (paper eq. 2.1).
* `failures` — failure plans, straggler weight-renormalization, splice repair.
* `compression` — int8 / top-k payload compression (beyond-paper).
"""
from repro.core import (  # noqa: F401
    compression,
    dfedavg,
    failures,
    gossip,
    mixing,
    packing,
    spectral,
    topology,
)
