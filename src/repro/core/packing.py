"""Flat-buffer packing of parameter pytrees for the gossip hot path.

The paper's gossip round ships the *entire* client parameter state every K
local steps. Executing it leaf-by-leaf costs d x n_leaves collective-permutes
per round and d+1 unfused HBM read-modify-write passes per leaf. Packing the
pytree into one lane-aligned flat buffer per dtype turns that into:

* **d collectives per round per dtype** — one ``lax.ppermute`` of the whole
  buffer per schedule, independent of how many parameter tensors the model
  has. Fewer, larger transfers saturate ICI and overlap with compute far
  better than hundreds of small per-leaf permutes.
* **one HBM pass for the mixing reduction** — the self buffer plus the d
  received buffers stack to ``(d+1, rows, 128)`` and feed straight into the
  fused ``gossip_mix_2d`` Pallas kernel (reads (d+1)x bytes, writes 1x bytes:
  the HBM lower bound), with no per-leaf flatten/pad work in the jitted step.

A :class:`PackSpec` is static and hashable, so it bakes into the jitted train
step as a closed-over constant: all offsets/shapes below are Python ints and
every slice in ``unpack_tree`` is static.

Layout: leaves are grouped by dtype (one buffer per distinct dtype — models
are usually single-dtype, so usually one buffer), raveled and concatenated in
tree-flatten order, then zero-padded so the buffer reshapes to
``(rows, LANE=128)`` with ``rows`` a multiple of ``PACK_BLOCK_ROWS`` — i.e.
already tiled for the Pallas gossip/quant kernels, no padding inside the step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LANE", "PACK_BLOCK_ROWS", "SCALE_BYTES", "LeafSlot", "PackSpec",
           "make_pack_spec", "make_stacked_pack_spec", "pack_tree",
           "unpack_tree", "scale_rows", "topk_wire_rows"]

PyTree = Any

LANE = 128
# Matches the gossip_mix / quant_gossip kernels' DEFAULT_BLOCK_ROWS so packed
# buffers are directly consumable without repadding; 256 rows is a multiple of
# every dtype's sublane minimum (f32:8, bf16:16, int8:32).
PACK_BLOCK_ROWS = 256
# bytes per f32 quantization scale folded into an int8 wire buffer
SCALE_BYTES = 4


def scale_rows(n_blocks: int) -> int:
    """Trailing lane rows an int8 wire buffer needs to carry `n_blocks`
    per-row-block f32 quant scales (4 bytes each, lane-folded like the PR-3
    wire format). One row carries LANE // SCALE_BYTES = 32 scales, so the
    wire overhead stays <= 1 row per 32 tile blocks (each >= 32 KiB)."""
    return (SCALE_BYTES * n_blocks + LANE - 1) // LANE


def topk_wire_rows(k: int) -> int:
    """Lane rows of a sparse top-k wire buffer: ``k`` f32 values followed by
    ``k`` int32 flat indices, each 4 bytes, bitcast into int8 lane rows (the
    same fold that carries quant scales — one int8 buffer per schedule, ONE
    collective). The two sections are padded to whole rows independently so
    both bitcasts stay static slices."""
    half = (SCALE_BYTES * k + LANE - 1) // LANE
    return 2 * half


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one leaf lives: ``buffers[buffer].reshape(-1)[offset:offset+size]``."""

    shape: tuple[int, ...]
    dtype: str
    buffer: int     # index into the spec's buffer list
    offset: int     # element offset within that flat buffer
    size: int       # number of elements


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static, hashable description of a packed parameter pytree.

    Attributes:
      slots: per-leaf placement, in ``jax.tree.flatten`` order.
      buffer_dtypes: dtype name of each flat buffer (one per distinct dtype).
      buffer_rows: row count of each ``(rows, LANE)`` buffer; always a
        multiple of ``block_rows``.
      block_rows: the kernel tile height the buffers are padded for.
      treedef: the source pytree structure (hashable), for ``unpack_tree``.
    """

    slots: tuple[LeafSlot, ...]
    buffer_dtypes: tuple[str, ...]
    buffer_rows: tuple[int, ...]
    block_rows: int
    treedef: Any

    @property
    def n_buffers(self) -> int:
        return len(self.buffer_dtypes)

    @property
    def n_leaves(self) -> int:
        return len(self.slots)

    def buffer_shape(self, b: int) -> tuple[int, int]:
        return (self.buffer_rows[b], LANE)

    def buffer_struct(self, b: int) -> jax.ShapeDtypeStruct:
        """Host-side ShapeDtypeStruct of buffer ``b`` — the base shape the
        engine's wire codecs derive their on-the-wire struct from (the f32
        codec ships it as-is; the int8 codecs append scale rows)."""
        return jax.ShapeDtypeStruct(self.buffer_shape(b),
                                    jnp.dtype(self.buffer_dtypes[b]))

    def buffer_blocks(self, b: int) -> int:
        """Row-block (kernel tile) count of buffer ``b`` — also the number of
        per-block quant scales its int8 wire buffer carries."""
        return self.buffer_rows[b] // self.block_rows

    @property
    def payload_elements(self) -> int:
        """Real (unpadded) elements across all buffers."""
        return sum(s.size for s in self.slots)

    @property
    def padded_elements(self) -> int:
        """Allocated elements including lane/tile padding."""
        return sum(r * LANE for r in self.buffer_rows)

    @property
    def payload_bytes(self) -> int:
        return sum(s.size * jnp.dtype(s.dtype).itemsize for s in self.slots)

    @property
    def padded_bytes(self) -> int:
        return sum(r * LANE * jnp.dtype(d).itemsize
                   for r, d in zip(self.buffer_rows, self.buffer_dtypes))


def make_stacked_pack_spec(tree: PyTree, *,
                           block_rows: int = PACK_BLOCK_ROWS) -> PackSpec:
    """PackSpec of a CLIENT-STACKED tree's per-client slice (leading axis =
    clients, stripped before packing). This is the layout shared by the
    stacked and blocked engine substrates: one ``(n, rows, 128)`` (or
    ``(B, rows, 128)`` device-local under ``blocked``) buffer per dtype, the
    per-client slice packed identically everywhere — which is why a splice
    repair remaps blocked state by the same old2new row take as stacked
    state, and why blocked-vs-stacked parity is bitwise for f32 cells.

    ``block_rows`` tunes the per-client padding floor: the default matches
    the Pallas kernels' tile, but f32 simulator cells at O(10^4) clients use
    no kernels and may pick a smaller multiple-of-8 block so 4096 tiny
    clients don't pad to 4096 x 256 rows (see benchmarks/bench_scale.py).
    """
    return make_pack_spec(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree),
        block_rows=block_rows)


def make_pack_spec(tree: PyTree, *, block_rows: int = PACK_BLOCK_ROWS
                   ) -> PackSpec:
    """Build a PackSpec from a pytree of arrays or ShapeDtypeStructs.

    Only ``.shape`` and ``.dtype`` of the leaves are consulted, so the spec
    can be built host-side from ``shape_structs`` without touching device
    memory, then reused against real arrays of the same structure.
    """
    leaves, treedef = jax.tree.flatten(tree)
    buffer_dtypes: list[str] = []
    fill: list[int] = []        # elements used so far per buffer
    slots: list[LeafSlot] = []
    for leaf in leaves:
        dt = str(jnp.dtype(leaf.dtype))
        if dt not in buffer_dtypes:
            buffer_dtypes.append(dt)
            fill.append(0)
        b = buffer_dtypes.index(dt)
        size = int(np.prod(leaf.shape)) if len(leaf.shape) else 1
        slots.append(LeafSlot(shape=tuple(int(x) for x in leaf.shape),
                              dtype=dt, buffer=b, offset=fill[b], size=size))
        fill[b] += size
    tile = block_rows * LANE
    rows = tuple((used + tile - 1) // tile * tile // LANE for used in fill)
    return PackSpec(slots=tuple(slots), buffer_dtypes=tuple(buffer_dtypes),
                    buffer_rows=rows, block_rows=block_rows, treedef=treedef)


def pack_tree(tree: PyTree, spec: PackSpec) -> tuple[jax.Array, ...]:
    """Pack a pytree into the spec's flat ``(rows, LANE)`` buffers."""
    leaves = jax.tree.leaves(tree)
    if len(leaves) != spec.n_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, spec packs "
                         f"{spec.n_leaves}")
    parts: list[list[jax.Array]] = [[] for _ in range(spec.n_buffers)]
    for leaf, slot in zip(leaves, spec.slots):
        if leaf.shape != slot.shape or str(jnp.dtype(leaf.dtype)) != slot.dtype:
            raise ValueError(f"leaf {leaf.shape}/{leaf.dtype} does not match "
                             f"slot {slot.shape}/{slot.dtype}")
        parts[slot.buffer].append(leaf.reshape(-1))
    bufs = []
    for b in range(spec.n_buffers):
        flat = (jnp.concatenate(parts[b]) if len(parts[b]) > 1
                else parts[b][0])
        total = spec.buffer_rows[b] * LANE
        if flat.shape[0] < total:
            flat = jnp.pad(flat, (0, total - flat.shape[0]))
        bufs.append(flat.reshape(spec.buffer_rows[b], LANE))
    return tuple(bufs)


def unpack_tree(buffers: tuple[jax.Array, ...], spec: PackSpec) -> PyTree:
    """Invert :func:`pack_tree` (all slices static, jit-friendly)."""
    if len(buffers) != spec.n_buffers:
        raise ValueError(f"got {len(buffers)} buffers, spec has "
                         f"{spec.n_buffers}")
    flats = [b.reshape(-1) for b in buffers]
    leaves = [flats[s.buffer][s.offset:s.offset + s.size].reshape(s.shape)
              for s in spec.slots]
    return jax.tree.unflatten(spec.treedef, leaves)
