"""DFedAvgM — Decentralized Federated Averaging with Momentum (paper eq. 2.1).

Per communication round t, client i runs K local heavy-ball steps

    w^{t,k+1} = w^{t,k} - eta_t * grad f_i(w^{t,k}; xi) + beta (w^{t,k} - w^{t,k-1})

with w^{t,-1} = w^{t,0} (momentum resets at each round boundary — paper
convention), then gossips: w_i^{t+1,0} = sum_l m_il w_l^{t,K}.

This module is executor-agnostic: the same `local_round` runs

* stacked under `jax.vmap` for the N-client simulator (benchmarks mirror the
  paper's experiments), and
* per-shard inside `shard_map` for the production multi-pod trainer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = [
    "DFedAvgMConfig",
    "momentum_update",
    "local_round",
    "make_client_round",
]

PyTree = Any
LossFn = Callable[[PyTree, Any], tuple[jax.Array, Any]]  # (params, batch) -> (loss, aux)


@dataclasses.dataclass(frozen=True)
class DFedAvgMConfig:
    """Hyper-parameters of the local solver (paper eq. 2.1)."""

    local_steps: int = 3          # K
    lr: float = 0.01              # eta (constant; schedules applied by caller)
    momentum: float = 0.9         # beta
    reset_momentum: bool = True   # w^{t,-1} = w^{t,0} (paper-faithful)
    grad_clip: float | None = None
    weight_decay: float = 0.0
    grad_accum: int = 1           # microbatches per local step (memory knob)
    # dtype of the microbatch-gradient accumulator; param dtype keeps the
    # per-microbatch reduce traffic in bf16 (f32 doubles collective bytes)
    accum_dtype: str | None = None


def _clip(grads: PyTree, max_norm: float) -> PyTree:
    g2 = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(g2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def momentum_update(params: PyTree, velocity: PyTree, grads: PyTree,
                    lr, beta) -> tuple[PyTree, PyTree]:
    """Heavy-ball: v' = beta v - lr g ; w' = w + v'  (== paper eq. 2.1)."""
    new_v = jax.tree.map(
        lambda v, g: (beta * v.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(v.dtype),
        velocity, grads)
    new_p = jax.tree.map(lambda p, v: (p.astype(jnp.float32)
                                       + v.astype(jnp.float32)).astype(p.dtype),
                         params, new_v)
    return new_p, new_v


def local_round(
    params: PyTree,
    velocity: PyTree,
    batches: PyTree,
    loss_fn: LossFn,
    cfg: DFedAvgMConfig,
    lr: jax.Array | float | None = None,
    update_fn: Callable[..., tuple[PyTree, PyTree]] | None = None,
) -> tuple[PyTree, PyTree, jax.Array]:
    """K local momentum steps for ONE client.

    Args:
      params/velocity: this client's model state.
      batches: pytree whose leaves have leading axis K (one slice per local step).
      loss_fn: (params, batch) -> (loss, aux).
      lr: overrides cfg.lr (e.g. a per-round scheduled value).
      update_fn: optional fused (params, velocity, grads, lr, beta) updater
        (the Pallas kernel on TPU); defaults to `momentum_update`.

    Returns (params, velocity, mean_loss).
    """
    lr = cfg.lr if lr is None else lr
    upd = update_fn or momentum_update
    if cfg.reset_momentum:
        velocity = jax.tree.map(jnp.zeros_like, velocity)

    def grads_of(p, batch):
        if cfg.grad_accum <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
        # gradient accumulation: scan over microbatches, average grads —
        # bounds transient activation memory for the giant MoE shapes
        mb = jax.tree.map(
            lambda x: x.reshape((cfg.grad_accum, x.shape[0] // cfg.grad_accum)
                                + x.shape[1:]), batch)

        adt = cfg.accum_dtype

        def acc(carry, b):
            (loss, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
            gsum, lsum = carry
            return (jax.tree.map(lambda a, x: a + x.astype(a.dtype), gsum, g),
                    lsum + loss), None

        zeros = jax.tree.map(
            lambda w: jnp.zeros(w.shape, jnp.dtype(adt) if adt else w.dtype), p)
        (gsum, lsum), _ = jax.lax.scan(acc, (zeros, jnp.zeros((), jnp.float32)), mb)
        inv = 1.0 / cfg.grad_accum
        return ((lsum * inv, None),
                jax.tree.map(lambda g, w: (g * inv).astype(w.dtype), gsum, p))

    def step(carry, batch):
        p, v = carry
        (loss, _aux), grads = grads_of(p, batch)
        if cfg.grad_clip is not None:
            grads = _clip(grads, cfg.grad_clip)
        if cfg.weight_decay:
            grads = jax.tree.map(lambda g, w: g + cfg.weight_decay * w, grads, p)
        p, v = upd(p, v, grads, lr, cfg.momentum)
        return (p, v), loss

    (params, velocity), losses = jax.lax.scan(step, (params, velocity), batches)
    return params, velocity, jnp.mean(losses)


def make_client_round(loss_fn: LossFn, cfg: DFedAvgMConfig,
                      update_fn=None) -> Callable:
    """vmap-able per-client round: (params, velocity, batches[, lr]) -> ..."""

    def fn(params, velocity, batches, lr=None):
        return local_round(params, velocity, batches, loss_fn, cfg, lr=lr,
                           update_fn=update_fn)

    return fn
