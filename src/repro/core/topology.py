"""Overlay-network topologies for decentralized federated learning (paper §3-§4).

The central object is :class:`Overlay`: a set of N clients plus a list of
*permutation schedules*. A permutation schedule is a bijection pi on [N] such
that client i exchanges parameters with pi(i) (a fixed point pi(i) == i means
"client i sits this schedule out"). This is exactly the form a TPU
``collective-permute`` wants, and it is exactly what the paper's §4 virtual
ring-space construction produces:

* each of the L = d/2 virtual ring spaces is one random Hamiltonian cycle,
  i.e. TWO directed permutation schedules (successor and predecessor);
* an optional random perfect matching (the paper's "extra edge on top of the
  Ring graph" used for the d=3 Ramanujan experiments) is ONE self-inverse
  schedule.

With S schedules, define ``L' = S*I - sum_s P_s``. For fixed-point-free
schedules the union is an S-regular multigraph and L' is its Laplacian; with
fixed points L' is still exactly the Laplacian of the off-diagonal multigraph.
The Chow mixing matrix ``M = I - c L'`` therefore decomposes as

    M = (1 - c*S) I + c * sum_s P_s,   c = 2 / ((1+theta) * lam_max(L'))

— a weighted sum of ppermutes with a single uniform edge weight. That
decomposition is what `core.gossip` lowers to hardware.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import spectral

__all__ = [
    "Overlay",
    "ring_overlay",
    "expander_overlay",
    "matching_schedule",
    "erdos_renyi_adjacency",
    "complete_adjacency",
    "overlay_from_rings",
    "ChowWeights",
]


def _ring_schedules_from_order(order: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Given node ids in ring order, return (successor, predecessor) permutations."""
    n = len(order)
    succ = np.empty(n, dtype=np.int64)
    pred = np.empty(n, dtype=np.int64)
    for pos in range(n):
        a = order[pos]
        b = order[(pos + 1) % n]
        succ[a] = b
        pred[b] = a
    return succ, pred


def _is_permutation(pi: np.ndarray) -> bool:
    return bool(np.array_equal(np.sort(pi), np.arange(len(pi))))


@dataclasses.dataclass(frozen=True)
class ChowWeights:
    """Decomposed Chow mixing weights: M = self_weight*I + edge_weight*sum_s P_s."""

    self_weight: float
    edge_weight: float
    theta: float
    lam: float  # lambda(M)
    kappa: float


@dataclasses.dataclass
class Overlay:
    """A client overlay: permutation schedules over n clients.

    Attributes:
      n: number of clients.
      schedules: list of int64 permutations of [n], closed under inverse
        (an involution is its own inverse). Fixed points are allowed and mean
        "no exchange for this client in this schedule".
      coords: [n, L] virtual ring coordinates (None for non-§4 constructions).
      name: topology family name for reports.
    """

    n: int
    schedules: list[np.ndarray]
    coords: np.ndarray | None = None
    name: str = "overlay"

    def __post_init__(self) -> None:
        self.schedules = [np.asarray(s, dtype=np.int64) for s in self.schedules]
        keys = {tuple(s.tolist()) for s in self.schedules}
        for s in self.schedules:
            if len(s) != self.n or not _is_permutation(s):
                raise ValueError("each schedule must be a permutation of [n]")
            if tuple(np.argsort(s).tolist()) not in keys:
                raise ValueError("schedule set must be closed under inverse")

    # ---------------------------------------------------------------- graphs
    @property
    def degree(self) -> int:
        """Nominal degree = number of schedules (max per-node degree)."""
        return len(self.schedules)

    def multigraph_adjacency(self) -> np.ndarray:
        """A[i,j] = number of schedules sending i -> j, for i != j (symmetric)."""
        a = np.zeros((self.n, self.n), dtype=np.float64)
        idx = np.arange(self.n)
        for s in self.schedules:
            mask = s != idx
            a[idx[mask], s[mask]] += 1.0
        return a

    def simple_adjacency(self) -> np.ndarray:
        """0/1 union adjacency (collapses multi-edges)."""
        return (self.multigraph_adjacency() > 0).astype(np.float64)

    def neighbor_lists(self) -> list[list[int]]:
        adj = self.simple_adjacency()
        return [list(map(int, np.nonzero(adj[i])[0])) for i in range(self.n)]

    def laplacian(self) -> np.ndarray:
        a = self.multigraph_adjacency()
        return np.diag(a.sum(axis=1)) - a

    # ---------------------------------------------------------------- theory
    def spectral_report(self) -> spectral.SpectralReport:
        return spectral.analyze(self.simple_adjacency())

    def chow_weights(self, theta: float | None = None) -> ChowWeights:
        """Chow mixing weights on the schedule multigraph (see module docstring)."""
        lap = self.laplacian()
        ev = np.linalg.eigvalsh(lap)
        lam2, lam_max = float(ev[1]), float(ev[-1])
        if lam2 <= 1e-12:
            raise ValueError("overlay graph is disconnected; cannot build mixing matrix")
        kap = lam_max / lam2
        if theta is None:
            theta = spectral.theta_star(kap)
        c = 2.0 / ((1.0 + theta) * lam_max)
        w0 = 1.0 - c * self.degree
        # lam from the *actual* mixing matrix spectrum (exact, incl. fixed points)
        lam_vals = 1.0 - c * ev
        lam = float(max(abs(lam_vals[1:]).max(), 0.0)) if self.n > 1 else 0.0
        return ChowWeights(self_weight=w0, edge_weight=c, theta=theta, lam=lam, kappa=kap)

    def mixing_matrix(self, theta: float | None = None) -> np.ndarray:
        """Dense N x N Chow mixing matrix (the reference for gossip executors)."""
        w = self.chow_weights(theta)
        m = w.self_weight * np.eye(self.n)
        idx = np.arange(self.n)
        for s in self.schedules:
            m[idx, s] += w.edge_weight
        return m

    # ------------------------------------------------------------- dynamics
    def remove_nodes(self, dead: list[int] | np.ndarray) -> tuple["Overlay", np.ndarray]:
        """Two-hop splice repair (paper §4.1).

        In each ring schedule, each dead node x is spliced out by connecting
        pred(x) -> succ(x) (skipping runs of dead nodes). Matching schedules
        lose the dead nodes' edges; orphaned partners are re-matched among
        themselves; an odd leftover keeps a fixed point (degree deficit of 1,
        exactly what the paper's local repair yields before the next rebuild).

        Returns (repaired overlay on surviving nodes, old->new index map where
        map[old] = new index or -1 if dead).
        """
        dead_set = {int(x) for x in np.asarray(dead, dtype=np.int64).ravel()}
        alive = [i for i in range(self.n) if i not in dead_set]
        if len(alive) < 2:
            raise ValueError("fewer than 2 surviving clients")
        old2new = -np.ones(self.n, dtype=np.int64)
        for new, old in enumerate(alive):
            old2new[old] = new
        m = len(alive)

        new_schedules: list[np.ndarray] = []
        handled: set[int] = set()
        for idx, s in enumerate(self.schedules):
            if idx in handled:
                continue
            inv = np.argsort(s)
            if np.array_equal(inv, s):
                # involution (matching): keep surviving pairs, re-pair orphans
                new_s = np.arange(m, dtype=np.int64)
                orphans: list[int] = []
                for i in alive:
                    j = int(s[i])
                    if j == i:
                        continue  # already a fixed point
                    if j in dead_set:
                        orphans.append(int(old2new[i]))
                    else:
                        new_s[old2new[i]] = old2new[j]
                for a, b in zip(orphans[0::2], orphans[1::2]):
                    new_s[a], new_s[b] = b, a
                new_schedules.append(new_s)
                handled.add(idx)
            else:
                # ring schedule: splice dead nodes out of the cycle
                succ = np.empty(m, dtype=np.int64)
                for i in alive:
                    j = int(s[i])
                    hops = 0
                    while j in dead_set:
                        j = int(s[j])
                        hops += 1
                        if hops > self.n:
                            raise RuntimeError("cycle splice failed")
                    succ[old2new[i]] = old2new[j]
                new_schedules.append(succ)
                new_schedules.append(np.argsort(succ))
                handled.add(idx)
                # mark the paired predecessor schedule as handled
                for jdx, s2 in enumerate(self.schedules):
                    if jdx not in handled and np.array_equal(inv, s2):
                        handled.add(jdx)
                        break

        coords = self.coords[alive] if self.coords is not None else None
        return (
            Overlay(n=m, schedules=new_schedules, coords=coords, name=self.name + "+repair"),
            old2new,
        )

    def add_node(self, rng: np.random.Generator | None = None) -> "Overlay":
        """Join protocol (paper §4): the new node draws coordinates and splices
        itself into each virtual ring between its two ring-closest nodes.
        Matching schedules give the new node a fixed point until the next
        matching rebuild (degree deficit of 1, as in the real protocol)."""
        if self.coords is None:
            raise ValueError("join protocol requires virtual ring coordinates")
        rng = rng or np.random.default_rng()
        n = self.n
        n_rings = self.coords.shape[1]
        coords = np.concatenate([self.coords, rng.random((1, n_rings))], axis=0)

        schedules: list[np.ndarray] = []
        handled: set[int] = set()
        ring_idx = 0
        for idx, s in enumerate(self.schedules):
            if idx in handled:
                continue
            inv = np.argsort(s)
            if np.array_equal(inv, s):
                schedules.append(np.concatenate([s, np.array([n], dtype=np.int64)]))
                handled.add(idx)
            else:
                order = np.argsort(coords[:, ring_idx], kind="stable")
                succ, pred = _ring_schedules_from_order(order)
                schedules.append(succ)
                schedules.append(pred)
                handled.add(idx)
                ring_idx += 1
                for jdx, s2 in enumerate(self.schedules):
                    if jdx not in handled and np.array_equal(inv, s2):
                        handled.add(jdx)
                        break
        return Overlay(n=n + 1, schedules=schedules, coords=coords, name=self.name)


# ------------------------------------------------------------------ builders
def ring_overlay(n: int) -> Overlay:
    """The Ring baseline (2-regular): one cycle in natural order."""
    if n < 3:
        raise ValueError("ring needs n >= 3")
    succ, pred = _ring_schedules_from_order(np.arange(n))
    return Overlay(n=n, schedules=[succ, pred], name="ring")


def overlay_from_rings(coords: np.ndarray, name: str = "expander") -> Overlay:
    """Build an overlay from explicit virtual-ring coordinates [n, L] (paper §4)."""
    coords = np.asarray(coords, dtype=np.float64)
    n, n_rings = coords.shape
    schedules: list[np.ndarray] = []
    for r in range(n_rings):
        order = np.argsort(coords[:, r], kind="stable")
        succ, pred = _ring_schedules_from_order(order)
        schedules.append(succ)
        schedules.append(pred)
    return Overlay(n=n, schedules=schedules, coords=coords, name=name)


def matching_schedule(n: int, rng: np.random.Generator) -> np.ndarray:
    """A uniformly random perfect matching as an involution schedule (n even)."""
    if n % 2 != 0:
        raise ValueError("perfect matching needs even n")
    perm = rng.permutation(n)
    s = np.empty(n, dtype=np.int64)
    for a, b in zip(perm[0::2], perm[1::2]):
        s[a], s[b] = b, a
    return s


def expander_overlay(
    n: int,
    d: int,
    seed: int = 0,
    include_base_ring: bool = True,
    max_tries: int = 32,
) -> Overlay:
    """d-regular expander via virtual ring spaces (paper §4) + optional matching.

    * d even: L = d/2 ring spaces. If ``include_base_ring`` the first "space" is
      the natural-order ring (the paper's construction adds expander edges on
      top of the Ring graph), and the remaining L-1 spaces use random coords.
    * d odd: (d-1)/2 ring spaces + one random perfect matching (needs even n).
      d=3 with include_base_ring reproduces the paper's "Ring + extra edge"
      Ramanujan setup.

    Retries the random draw until the union multigraph is connected (w.h.p.
    the first draw works).
    """
    if d < 2:
        raise ValueError("expander needs d >= 2")
    if d % 2 == 1 and n % 2 == 1:
        raise ValueError("odd degree requires even n (perfect matching)")
    n_rings = d // 2
    use_matching = d % 2 == 1

    rng = np.random.default_rng(seed)
    last_err: Exception | None = None
    for _ in range(max_tries):
        if n_rings > 0:
            coords = rng.random((n, n_rings))
            if include_base_ring:
                coords[:, 0] = np.arange(n) / n  # natural ring as space 0
            ov = overlay_from_rings(coords, name=f"expander-d{d}")
            schedules = list(ov.schedules)
        else:
            coords = np.zeros((n, 0))
            schedules = []
        if use_matching:
            schedules.append(matching_schedule(n, rng))
        try:
            ov = Overlay(n=n, schedules=schedules, coords=coords, name=f"expander-d{d}")
            if not ov.spectral_report().connected:
                raise ValueError("disconnected draw")
            return ov
        except (ValueError, RuntimeError) as e:  # retry the random draw
            last_err = e
    raise RuntimeError(f"could not draw a connected {d}-regular overlay: {last_err}")


def erdos_renyi_adjacency(n: int, p: float | None = None, seed: int = 0,
                          max_tries: int = 64) -> np.ndarray:
    """Erdos-Renyi G(n, p) adjacency, p defaults to ln(N)/N (paper §5); retried
    until connected."""
    if p is None:
        p = math.log(n) / n
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        u = rng.random((n, n))
        a = np.triu((u < p).astype(np.float64), k=1)
        adj = a + a.T
        if spectral.is_connected(adj):
            return adj
    raise RuntimeError(f"could not draw a connected ER graph with p={p}")


def complete_adjacency(n: int) -> np.ndarray:
    """Fully-connected baseline."""
    return np.ones((n, n)) - np.eye(n)
