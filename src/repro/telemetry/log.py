"""TelemetryLogger: the one structured JSONL stream both trainers thread.

A logger is a cheap host-side object: it stamps records (``seq``/``ts``/
``kind``), keeps them in memory (``records``), and — when given a path —
appends each as one JSON line (flushed per record, so a crashed run keeps
everything up to its last round). Phase wall-clock rides a context
manager::

    log = TelemetryLogger("run.jsonl", run="demo")
    with log.phase("local+gossip"):
        params, losses = trainer.step(params, batches, lr)
    log.round(rnd, loss=float(losses.mean()), metrics=summary)

``round`` folds the phase seconds accumulated since the previous round
record into the emitted record (``{"phases": {name: seconds}}``) — the
local-step vs gossip vs host breakdown is whatever phases the caller
brackets. ``phase(..., profile=True)`` additionally wraps the block in a
``jax.profiler.TraceAnnotation`` so the same names show up on a profiler
timeline when one is being captured (a no-op otherwise).

``round_every=k`` samples the round records: only every k-th round is
emitted (``rnd % k == 0``), and both trainers consult ``wants_round``
before materializing the record's floats — on off-rounds the per-round
device->host sync is skipped entirely, so a streamed run at ``k > 1``
keeps near the un-streamed throughput. The default ``k=1`` emits every
round and produces a byte-identical stream to pre-knob loggers.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, IO

from repro.telemetry.events import validate_event

__all__ = ["TelemetryLogger", "read_jsonl"]


def read_jsonl(path: str) -> list[dict]:
    """Load a telemetry stream back, validating the reserved fields."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(validate_event(json.loads(line)))
    return records


class TelemetryLogger:
    """Structured JSONL event stream (see :mod:`repro.telemetry.events`
    for the record schema). ``path=None`` keeps the stream in memory only
    (tests, throwaway runs)."""

    def __init__(self, path: str | None = None, run: str | None = None,
                 round_every: int = 1, **header: Any):
        if round_every < 1:
            raise ValueError(f"round_every must be >= 1, got {round_every}")
        self.path = path
        self.round_every = round_every
        self.records: list[dict] = []
        self._seq = 0
        self._t0 = time.time()
        self._phases: dict[str, float] = {}
        self._fh: IO[str] | None = open(path, "a") if path else None
        if run is not None or header:
            self.event("run", run=run, **header)

    # ------------------------------------------------------------- stream
    def event(self, kind: str, **fields: Any) -> dict:
        record = {"seq": self._seq, "ts": round(time.time() - self._t0, 6),
                  "kind": kind, **fields}
        validate_event(record)
        self._seq += 1
        self.records.append(record)
        if self._fh is not None:
            self._fh.write(json.dumps(record) + "\n")
            self._fh.flush()
        return record

    def wants_round(self, rnd: int) -> bool:
        """True when round ``rnd`` would be emitted under ``round_every``
        sampling. Callers should peek this BEFORE materializing round
        fields: the loss/metrics floats are device->host syncs, and the
        whole point of sampling is to skip that sync on off-rounds."""
        return rnd % self.round_every == 0

    def round(self, rnd: int, **fields: Any) -> dict:
        """One training-round record; folds in (and clears) the phase
        seconds accumulated since the last round record. Off-sample rounds
        (``round_every > 1``) emit nothing and keep accumulating phase
        seconds into the next emitted record."""
        if not self.wants_round(rnd):
            return {}
        phases = {k: round(v, 6) for k, v in self._phases.items()}
        self._phases.clear()
        extra = {"phases": phases} if phases else {}
        return self.event("round", round=rnd, **extra, **fields)

    def repair(self, record: dict) -> dict:
        """An elastic-runtime repair record (splice or permanent mask)."""
        return self.event("repair", **record)

    # ------------------------------------------------------------- phases
    @contextlib.contextmanager
    def phase(self, name: str, profile: bool = False):
        """Accumulate wall-clock for ``name`` until the next :meth:`round`.
        ``profile=True`` also annotates a captured profiler timeline."""
        ctx = contextlib.nullcontext()
        if profile:
            try:
                import jax
                ctx = jax.profiler.TraceAnnotation(name)
            except Exception:  # profiler unavailable: timing still works
                ctx = contextlib.nullcontext()
        t0 = time.perf_counter()
        with ctx:
            yield
        self._phases[name] = (self._phases.get(name, 0.0)
                              + time.perf_counter() - t0)

    # -------------------------------------------------------------- query
    def of_kind(self, kind: str) -> list[dict]:
        return [r for r in self.records if r["kind"] == kind]

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "TelemetryLogger":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
