"""Report aggregation: one summary from bench JSONs + run JSONL streams.

The benches each drop a JSON record under ``experiments/bench/`` and the
trainers write JSONL event streams; this module folds both into one
summary record with the headline tables —

* ``wire_bytes_per_round`` per codec (exact, from the engine's
  ``wire_struct``-derived accounting; recorded by ``bench_telemetry``),
* ``rounds_per_sec`` per measured cell (every bench row that carries one),
* ``rounds_to_threshold`` per consensus-crossing cell (every bench row that
  records one — the Chebyshev panel in ``bench_elastic`` and the sparse
  k_fraction sweep in ``bench_comm``), with the wire/bytes columns,
* ``retraces`` per counted cell (every ``n_traces`` a bench recorded, plus
  the ``compile`` events of each run stream),
* ``consensus`` trajectory per run (the ``resid_sqnorm`` series from the
  round records),
* ``repairs`` / round + phase-seconds totals per run.

CLI::

    PYTHONPATH=src python -m repro.telemetry.report \
        --bench-dir experiments/bench --log runs/demo.jsonl \
        --out experiments/bench/summary.json

``benchmarks/run.py --report`` and the CI bench-smoke lane call
:func:`build_summary` directly and upload the result as one artifact.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any

from repro.telemetry.log import read_jsonl

__all__ = ["build_summary", "load_bench_records", "summarize_run_log"]


def load_bench_records(bench_dir: str) -> dict[str, Any]:
    """``{basename-without-ext: parsed json}`` for every bench record."""
    records = {}
    for path in sorted(glob.glob(os.path.join(bench_dir, "*.json"))):
        name = os.path.splitext(os.path.basename(path))[0]
        if name == "summary":
            continue  # never fold a previous summary into the next one
        try:
            with open(path) as f:
                records[name] = json.load(f)
        except (OSError, json.JSONDecodeError):
            records[name] = {"error": f"unreadable: {path}"}
    return records


def _walk(node: Any, path: str):
    """Yield (dotted-path, dict) for every dict in a parsed JSON tree."""
    if isinstance(node, dict):
        yield path, node
        for k, v in node.items():
            yield from _walk(v, f"{path}.{k}" if path else str(k))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, f"{path}[{i}]")


def _cell_label(path: str, d: dict) -> str:
    return str(d.get("label") or d.get("bench") or d.get("name") or path)


def summarize_run_log(path: str) -> dict:
    """Headline summary of one JSONL run stream (see events.py schema)."""
    records = read_jsonl(path)
    rounds = [r for r in records if r["kind"] == "round"]
    compiles = [r for r in records if r["kind"] == "compile"]
    repairs = [r for r in records if r["kind"] == "repair"]
    consensus = []
    for r in rounds:
        # trainers flatten the metric summary into the round record; accept
        # a nested "metrics" sub-dict too for hand-rolled streams
        v = r.get("resid_sqnorm")
        if v is None and isinstance(r.get("metrics"), dict):
            v = r["metrics"].get("resid_sqnorm")
        if v is not None:
            consensus.append([r["round"], v])
    phases: dict[str, float] = {}
    for r in rounds:
        for name, sec in (r.get("phases") or {}).items():
            phases[name] = phases.get(name, 0.0) + sec
    out = {
        "log": path,
        "rounds": len(rounds),
        "retraces": len(compiles),
        "repairs": len(repairs),
        "phase_seconds": {k: round(v, 3) for k, v in sorted(phases.items())},
    }
    if consensus:
        out["consensus"] = consensus
    losses = [r["loss"] for r in rounds if "loss" in r]
    if losses:
        out["first_loss"], out["last_loss"] = losses[0], losses[-1]
    return out


def build_summary(bench_dir: str = "experiments/bench",
                  logs: tuple[str, ...] = (),
                  out: str | None = None) -> dict:
    """Merge every bench record + run stream into the one summary dict
    (written to ``out`` when given)."""
    benches = load_bench_records(bench_dir)
    rounds_per_sec: dict[str, dict] = {}
    rounds_to_threshold: dict[str, dict] = {}
    retraces: dict[str, int] = {}
    for bench, record in benches.items():
        for path, d in _walk(record, bench):
            if "rounds_per_sec" in d:
                label = _cell_label(path, d)
                cell = {"rounds_per_sec": d["rounds_per_sec"]}
                for extra in ("rounds_per_sec_one_peer", "codec", "screen",
                              "n_clients", "spectral_gap"):
                    if extra in d:
                        cell[extra] = d[extra]
                rounds_per_sec[f"{bench}/{label}"] = cell
            if "rounds_to_threshold" in d:
                label = _cell_label(path, d)
                cell = {"rounds_to_threshold": d["rounds_to_threshold"]}
                for extra in ("family", "sub_rounds", "codec", "k_fraction",
                              "lam", "cheby_lambda", "wire_bytes_per_round",
                              "bytes_to_threshold", "mean_keep_at_rt"):
                    if extra in d:
                        cell[extra] = d[extra]
                rounds_to_threshold[f"{bench}/{label}"] = cell
            if "n_traces" in d:
                retraces[f"{bench}/{_cell_label(path, d)}"] = d["n_traces"]
    wire_bytes = (benches.get("telemetry") or {}).get("wire_bytes", {})
    summary = {
        "bench_dir": bench_dir,
        "benches": sorted(benches),
        "wire_bytes_per_round": wire_bytes,
        "rounds_per_sec": rounds_per_sec,
        "rounds_to_threshold": rounds_to_threshold,
        "retraces": retraces,
        "runs": [summarize_run_log(p) for p in logs],
    }
    if out:
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        with open(out, "w") as f:
            json.dump(summary, f, indent=1)
    return summary


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir", default="experiments/bench")
    ap.add_argument("--log", action="append", default=[],
                    help="run JSONL stream(s) to fold in (repeatable)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    summary = build_summary(args.bench_dir, tuple(args.log), args.out)
    print(json.dumps(summary, indent=1))


if __name__ == "__main__":
    main()
