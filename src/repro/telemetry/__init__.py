"""Telemetry: in-graph round metrics, the unified event stream, and reports.

The repo's central invariants — exactly d collectives/round, zero retraces
under churn/gates/active-sets, screened-wire suspicion — used to be
observable only through scattered per-bench asserts and ad-hoc ``n_traces``
counters. This package makes them one queryable layer:

* :mod:`repro.telemetry.metrics` — the **traced** side. An opt-in
  :class:`TelemetryConfig` on :class:`repro.core.engine.GossipEngineConfig`
  (surfaced as ``ParallelConfig.gossip_telemetry`` and the trainers'
  ``telemetry`` knob) makes the executor and the production train step
  additionally return a small :data:`RoundMetrics` pytree of traced values
  computed from what the round already materializes — neighborhood residual
  sqnorms (the consensus proxy), live/active in-degree, per-schedule
  contributor mass, norm-clip counts, attack-vector energy, exact per-codec
  wire bytes. Telemetry **off** is bit-identical HLO to the untelemetered
  step (anchored like delay-0); telemetry **on** adds zero collectives and
  zero retraces — metrics are outputs, never trace structure.
* :mod:`repro.telemetry.events` / :mod:`repro.telemetry.log` — the
  **host** side. One structured JSONL logger (:class:`TelemetryLogger`)
  both trainers thread through: round records, compile/retrace events via
  the one shared :class:`TraceCounter`, repair/quarantine/splice records,
  attack activations, per-phase wall-clock.
* :mod:`repro.telemetry.report` — merge the per-bench
  ``experiments/bench/*.json`` records and run JSONL logs into one summary
  (wire bytes/round per codec, rounds/sec per cell, retrace counts,
  consensus trajectory) — the single CI artifact.
"""
from repro.telemetry.events import EVENT_KINDS, TraceCounter
from repro.telemetry.log import TelemetryLogger, read_jsonl
from repro.telemetry.metrics import (RoundMetrics, TelemetryConfig,
                                     summarize_metrics)
from repro.telemetry.report import build_summary

__all__ = [
    "EVENT_KINDS",
    "RoundMetrics",
    "TelemetryConfig",
    "TelemetryLogger",
    "TraceCounter",
    "build_summary",
    "read_jsonl",
    "summarize_metrics",
]
