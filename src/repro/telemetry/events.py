"""Event schema + the one shared retrace counter.

Every record in the telemetry stream is a flat JSON-able dict with three
reserved fields stamped by :class:`repro.telemetry.log.TelemetryLogger`:
``seq`` (per-logger monotone ordinal — total order within a run), ``ts``
(wall clock, seconds) and ``kind`` (one of :data:`EVENT_KINDS`). Everything
else is kind-specific payload:

* ``run``     — run header (config echo, wire bytes, client count).
* ``round``   — one training round: loss, metrics summary, phase seconds.
* ``compile`` — a jit trace happened (:class:`TraceCounter` hook): counter
  name + running count. Round 0 emits exactly one; any later one is the
  re-jit of a membership change — anything else is a retrace bug.
* ``repair``  — a splice repair / permanent masking (the elastic runtime's
  ``repairs`` record verbatim: dead, spliced, quarantined/masked, n_after).
* ``suspicion`` — one round of norm-clip clip counts entering the
  :class:`repro.core.failures.HealthTracker` (per-sender totals).
* ``attack``  — the scripted attacker set changed (AttackPlan activation).
* ``note``    — freeform.
"""
from __future__ import annotations

import functools
from typing import Any

__all__ = ["EVENT_KINDS", "TraceCounter", "validate_event"]

EVENT_KINDS = ("run", "round", "compile", "repair", "suspicion", "attack",
               "note")


def validate_event(record: dict) -> dict:
    """Check the reserved fields of one stream record (round-trip guard)."""
    for field in ("seq", "ts", "kind"):
        if field not in record:
            raise ValueError(f"telemetry record missing {field!r}: {record}")
    if record["kind"] not in EVENT_KINDS:
        raise ValueError(f"unknown telemetry event kind {record['kind']!r}; "
                         f"available: {', '.join(EVENT_KINDS)}")
    return record


class TraceCounter:
    """THE retrace counter — one implementation for every ``n_traces`` /
    ``_cache_size()`` variant the tests and benches used to hand-roll.

    Three equivalent hookups, matching the three legacy idioms:

    * :meth:`hit` — call it inside the function being jitted (a python
      side effect, so it runs at trace time only)::

          tc = TraceCounter("round")
          @jax.jit
          def round_fn(...):
              tc.hit()
              ...
          assert tc.count == 1

    * :meth:`wrap` — the same, as a decorator for a pre-built body.
    * :meth:`cache_size` — read an already-jitted function's executable
      cache (the ``step_fn._cache_size()`` idiom; no instance needed).

    With a :class:`repro.telemetry.log.TelemetryLogger` attached, every hit
    additionally emits a ``compile`` event into the stream, so retraces are
    queryable next to the rounds that caused them.
    """

    def __init__(self, name: str = "step", logger: Any = None):
        self.name = name
        self.count = 0
        self.logger = logger

    def hit(self) -> None:
        """Count one trace (call from inside the traced function)."""
        self.count += 1
        if self.logger is not None:
            self.logger.event("compile", counter=self.name, count=self.count)

    def wrap(self, fn):
        """``fn`` with a :meth:`hit` on entry (count traces of ``jit(
        tc.wrap(fn))``)."""
        @functools.wraps(fn)
        def counted(*args, **kwargs):
            self.hit()
            return fn(*args, **kwargs)
        return counted

    def reset(self) -> None:
        self.count = 0

    @staticmethod
    def cache_size(jitted: Any) -> int:
        """Executable-cache size of a jitted function — the compiled-trace
        count for callers that cannot instrument the body."""
        return int(jitted._cache_size())

    def expect(self, expected: int, what: str = "") -> None:
        """Assert the count (the shared assertion the benches emit)."""
        if self.count != expected:
            raise AssertionError(
                f"{self.name}: {self.count} traces, expected {expected}"
                + (f" ({what})" if what else ""))

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"TraceCounter({self.name!r}, count={self.count})"
