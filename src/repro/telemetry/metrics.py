"""In-graph round metrics: the traced half of the telemetry subsystem.

:class:`TelemetryConfig` is a static (hashable, frozen) knob carried by
:class:`repro.core.engine.GossipEngineConfig`. When set, the executor (and
through it the production train step) additionally returns a
:data:`RoundMetrics` dict of traced values, every one of them computed from
something the round already materializes:

* ``resid_sqnorm`` — the **consensus proxy**: per receiver, the
  contributor-weighted squared distance between each mixed-in neighbor
  payload and the receiver's own fresh buffer,
  ``sum_s contrib[1+s] * ||decode(recv_s) - fresh||^2``, accumulated over
  buffers through the same fused :func:`packed_sqnorms` per-block pass the
  norm-clip screen uses. It measures what was *actually mixed* — the
  delayed snapshot in pipelined mode, the dequantized wire under the int8
  codecs. On the shard_map substrate each device reports its local
  *shard's* residual (summing them host-side over the non-client mesh axes
  gives the whole-model value up to replicated leaves — a monotone proxy,
  which is all a consensus trajectory needs, and the price of adding
  **zero** collectives).
* ``in_degree`` — this round's effective live/active in-degree per client:
  ``sum_s contrib[1+s]`` (gates x live-mask x sender-liveness; fixed points
  are invisible, exactly as in the mixing reduction).
* ``sched_contrib`` — the per-(client, schedule) contributor mass, the
  pre-aggregation form of "per-schedule gate mass" (column-sum host-side;
  a per-schedule *global* sum in-graph would cost a collective on
  shard_map, so aggregation stays on the host).
* ``clipped`` / ``clip_recv`` — norm-clip screen counts
  (``screen="norm_clip"`` only). The stacked substrate has the global view
  and emits per-SENDER counts of receivers that clipped them (the
  suspicion signal :class:`repro.core.failures.HealthTracker` accumulates);
  the shard_map substrate emits the local per-RECEIVER count of incoming
  wires it clipped (a per-sender count there would need a reverse
  collective).

Wire bytes and attack energy ride next to these at the layer that owns the
data: exact per-codec wire bytes come from
:meth:`repro.core.engine.GossipExecutor.wire_bytes_per_round` (static — a
constant output / a logged field), and ``attack_energy`` is computed by the
step/trainer from the (2, n) attack operand (``sum (scale-1)^2 + noise^2``;
zero on all-honest rounds).

The build-time-branch discipline is the delay-0 one: ``telemetry=None``
(the default everywhere) adds **no ops and no outputs** — the lowered HLO
is textually identical to the untelemetered step (regression-anchored in
``tests/test_telemetry.py``). A non-None config only appends outputs; the
collectives and the trace structure are untouched, so churn / gate
rotation / cohort rotation still reuse ONE executable.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "RoundMetrics",
    "TelemetryConfig",
    "block_sqnorm",
    "clip_only",
    "summarize_metrics",
]

# a RoundMetrics value is a plain dict of traced arrays; the key set is
# fixed by (TelemetryConfig, engine cell) at build time — data flows, the
# structure never changes (zero retraces)
RoundMetrics = dict


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Static per-metric switches (frozen => hashable, closable by jit).

    Attributes:
      consensus: emit ``resid_sqnorm`` (costs one fused sqnorm pass per
        (buffer, schedule); the quantized stacked norm-clip cell also pays
        a dequant of the gathered wires it otherwise never decodes).
      degree: emit ``in_degree`` + ``sched_contrib`` (a handful of scalar
        ops off the contributor table the round already builds).
      clip: emit the norm-clip screen counts (``screen="norm_clip"``
        cells only; ignored elsewhere).
    """

    consensus: bool = True
    degree: bool = True
    clip: bool = True

    @property
    def any_on(self) -> bool:
        return self.consensus or self.degree or self.clip


def clip_only() -> TelemetryConfig:
    """The minimal cell the elastic runtime uses to keep quarantine fed
    when the user did not ask for metrics: clip counts, nothing else."""
    return TelemetryConfig(consensus=False, degree=False, clip=True)


def block_sqnorm(buf: jax.Array, *, block_rows: int, impl: str) -> jax.Array:
    """Whole-buffer squared norm through the fused per-block pass (the
    same ``packed_sqnorms`` kernel the norm-clip screen piggybacks on)."""
    from repro.kernels.gossip_mix import ops as mix_ops

    return jnp.sum(mix_ops.packed_sqnorms(buf, block_rows=block_rows,
                                          impl=impl))


def summarize_metrics(metrics: RoundMetrics | None,
                      n_clients: int | None = None) -> dict:
    """Host-side JSON-ready summary of one round's RoundMetrics pytree.

    Accepts both layouts: the stacked substrate's client-leading arrays
    and the production step's mesh-shaped arrays (per-device values with
    one leading dim per mesh axis — see the module docstring's shard_map
    note). ``resid`` sums everything (shards partition the model);
    per-client-replicated quantities (``in_degree``, ``sched_contrib``)
    average over the device copies, scaled back up by ``n_clients`` where
    the quantity is a population total.
    """
    if not metrics:
        return {}
    out: dict[str, Any] = {}
    if "resid_sqnorm" in metrics:
        out["resid_sqnorm"] = float(jnp.sum(metrics["resid_sqnorm"]))
    if "in_degree" in metrics:
        deg = np.asarray(metrics["in_degree"], np.float64)
        out["in_degree_mean"] = float(deg.mean())
    if "sched_contrib" in metrics:
        sc = np.asarray(metrics["sched_contrib"], np.float64)
        sc = sc.reshape(-1, sc.shape[-1])           # (copies*clients, S)
        mass = sc.mean(axis=0)
        if n_clients is not None:
            mass = mass * n_clients                 # per-schedule gate mass
        out["sched_mass"] = [round(float(m), 6) for m in mass]
    for key in ("clipped", "clip_recv"):
        if key in metrics:
            arr = np.asarray(metrics[key])
            out[f"{key}_total"] = int(arr.sum())
    if "attack_energy" in metrics:
        out["attack_energy"] = float(np.asarray(metrics["attack_energy"]))
    if "wire_bytes" in metrics:
        out["wire_bytes"] = int(float(np.asarray(metrics["wire_bytes"])))
    return out
