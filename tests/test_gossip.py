"""Gossip executor equivalence + convergence-to-consensus tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional dep (requirements-dev.txt): property tests degrade, not error
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import gossip, topology


def _tree(n, seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.standard_normal((n, 6, 5)), jnp.float32),
            "b": jnp.asarray(r.standard_normal((n, 11)), jnp.float32)}


class TestExecutorEquivalence:
    @pytest.mark.parametrize("n,d", [(8, 2), (16, 4), (12, 3)])
    def test_schedules_match_dense(self, n, d):
        ov = topology.expander_overlay(n, d, seed=0)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(n)
        dense = gossip.mix_dense(x, ov.mixing_matrix())
        sched = gossip.mix_schedules(x, spec)
        for k in x:
            np.testing.assert_allclose(dense[k], sched[k], rtol=2e-5, atol=2e-5)

    def test_gossip_preserves_mean(self):
        """Doubly-stochastic mixing: the client-mean is invariant."""
        ov = topology.expander_overlay(16, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(16, seed=3)
        y = gossip.mix_schedules(x, spec)
        for k in x:
            np.testing.assert_allclose(jnp.mean(x[k], 0), jnp.mean(y[k], 0),
                                       rtol=1e-4, atol=1e-5)

    def test_consensus_rate_matches_lambda(self):
        """Disagreement contracts at rate lambda per round (spectral theory)."""
        n = 32
        ov = topology.expander_overlay(n, 4, seed=1)
        spec = gossip.make_gossip_spec(ov)
        lam = spec.lam
        r = np.random.default_rng(0)
        x = {"w": jnp.asarray(r.standard_normal((n, 40)), jnp.float32)}
        def disagreement(t):
            mean = jnp.mean(t["w"], 0, keepdims=True)
            return float(jnp.linalg.norm(t["w"] - mean))
        d0 = disagreement(x)
        for _ in range(10):
            x = gossip.mix_schedules(x, spec)
        d10 = disagreement(x)
        assert d10 <= d0 * (lam ** 10) * 1.05  # within 5% of the bound

    def test_expander_mixes_faster_than_ring(self):
        n = 32
        r = np.random.default_rng(0)
        x0 = np.asarray(r.standard_normal((n, 20)), np.float32)
        outs = {}
        for name, ov in [("ring", topology.ring_overlay(n)),
                         ("exp", topology.expander_overlay(n, 4, seed=0))]:
            spec = gossip.make_gossip_spec(ov)
            x = {"w": jnp.asarray(x0)}
            for _ in range(8):
                x = gossip.mix_schedules(x, spec)
            mean = jnp.mean(x["w"], 0, keepdims=True)
            outs[name] = float(jnp.linalg.norm(x["w"] - mean))
        assert outs["exp"] < outs["ring"] * 0.5


class TestShardMapGossip:
    """ppermute path == stacked-gather path, on real (fake-device) meshes."""

    def test_ppermute_matches_schedules(self):
        import subprocess
        import sys
        import textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import gossip, topology
            from repro.launch.mesh import shard_map

            mesh = jax.make_mesh((8,), ("client",))
            ov = topology.expander_overlay(8, 4, seed=0)
            spec = gossip.make_gossip_spec(ov)
            r = np.random.default_rng(0)
            x = jnp.asarray(r.standard_normal((8, 16, 3)), jnp.float32)

            ref = gossip.mix_schedules({"w": x}, spec)["w"]

            def body(t):
                local = jax.tree.map(lambda a: a[0], t)
                out = gossip.ppermute_mix(local, spec, "client")
                return jax.tree.map(lambda a: a[None], out)

            fn = shard_map(body, mesh, in_specs=(P("client"),),
                           out_specs=P("client"))
            got = jax.jit(fn)(jax.device_put(
                {"w": x}, NamedSharding(mesh, P("client"))))["w"]
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-5, atol=2e-5)
            print("PPERMUTE_OK")
        """)
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, cwd=".")
        assert "PPERMUTE_OK" in out.stdout, out.stdout + out.stderr


class TestFailureAdjustedGossip:
    def test_alive_weight_table_matches_masked_matrix(self):
        """The traced-argument weight table rebuilds mix_dense_masked's
        effective matrix row-for-row (the packed engine's masking math)."""
        ov = topology.expander_overlay(12, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        alive = np.ones(12, np.float32)
        alive[[2, 7]] = 0
        table = np.asarray(gossip.alive_weight_table(spec, jnp.asarray(alive)))
        # scatter the table back into an n x n matrix
        m = np.zeros((12, 12))
        m[np.arange(12), np.arange(12)] += table[:, 0]
        for s, rf in enumerate(spec.recv_from):
            for i, j in enumerate(rf):
                m[i, j] += table[i, 1 + s] if i != j else 0.0
        np.testing.assert_allclose(m.sum(1), 1.0, atol=1e-5)
        assert m[2, 2] == pytest.approx(1.0) and m[7, 7] == pytest.approx(1.0)
        alive_idx = [i for i in range(12) if alive[i]]
        assert np.all(np.abs(m[np.ix_(alive_idx, [2, 7])]) < 1e-7)

    def test_mix_packed_stacked_matches_dense_masked(self):
        """Stacked packed executor (the elastic round's mixing path) ==
        mix_dense_masked for random masks; unmasked == mix_dense."""
        ov = topology.expander_overlay(10, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        m = ov.mixing_matrix()
        x = _tree(10, seed=5)
        got = gossip.mix_packed_stacked(x, spec)
        ref = gossip.mix_dense(x, m)
        for k in x:
            np.testing.assert_allclose(got[k], ref[k], rtol=2e-5, atol=2e-5)
        r = np.random.default_rng(0)
        for t in range(4):
            alive = (r.random(10) > 0.3).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1
            got = gossip.mix_packed_stacked(x, spec, jnp.asarray(alive))
            ref = gossip.mix_dense_masked(x, m, alive)
            for k in x:
                np.testing.assert_allclose(got[k], ref[k],
                                           rtol=2e-5, atol=2e-5)


class TestDelayedGossip:
    """Pipelined (one-round-delayed) mixing: the stacked delayed executor
    against the mix_dense_delayed oracle, and the delay=0 anchors."""

    def test_delayed_stacked_matches_dense_delayed(self):
        ov = topology.expander_overlay(10, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        fresh = _tree(10, seed=5)
        prev = _tree(10, seed=6)
        snap = gossip.pack_state_stacked(prev)
        got, new_snap = gossip.mix_packed_stacked_delayed(fresh, snap, spec)
        ref = gossip.mix_dense_delayed(fresh, prev, spec)
        for k in fresh:
            np.testing.assert_allclose(got[k], ref[k], rtol=2e-5, atol=2e-5)
        # the new in-flight state is this round's packed fresh tree
        want = gossip.pack_state_stacked(fresh)
        for a, b in zip(new_snap, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_delayed_composes_with_alive_and_gates(self):
        ov = topology.expander_overlay(12, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        fresh, prev = _tree(12, seed=7), _tree(12, seed=8)
        snap = gossip.pack_state_stacked(prev)
        r = np.random.default_rng(0)
        for t in range(3):
            alive = (r.random(12) > 0.3).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1
            gates = np.zeros(spec.degree, np.float32)
            gates[t % spec.degree] = 1.0  # one-peer round
            got, _ = gossip.mix_packed_stacked_delayed(
                fresh, snap, spec, jnp.asarray(alive),
                gates=jnp.asarray(gates))
            ref = gossip.mix_dense_delayed(fresh, prev, spec,
                                           jnp.asarray(gates),
                                           jnp.asarray(alive))
            for k in fresh:
                np.testing.assert_allclose(got[k], ref[k],
                                           rtol=2e-5, atol=2e-5)

    def test_self_snapshot_is_bitwise_sync(self):
        """delay=0 anchor: feeding the CURRENT tree as the snapshot must
        reproduce the synchronous packed executor bit-for-bit (identical
        stack, identical einsum)."""
        ov = topology.expander_overlay(8, 4, seed=1)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(8, seed=9)
        got, _ = gossip.mix_packed_stacked_delayed(
            x, gossip.pack_state_stacked(x), spec)
        sync = gossip.mix_packed_stacked(x, spec)
        for k in x:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(sync[k]))

    def test_dense_delayed_with_fresh_equals_sync_oracle(self):
        ov = topology.expander_overlay(10, 4, seed=3)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(10, seed=10)
        got = gossip.mix_dense_delayed(x, x, spec)
        ref = gossip.mix_dense(x, ov.mixing_matrix())
        for k in x:
            np.testing.assert_allclose(got[k], ref[k], rtol=2e-5, atol=2e-5)

    def test_delayed_recursion_reaches_consensus(self):
        """One-round staleness slows mixing but still contracts to
        consensus (the convergence story of asynchronous gossip)."""
        n = 16
        ov = topology.expander_overlay(n, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        r = np.random.default_rng(0)
        x = {"w": jnp.asarray(r.standard_normal((n, 24)), jnp.float32)}
        y = x

        def disagreement(t):
            mean = jnp.mean(t["w"], 0, keepdims=True)
            return float(jnp.linalg.norm(t["w"] - mean))

        d0 = disagreement(x)
        for _ in range(20):
            x, y = gossip.mix_dense_delayed(x, y, spec), x
        assert disagreement(x) < 0.05 * d0


def _check_executors_agree(n, d, seed):
    ov = topology.expander_overlay(n, d, seed=seed)
    spec = gossip.make_gossip_spec(ov)
    x = _tree(n, seed=seed)
    dense = gossip.mix_dense(x, ov.mixing_matrix())
    sched = gossip.mix_schedules(x, spec)
    for k in x:
        np.testing.assert_allclose(dense[k], sched[k], rtol=3e-5, atol=3e-5)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([8, 12, 16]), d=st.sampled_from([2, 3, 4]),
           seed=st.integers(0, 500))
    def test_gossip_executors_agree_property(n, d, seed):
        _check_executors_agree(n, d, seed)
else:
    @pytest.mark.parametrize("n,d,seed", [(8, 2, 0), (12, 3, 7), (16, 4, 123),
                                          (16, 2, 31), (12, 4, 255)])
    def test_gossip_executors_agree_property(n, d, seed):
        _check_executors_agree(n, d, seed)
