"""Massive-client substrate tests: active-set round plans + blocked gossip.

Covers the two halves of the client-count/device-count decoupling:

* :class:`repro.overlay.plan.ActiveSetPlan` — round-level client subsampling
  shipped as step data (participation-as-data: zero retraces across cohort
  rotations, never visible to the HealthTracker);
* the ``blocked`` engine substrate (`repro.core.gossip.BlockedSpec`) — B
  simulated clients per device, intra-block edges as stacked gathers and
  cross-block schedule parts as whole-block ppermutes, bit-compatible with
  the stacked substrate.
"""
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import dfedavg, engine as engine_lib, failures as failures_lib, \
    gossip, topology
from repro.launch.elastic import ElasticTrainer
from repro.overlay import plan as plan_lib


# ------------------------------------------------------------ active sets
class TestActiveSetPlans:
    def test_full_plan_is_inert(self):
        """No plan and the "full" plan are the same non-engagement: ones
        vector, is_subsampling False (the predicate the step builders key
        their signature on)."""
        assert not plan_lib.is_subsampling(None)
        assert not plan_lib.is_subsampling(plan_lib.FullActiveSet())
        assert plan_lib.is_subsampling(plan_lib.RandomKActiveSet(k=2))
        np.testing.assert_array_equal(plan_lib.active_for(None, 3, 7),
                                      np.ones(7, np.float32))
        np.testing.assert_array_equal(
            plan_lib.active_for(plan_lib.FullActiveSet(), 3, 7),
            np.ones(7, np.float32))

    def test_random_k_count_and_determinism(self):
        plan = plan_lib.RandomKActiveSet(k=5, seed=3)
        for rnd in range(6):
            a = plan.active(rnd, 16)
            assert a.sum() == 5 and set(np.unique(a)) <= {0.0, 1.0}
            np.testing.assert_array_equal(a, plan.active(rnd, 16))
        # cohorts rotate (not the same set every round)
        assert any(not np.array_equal(plan.active(0, 16), plan.active(r, 16))
                   for r in range(1, 6))

    def test_shards_cover_everyone_exactly_once(self):
        plan = plan_lib.ShardActiveSet(n_shards=4)
        total = np.zeros(12)
        for rnd in range(4):
            a = plan.active(rnd, 12)
            assert a.sum() == 3  # 12 clients / 4 shards
            total += a
        np.testing.assert_array_equal(total, np.ones(12))

    def test_stratified_every_stratum_represented(self):
        plan = plan_lib.StratifiedActiveSet(k=4, n_strata=4, seed=0)
        for rnd in range(5):
            a = plan.active(rnd, 16)
            # strata are contiguous quarters; each must send >= 1 client
            for j in range(4):
                assert a[4 * j:4 * (j + 1)].sum() >= 1
            np.testing.assert_array_equal(a, plan.active(rnd, 16))

    def test_factory_names_and_validation(self):
        assert plan_lib.make_active_set("full").name == "full"
        assert plan_lib.make_active_set("random_k", k=3).k == 3
        assert plan_lib.make_active_set("shards", n_shards=5).n_shards == 5
        st = plan_lib.make_active_set("stratified", k=4, n_shards=2)
        assert st.n_strata == 2
        with pytest.raises(ValueError, match="unknown active-set plan"):
            plan_lib.make_active_set("typo")
        assert set(plan_lib.ACTIVE_SET_NAMES) == {
            "full", "random_k", "shards", "stratified"}


# ------------------------------------------------------------ blocked spec
class TestBlockedSpec:
    def test_block_equals_n_is_intra_only(self):
        """B = n: one device holds everyone — every schedule is intra-block
        (no transfers) and the gather table degenerates to recv_from."""
        ov = topology.expander_overlay(12, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        bs = gossip.make_blocked_spec(spec, 12)
        assert bs.n_devices == 1 and bs.n_transfers == 0
        assert bs.cross_schedules == 0
        for s, rf in enumerate(spec.recv_from):
            np.testing.assert_array_equal(bs.gather_flat[s], rf)

    def test_ring_two_devices(self):
        """Ring on 2 devices: each direction schedule has exactly one
        cross-block partial permutation (the {0->1, 1->0} swap)."""
        ov = topology.ring_overlay(8)
        spec = gossip.make_gossip_spec(ov)
        bs = gossip.make_blocked_spec(spec, 4)
        assert bs.n_devices == 2
        assert bs.cross_schedules == len(spec.recv_from)
        # on 2 devices a schedule's cross demand is always one swap
        assert bs.n_transfers == bs.cross_schedules
        for part in bs.transfers:
            assert set(part) <= {(0, 1), (1, 0)}

    @pytest.mark.parametrize("n,d,b", [(12, 4, 3), (16, 4, 4), (12, 4, 6),
                                       (16, 2, 8)])
    def test_gather_table_reconstructs_recv_from(self, n, d, b):
        """Brute-force replay of the blocked round's data movement with
        client ids as payload: applying the transfers and then the flat
        gather must reproduce each schedule's recv_from exactly."""
        ov = topology.expander_overlay(n, d, seed=1)
        spec = gossip.make_gossip_spec(ov)
        bs = gossip.make_blocked_spec(spec, b)
        device_wire = [np.arange(dev * b, (dev + 1) * b)
                       for dev in range(bs.n_devices)]
        for s, rf in enumerate(spec.recv_from):
            for dev in range(bs.n_devices):
                cand = [device_wire[dev]]
                for part in bs.transfers:
                    srcs = [sd for (sd, dd) in part if dd == dev]
                    # ppermute: a device outside the partial permutation
                    # receives zeros; -1 sentinel catches a bad slot
                    cand.append(device_wire[srcs[0]] if srcs
                                else np.full(b, -1))
                flat = np.concatenate(cand)
                for row in range(b):
                    i = dev * b + row
                    assert flat[bs.gather_flat[s][i]] == rf[i], (s, i)

    def test_partial_permutation_invariant(self):
        """No device sends or receives twice within one transfer (the
        condition for a single ppermute to carry the whole part)."""
        ov = topology.expander_overlay(16, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        bs = gossip.make_blocked_spec(spec, 2)
        for part in bs.transfers:
            srcs = [s for s, _ in part]
            dsts = [d for _, d in part]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)

    def test_invalid_block_rejected(self):
        ov = topology.expander_overlay(12, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        with pytest.raises(ValueError, match="dividing n_clients"):
            gossip.make_blocked_spec(spec, 5)
        with pytest.raises(ValueError, match="dividing n_clients"):
            gossip.make_blocked_spec(spec, 0)


# ------------------------------------------------- engine config validation
class TestBlockedConfigValidation:
    def test_delay_on_blocked_rejected_names_supported_cells(self):
        with pytest.raises(ValueError) as e:
            engine_lib.GossipEngineConfig(substrate="blocked", block=4,
                                          delay=1)
        msg = str(e.value)
        assert "shard_map | stacked" in msg and "blocked" in msg

    def test_screen_on_blocked_rejected_names_supported_cells(self):
        for screen in ("norm_clip", "trimmed_mean"):
            with pytest.raises(ValueError) as e:
                engine_lib.GossipEngineConfig(substrate="blocked", block=4,
                                              screen=screen)
            msg = str(e.value)
            assert "shard_map | stacked" in msg and "blocked" in msg

    def test_blocked_needs_block(self):
        with pytest.raises(ValueError, match="block >= 1"):
            engine_lib.GossipEngineConfig(substrate="blocked")

    def test_block_on_other_substrates_rejected(self):
        with pytest.raises(ValueError):
            engine_lib.GossipEngineConfig(substrate="stacked", block=4)

    def test_blocked_needs_axis_names(self):
        ov = topology.expander_overlay(8, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        with pytest.raises(ValueError, match="axis_names"):
            engine_lib.build_gossip_executor(
                engine_lib.GossipEngineConfig(substrate="blocked", block=4),
                spec)


# ------------------------------------------------ single-device blocked
def _island(executor, mesh):
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import shard_map

    def body(t, a, g):
        return executor(t, alive=a, gates=g)

    return jax.jit(shard_map(body, mesh, in_specs=(P("clients"), P(), P()),
                             out_specs=P("clients")))


class TestBlockedParityOneDevice:
    """block = n on the single local device: the blocked round must be
    BITWISE identical to the stacked round (identical stack + einsum)."""

    def test_bitwise_vs_stacked_with_alive_and_gates(self):
        from jax.sharding import Mesh
        n = 12
        ov = topology.expander_overlay(n, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        r = np.random.default_rng(0)
        tree = {"a": jnp.asarray(r.standard_normal((n, 6, 5)), jnp.float32),
                "b": jnp.asarray(r.standard_normal((n, 11)), jnp.float32)}
        stacked = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="stacked"), spec)
        blocked = engine_lib.build_gossip_executor(
            engine_lib.GossipEngineConfig(substrate="blocked", block=n),
            spec, axis_names="clients")
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("clients",))
        fn = _island(blocked, mesh)
        for t in range(3):
            alive = (np.random.default_rng(t).random(n) > 0.3
                     ).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1
            gates = np.zeros(spec.degree, np.float32)
            gates[t % spec.degree] = 1.0
            ref = stacked(tree, alive=jnp.asarray(alive),
                          gates=jnp.asarray(gates))
            got = fn(tree, jnp.asarray(alive), jnp.asarray(gates))
            for k in tree:
                np.testing.assert_array_equal(np.asarray(got[k]),
                                              np.asarray(ref[k]))


# --------------------------------------------- trainer-level composition
def _quad_loss(p, b):
    pred = b["x"] @ p["w"]
    return jnp.mean((pred - b["y"]) ** 2), {}


def _quad_setup(n, seed=0):
    r = np.random.default_rng(seed)
    params = {"w": jnp.asarray(r.standard_normal((n, 5, 3)), jnp.float32)}

    def batches(rnd, m=n):  # m: current client count (shrinks after splice)
        rr = np.random.default_rng(1000 + rnd)
        return {"x": jnp.asarray(rr.standard_normal((m, 8, 5)), jnp.float32),
                "y": jnp.asarray(rr.standard_normal((m, 8, 3)), jnp.float32)}

    return params, batches


def _make_trainer(n, **kw):
    ov = topology.expander_overlay(n, 4, seed=0)
    dcfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.05, momentum=0.9)
    return ElasticTrainer(overlay=ov, loss_fn=_quad_loss, dcfg=dcfg, **kw)


class TestElasticActiveSetComposition:
    """Satellite: active-set plan x alive churn x one-peer gates x
    AttackPlan, on both the stacked and (1-device) blocked substrates —
    zero retraces across >= 3 cohort rotations, identical results."""

    def _run(self, n, rounds, gossip_block):
        t = _make_trainer(
            n,
            plan=plan_lib.make_plan("one_peer"),
            active_plan=plan_lib.make_active_set("random_k", k=n // 2,
                                                 seed=1),
            attack_plan=failures_lib.sample_attackers(n, 2, seed=3),
            engine=engine_lib.GossipEngineConfig(
                substrate="blocked" if gossip_block else "stacked",
                block=gossip_block))
        params, batches = _quad_setup(n)
        r = np.random.default_rng(7)
        for rnd in range(rounds):
            hb = (r.random(n) > 0.2).astype(np.float32)  # straggler churn
            params, _, o2n = t.observe_heartbeats(hb, params)
            assert o2n is None  # churn below failure_rounds: no repair
            params, _ = t.step(params, batches(rnd), 0.05)
        return t, params

    def test_zero_retraces_and_blocked_parity(self):
        n, rounds = 12, 5  # >= 3 distinct cohorts from the random_k plan
        t_stacked, p_stacked = self._run(n, rounds, gossip_block=0)
        t_blocked, p_blocked = self._run(n, rounds, gossip_block=n)
        assert t_stacked.n_traces == 1
        assert t_blocked.n_traces == 1
        # distinct cohorts actually happened (rotation, not repetition)
        cohorts = {tuple(t_stacked.active_for_round(r)) for r in range(rounds)}
        assert len(cohorts) >= 3
        np.testing.assert_array_equal(np.asarray(p_stacked["w"]),
                                      np.asarray(p_blocked["w"]))

    def test_active_set_never_feeds_health_tracker(self):
        """Inactive clients are resting, not failing: with every heartbeat
        present, a rotating active set must leave the tracker pristine —
        no stragglers, no dead, no repairs."""
        n = 8
        t = _make_trainer(n, active_plan=plan_lib.ShardActiveSet(n_shards=4))
        params, batches = _quad_setup(n)
        for rnd in range(6):
            params, _, _ = t.observe_heartbeats(np.ones(n, np.float32),
                                                params)
            params, _ = t.step(params, batches(rnd), 0.05)
        assert t.health.missed.sum() == 0
        assert len(t.health.stragglers()) == 0 and len(t.health.dead()) == 0
        assert t.repairs == [] and t.n_traces == 1

    def test_inactive_clients_mix_as_identity(self):
        """One gossip round: an alive-but-inactive client keeps its
        post-local-step params (identity row), and its neighbors mix
        without it — the dead-client semantics, minus the health cost."""
        n = 10
        ov = topology.expander_overlay(n, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        r = np.random.default_rng(0)
        x = {"w": jnp.asarray(r.standard_normal((n, 7)), jnp.float32)}
        active = np.ones(n, np.float32)
        active[[2, 5]] = 0.0
        got = gossip.mix_packed_stacked(x, spec, alive=jnp.asarray(active))
        ref = gossip.mix_dense_masked(x, ov.mixing_matrix(), active)
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(ref["w"]), rtol=2e-5,
                                   atol=2e-5)
        np.testing.assert_array_equal(np.asarray(got["w"])[[2, 5]],
                                      np.asarray(x["w"])[[2, 5]])

    def test_byte_exact_remap_through_splice_repair(self):
        """A permanent failure under an active-set plan: the splice must
        remap the surviving rows byte-exactly (pure row gather, no math)
        and cost exactly one retrace."""
        n = 12
        t = _make_trainer(
            n, plan=plan_lib.make_plan("one_peer"),
            active_plan=plan_lib.make_active_set("shards", n_shards=3),
            failure_rounds=2)
        params, batches = _quad_setup(n)
        for rnd in range(2):
            params, _, _ = t.observe_heartbeats(np.ones(n, np.float32),
                                                params)
            params, _ = t.step(params, batches(rnd), 0.05)
        assert t.n_traces == 1
        before = np.asarray(params["w"])
        hb = np.ones(n, np.float32)
        hb[4] = 0.0
        old2new = None
        rnd = 2
        while old2new is None:
            params, _, old2new = t.observe_heartbeats(hb, params)
            if old2new is None:
                params, _ = t.step(params, batches(rnd), 0.05)
                before = np.asarray(params["w"])
                rnd += 1
        survivors = np.asarray(
            [i for i in range(n) if np.asarray(old2new)[i] >= 0])
        np.testing.assert_array_equal(np.asarray(params["w"]),
                                      before[survivors])
        assert t.repairs[-1]["spliced"] is True
        params, _ = t.step(params, batches(rnd, t.overlay.n), 0.05)
        assert t.n_traces == 2  # exactly one re-jit, from the repair

    def test_blocked_masking_repair_never_rejits(self):
        """Blocked layout, survivor count not divisible by block: the dead
        client is permanently masked instead of spliced — repairs records
        spliced=False and the executable never retraces."""
        n = 12
        t = _make_trainer(n, failure_rounds=2,
                          engine=engine_lib.GossipEngineConfig(
                              substrate="blocked", block=n))
        params, batches = _quad_setup(n)
        hb = np.ones(n, np.float32)
        hb[3] = 0.0
        for rnd in range(4):
            params, _, o2n = t.observe_heartbeats(hb, params)
            assert o2n is None  # masking is not a membership change
            params, _ = t.step(params, batches(rnd), 0.05)
        assert t.repairs and t.repairs[-1]["spliced"] is False
        assert t.repairs[-1]["masked"] == [3]
        assert t.overlay.n == n and t.n_traces == 1

    def test_blocked_validation(self):
        with pytest.raises(ValueError, match="divisor"):
            _make_trainer(12, engine=engine_lib.GossipEngineConfig(
                substrate="blocked", block=5))
        with pytest.raises(ValueError, match="devices"):
            # 12 devices on a 1-CPU host
            _make_trainer(12, engine=engine_lib.GossipEngineConfig(
                substrate="blocked", block=1))


# -------------------------------------------------- multi-device (slow)
class TestBlockedMultiDevice:
    """Real cross-device blocked gossip on fake-device meshes (subprocess:
    the device count must be pinned before jax initializes)."""

    @pytest.mark.slow
    def test_two_device_parity_and_collective_count(self):
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import Mesh, PartitionSpec as P
            from repro.core import engine as engine_lib, gossip, topology
            from repro.launch.mesh import shard_map

            n, b = 8, 4
            ov = topology.expander_overlay(n, 4, seed=0)
            spec = gossip.make_gossip_spec(ov)
            bs = gossip.make_blocked_spec(spec, b)
            r = np.random.default_rng(0)
            tree = {"a": jnp.asarray(r.standard_normal((n, 6, 5)), jnp.float32),
                    "w": jnp.asarray(r.standard_normal((n, 11)), jnp.float32)}
            alive = jnp.asarray(
                np.array([1, 1, 0, 1, 1, 1, 1, 0], np.float32))
            gates = jnp.asarray(np.array([1, 0, 1, 1], np.float32))
            mesh = Mesh(np.asarray(jax.devices()), ("clients",))

            def island(executor):
                def body(t, a, g):
                    return executor(t, alive=a, gates=g)
                return jax.jit(shard_map(
                    body, mesh, in_specs=(P("clients"), P(), P()),
                    out_specs=P("clients")))

            for codec, exact in (("f32", True), ("int8", False)):
                stacked = engine_lib.build_gossip_executor(
                    engine_lib.GossipEngineConfig(substrate="stacked",
                                                  codec=codec), spec)
                blocked = engine_lib.build_gossip_executor(
                    engine_lib.GossipEngineConfig(substrate="blocked",
                                                  codec=codec, block=b),
                    spec, axis_names="clients")
                fn = island(blocked)
                hlo = fn.lower(tree, alive, gates).as_text()
                n_perm = hlo.count("collective_permute")
                # cross-device edge count in HLO == the schedule partition:
                # on 2 devices, one swap per cross-block schedule
                assert n_perm == bs.n_transfers == bs.cross_schedules, (
                    codec, n_perm, bs.n_transfers)
                ref = stacked(tree, alive=alive, gates=gates)
                got = fn(tree, alive, gates)
                for k in tree:
                    a_ref, a_got = np.asarray(ref[k]), np.asarray(got[k])
                    if exact:
                        np.testing.assert_array_equal(a_got, a_ref)
                    else:  # int8: same codec path, tiny tolerance
                        np.testing.assert_allclose(a_got, a_ref,
                                                   rtol=1e-5, atol=1e-5)
            print("BLOCKED_PARITY_OK")
        """)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, cwd=".")
        assert "BLOCKED_PARITY_OK" in out.stdout, out.stdout + out.stderr

    @pytest.mark.slow
    def test_blocked_trainer_splice_on_four_devices(self):
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from repro.core import dfedavg, engine as engine_lib, topology
            from repro.launch.elastic import ElasticTrainer
            from repro.overlay import plan as plan_lib

            n, b = 16, 4

            def loss_fn(p, batch):
                pred = batch["x"] @ p["w"]
                return jnp.mean((pred - batch["y"]) ** 2), {}

            t = ElasticTrainer(
                overlay=topology.expander_overlay(n, 4, seed=0),
                loss_fn=loss_fn,
                dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.05,
                                            momentum=0.9),
                active_plan=plan_lib.make_active_set("shards", n_shards=2),
                engine=engine_lib.GossipEngineConfig(substrate="blocked",
                                                     block=b),
                failure_rounds=2)
            r = np.random.default_rng(0)
            params = {"w": jnp.asarray(r.standard_normal((n, 5, 3)),
                                       jnp.float32)}

            def batches(rnd, m):
                rr = np.random.default_rng(1000 + rnd)
                return {"x": jnp.asarray(rr.standard_normal((m, 8, 5)),
                                         jnp.float32),
                        "y": jnp.asarray(rr.standard_normal((m, 8, 3)),
                                         jnp.float32)}

            for rnd in range(2):
                params, _, _ = t.observe_heartbeats(np.ones(n, np.float32),
                                                    params)
                params, _ = t.step(params, batches(rnd, n), 0.05)
            assert t.n_traces == 1

            # kill 4 clients: survivors 12 = 3 blocks -> splice to 3 devices
            hb = np.ones(n, np.float32)
            hb[[1, 6, 9, 14]] = 0.0
            before = old2new = None
            rnd = 2
            while old2new is None:
                before = np.asarray(params["w"])
                params, _, old2new = t.observe_heartbeats(hb, params)
                if old2new is None:
                    params, _ = t.step(params, batches(rnd, n), 0.05)
                    rnd += 1
            survivors = np.asarray([i for i in range(n)
                                    if np.asarray(old2new)[i] >= 0])
            np.testing.assert_array_equal(np.asarray(params["w"]),
                                          before[survivors])
            assert t.repairs[-1]["spliced"] is True
            assert t.overlay.n == 12
            params, _ = t.step(params, batches(rnd, 12), 0.05)
            assert t.n_traces == 2  # exactly one re-jit for the repair
            print("BLOCKED_SPLICE_OK")
        """)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, cwd=".")
        assert "BLOCKED_SPLICE_OK" in out.stdout, out.stdout + out.stderr
