"""GossipEngine (codec x timing x substrate) tests.

The tentpole claims under test:

* every legacy ``gossip_impl`` string parses to exactly one engine cell and
  every legacy executor entry point resolves through
  ``engine.build_gossip_executor`` (no per-variant mixing bodies left);
* the free composition — pipelined + quantized (``delay=1 x int8``) — is
  correct against a ``mix_dense_delayed`` + quantize oracle (incl. alive
  masks and round-plan gates), carries its snapshot in the int8 wire
  format through splice repair, retraces nothing under churn + active
  plans, and ships exactly d int8 collectives per round in lowered HLO.
"""
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine, gossip, packing, topology


def _tree(n, seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.standard_normal((n, 6, 5)), jnp.float32),
            "b": jnp.asarray(r.standard_normal((n, 11)), jnp.float32)}


def _quantize_roundtrip_stacked(tree, codec_name):
    """What the int8 wire does to a snapshot: per-client pack -> quantize ->
    fold -> split -> dequantize -> unpack (the delayed-quant oracle input)."""
    codec = engine.get_codec(codec_name)
    ps = gossip._stacked_pack_spec(tree)
    bufs = jax.vmap(lambda t: packing.pack_tree(t, ps))(tree)
    deq = tuple(
        jax.vmap(lambda x, b=b: codec.decode(
            codec.encode(x, n_blocks=ps.buffer_blocks(b),
                         block_rows=ps.block_rows, impl="auto"),
            x.dtype, n_blocks=ps.buffer_blocks(b),
            block_rows=ps.block_rows))(buf)
        for b, buf in enumerate(bufs))
    return jax.vmap(lambda bs: packing.unpack_tree(bs, ps))(deq)


class TestEngineConfig:
    def test_legacy_impl_alias_table(self):
        """Every legacy gossip_impl string parses to exactly one engine
        cell (the documented alias table)."""
        expect = {
            "dense": ("dense", "f32"),
            "ppermute": ("per_leaf", "f32"),
            "ppermute_quant": ("per_leaf", "int8"),
            "ppermute_packed": ("shard_map", "f32"),
            "ppermute_packed_quant": ("shard_map", "int8_block"),
            "ppermute_packed_async": ("shard_map", "f32"),
        }
        for impl, (substrate, codec) in expect.items():
            cfg = engine.parse_gossip_impl(impl)
            assert (cfg.substrate, cfg.codec, cfg.delay) == (substrate,
                                                             codec, 0)
        # async + delay=1 is the only delayed alias; codec override is how
        # pipelined+quantized is spelled
        cfg = engine.parse_gossip_impl("ppermute_packed_async", 1,
                                       "int8_block")
        assert (cfg.substrate, cfg.codec, cfg.delay) == ("shard_map",
                                                         "int8_block", 1)
        # delay=0 async == ppermute_packed: the SAME hashable config (the
        # textual-HLO-identity anchor is this equality)
        assert (engine.parse_gossip_impl("ppermute_packed_async", 0)
                == engine.parse_gossip_impl("ppermute_packed", 0))

    def test_invalid_cells_rejected(self):
        with pytest.raises(ValueError):
            engine.parse_gossip_impl("nope")
        with pytest.raises(ValueError):
            engine.parse_gossip_impl("ppermute_packed", 1)  # delay needs async
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="per_leaf", delay=1)
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="per_leaf",
                                      codec="int8_block")
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="dense", codec="int8")
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(codec="int7")
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="mesh")

    def test_shard_map_substrate_needs_axis_names(self):
        spec = gossip.make_gossip_spec(topology.ring_overlay(4))
        with pytest.raises(ValueError):
            engine.build_gossip_executor(
                engine.GossipEngineConfig(substrate="shard_map"), spec)

    def test_delayed_executor_requires_state(self):
        spec = gossip.make_gossip_spec(topology.ring_overlay(4))
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", delay=1), spec)
        with pytest.raises(ValueError):
            ex(_tree(4))


class TestLegacyEntryPointsResolveThroughEngine:
    """The seven pre-engine executors are aliases of engine cells: stacked
    cells bitwise, and the wrappers carry no mixing bodies of their own."""

    def test_stacked_sync_is_engine_cell(self):
        ov = topology.expander_overlay(10, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(10, seed=5)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", codec="f32"),
            spec)
        got = gossip.mix_packed_stacked(x, spec)
        ref = ex(x)
        for k in x:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]))

    def test_stacked_delayed_is_engine_cell(self):
        ov = topology.expander_overlay(10, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        fresh, prev = _tree(10, seed=5), _tree(10, seed=6)
        snap = gossip.pack_state_stacked(prev)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", codec="f32",
                                      delay=1), spec)
        got, gsnap = gossip.mix_packed_stacked_delayed(fresh, snap, spec)
        ref, rsnap = ex(fresh, state=snap)
        for k in fresh:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]))
        for a, b in zip(gsnap, rsnap):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_init_state_matches_pack_state_stacked_for_f32(self):
        ov = topology.expander_overlay(8, 4, seed=1)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(8, seed=7)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", delay=1), spec)
        for a, b in zip(ex.init_state(x), gossip.pack_state_stacked(x)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_no_copy_paste_mixing_bodies_left_in_gossip(self):
        """Source-level guard on the refactor's acceptance criterion: the
        seven legacy entry points in core/gossip.py contain no ppermute /
        stack / einsum mixing bodies — they delegate to the engine."""
        import ast
        import inspect
        import textwrap as tw

        for fn in (gossip.ppermute_mix, gossip.ppermute_mix_quantized,
                   gossip.ppermute_mix_packed,
                   gossip.ppermute_mix_packed_quantized,
                   gossip.ppermute_mix_packed_delayed,
                   gossip.mix_packed_stacked,
                   gossip.mix_packed_stacked_delayed):
            fndef = ast.parse(tw.dedent(inspect.getsource(fn))).body[0]
            if (fndef.body and isinstance(fndef.body[0], ast.Expr)
                    and isinstance(fndef.body[0].value, ast.Constant)):
                fndef.body = fndef.body[1:]  # drop the docstring
            src = ast.unparse(fndef)
            assert "build_gossip_executor" in src, fn.__name__
            for marker in ("lax.ppermute", "jnp.stack", "jnp.einsum",
                           "quantize_packed", "dequant_accumulate"):
                assert marker not in src, (fn.__name__, marker)


class TestStackedQuantCells:
    """int8 codecs on the stacked substrate (the elastic/simulator path)."""

    @pytest.mark.parametrize("codec", ["int8", "int8_block"])
    def test_sync_quant_within_int8_tolerance(self, codec):
        ov = topology.expander_overlay(10, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(10, seed=5)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", codec=codec),
            spec)
        got = ex(x)
        ref = gossip.mix_dense(x, ov.mixing_matrix())
        amax = max(float(jnp.max(jnp.abs(v))) for v in jax.tree.leaves(x))
        bound = 2 * spec.degree * spec.edge_weight * amax / 127.0 + 1e-6
        for k in x:
            err = float(np.max(np.abs(np.asarray(got[k])
                                      - np.asarray(ref[k]))))
            assert err <= bound, (k, err, bound)

    @pytest.mark.parametrize("codec", ["int8", "int8_block"])
    def test_delayed_quant_matches_dense_delayed_oracle(self, codec):
        """THE free-composition parity: delayed x int8 == mix_dense_delayed
        on the quantize-roundtripped snapshot (the wire is the only lossy
        element, and it only touches the delayed neighbor payloads)."""
        ov = topology.expander_overlay(10, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        fresh, prev = _tree(10, seed=5), _tree(10, seed=6)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", codec=codec,
                                      delay=1), spec)
        state = ex.init_state(prev)
        assert all(str(s.dtype) == "int8" for s in state)
        got, new_state = ex(fresh, state=state)
        prev_deq = _quantize_roundtrip_stacked(prev, codec)
        ref = gossip.mix_dense_delayed(fresh, prev_deq, spec)
        for k in fresh:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(ref[k]),
                                       rtol=2e-5, atol=2e-5)
        # the emitted state is the encoded fresh tree (next round's wire)
        for a, b in zip(new_state, ex.init_state(fresh)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_delayed_quant_composes_with_alive_and_gates(self):
        ov = topology.expander_overlay(12, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        fresh, prev = _tree(12, seed=7), _tree(12, seed=8)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      codec="int8_block", delay=1), spec)
        state = ex.init_state(prev)
        prev_deq = _quantize_roundtrip_stacked(prev, "int8_block")
        r = np.random.default_rng(0)
        for t in range(3):
            alive = (r.random(12) > 0.3).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1
            gates = np.zeros(spec.degree, np.float32)
            gates[t % spec.degree] = 1.0  # one-peer round
            got, _ = ex(fresh, state=state, alive=jnp.asarray(alive),
                        gates=jnp.asarray(gates))
            ref = gossip.mix_dense_delayed(fresh, prev_deq, spec,
                                           jnp.asarray(gates),
                                           jnp.asarray(alive))
            for k in fresh:
                np.testing.assert_allclose(np.asarray(got[k]),
                                           np.asarray(ref[k]),
                                           rtol=2e-5, atol=2e-5)

    def test_blockwise_beats_per_buffer_on_heterogeneous_tree(self):
        """The int8_block codec's reason to exist, at engine level: a tiny-
        magnitude leaf mixed next to a large one keeps its precision."""
        ov = topology.expander_overlay(8, 4, seed=1)
        spec = gossip.make_gossip_spec(ov)
        r = np.random.default_rng(3)
        # "big" fills the first two (256, 128) tiles exactly, so "small"
        # (~1e-3 magnitudes, a norm-gain run) owns its own tile and its
        # block scale cannot inherit big's amax
        x = {"big": jnp.asarray(r.standard_normal((8, 512, 128)),
                                jnp.float32),
             "small": jnp.asarray(r.standard_normal((8, 256, 128)) * 1e-3,
                                  jnp.float32)}
        ref = gossip.mix_dense(x, ov.mixing_matrix())
        errs = {}
        for codec in ("int8", "int8_block"):
            ex = engine.build_gossip_executor(
                engine.GossipEngineConfig(substrate="stacked", codec=codec),
                spec)
            got = ex(x)
            errs[codec] = float(np.max(np.abs(np.asarray(got["small"])
                                              - np.asarray(ref["small"]))))
        assert errs["int8_block"] < 1e-2 * errs["int8"], errs


class TestPipelinedQuantElastic:
    """The composition on the elastic runtime: zero retraces under churn +
    active plans, and the int8 snapshot follows survivors through repair."""

    def _trainer(self, n, **kw):
        from repro.core import dfedavg
        from repro.launch.elastic import ElasticTrainer

        def quad_loss(params, batch):
            return jnp.mean(jnp.square(params["w"] - batch["target"])), {}

        return ElasticTrainer(
            overlay=topology.expander_overlay(n, 4, seed=0),
            loss_fn=quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.0),
            **kw)

    @staticmethod
    def _batches(targets, k):
        return {"target": jnp.broadcast_to(
            targets[:, None], (targets.shape[0], k, targets.shape[1]))}

    def test_pipelined_quant_zero_retrace_under_churn_and_plan(self):
        from repro.overlay.plan import OnePeerPlan

        n, dim = 10, 3
        trainer = self._trainer(n, straggler_rounds=1, failure_rounds=99,
                                engine=engine.GossipEngineConfig(
                                    substrate="stacked", codec="int8_block",
                                    delay=1),
                                plan=OnePeerPlan())
        params = {"w": jnp.ones((n, dim))}
        targets = jnp.zeros((n, dim))
        rng = np.random.default_rng(0)
        for rnd in range(8):
            alive = (rng.random(n) > 0.3).astype(np.float32)
            if rnd == 3:
                alive[:] = 1.0
            params, _, old2new = trainer.observe_heartbeats(alive, params)
            assert old2new is None
            params, _ = trainer.step(params, self._batches(targets, 1), 0.2)
        assert trainer.n_traces == 1, trainer.n_traces
        assert all(str(b.dtype) == "int8" for b in trainer._inflight)

    def test_int8_snapshot_survives_repair_remap(self):
        """repair_and_remap compacts the int8 wire snapshot by the same
        old2new row permutation as the params (byte-exact rows)."""
        n, dim = 12, 4
        r = np.random.default_rng(1)
        targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
        trainer = self._trainer(n, straggler_rounds=1, failure_rounds=2,
                                engine=engine.GossipEngineConfig(
                                    substrate="stacked", codec="int8_block",
                                    delay=1))
        params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
        params, _ = trainer.step(params, self._batches(targets, 1), 0.1)
        alive = np.ones(n)
        alive[5] = 0
        params, _, old2new = trainer.observe_heartbeats(alive, params)
        assert old2new is None                    # straggler, not dead yet
        params, _ = trainer.step(params, self._batches(targets, 1), 0.1)
        pre = [np.asarray(b) for b in trainer._inflight]
        params, _, old2new = trainer.observe_heartbeats(alive, params)
        assert old2new is not None and old2new[5] == -1
        survivors = np.arange(n) != 5
        for b_pre, b_post in zip(pre, trainer._inflight):
            assert str(np.asarray(b_post).dtype) == "int8"
            np.testing.assert_array_equal(np.asarray(b_post),
                                          b_pre[survivors])
        surv_targets = jnp.concatenate([targets[:5], targets[6:]])
        params, _ = trainer.step(params, self._batches(surv_targets, 1), 0.1)
        assert params["w"].shape[0] == n - 1
        assert bool(jnp.isfinite(params["w"]).all())
        assert trainer.n_traces == 2              # one re-jit per membership

    def test_pipelined_quant_tracks_f32_pipeline(self):
        """Convergence sanity: delayed int8 follows delayed f32 to the same
        consensus neighborhood (the wire error is bounded by the scales)."""
        n, dim = 10, 16
        r = np.random.default_rng(2)
        targets = jnp.zeros((n, dim))
        finals = {}
        for codec in ("f32", "int8_block"):
            trainer = self._trainer(n, straggler_rounds=1,
                                    failure_rounds=99,
                                    engine=engine.GossipEngineConfig(
                                        substrate="stacked", codec=codec,
                                        delay=1))
            params = {"w": jnp.asarray(r.standard_normal((n, dim)),
                                       jnp.float32)}
            for _ in range(12):
                params, _, _ = trainer.observe_heartbeats(np.ones(n), params)
                params, _ = trainer.step(params, self._batches(targets, 2),
                                         0.3)
            finals[codec] = float(jnp.mean(jnp.square(params["w"])))
        assert finals["int8_block"] <= 4 * finals["f32"] + 1e-4, finals


class TestShardMapPipelinedQuant:
    """The production composition under shard_map on fake devices."""

    def _run(self, code):
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, cwd=".")
        assert "OK" in out.stdout, out.stdout + out.stderr

    def test_delayed_quant_matches_dense_delayed_oracle(self):
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import engine, gossip, packing, topology
            from repro.launch.mesh import shard_map

            mesh = jax.make_mesh((8,), ("client",))
            ov = topology.expander_overlay(8, 4, seed=0)
            spec = gossip.make_gossip_spec(ov)
            r = np.random.default_rng(0)
            x = {"w": jnp.asarray(r.standard_normal((8, 6, 5)), jnp.float32),
                 "b": jnp.asarray(r.standard_normal((8, 11)), jnp.float32)}
            prev = {"w": jnp.asarray(r.standard_normal((8, 6, 5)), jnp.float32),
                    "b": jnp.asarray(r.standard_normal((8, 11)), jnp.float32)}
            locals_ = {"w": jax.ShapeDtypeStruct((6, 5), jnp.float32),
                       "b": jax.ShapeDtypeStruct((11,), jnp.float32)}
            pack_spec = packing.make_pack_spec(locals_)
            ex = engine.build_gossip_executor(
                engine.GossipEngineConfig(substrate="shard_map",
                                          codec="int8_block", delay=1),
                spec, axis_names="client", pack_spec=pack_spec)
            specs = jax.tree.map(lambda _: P("client"), x)
            sspecs = tuple(P("client", None, None)
                           for _ in ex.state_structs())

            def init_body(t):
                local = jax.tree.map(lambda a: a[0], t)
                return tuple(b[None] for b in ex.init_state(local))

            def body(t, s, a, g):
                local = jax.tree.map(lambda v: v[0], t)
                mixed, new_s = ex(local, state=tuple(b[0] for b in s),
                                  alive=a, gates=g)
                return (jax.tree.map(lambda v: v[None], mixed),
                        tuple(b[None] for b in new_s))

            put = lambda t: jax.device_put(t, jax.tree.map(
                lambda _: NamedSharding(mesh, P("client")), t))
            snap = jax.jit(shard_map(init_body, mesh, in_specs=(specs,),
                                     out_specs=sspecs))(put(prev))
            assert all(str(b.dtype) == "int8" for b in snap)
            alive = jnp.asarray([1., 1., 1., 1., 1., 1., 0., 1.], jnp.float32)
            gates = jnp.asarray([1., 0., 1., 1.], jnp.float32)
            fn = jax.jit(shard_map(body, mesh,
                                   in_specs=(specs, sspecs, P(), P()),
                                   out_specs=(specs, sspecs)))
            got, new_state = fn(put(x), snap, alive, gates)

            # oracle: mix_dense_delayed on the quantize-roundtripped snapshot
            codec = ex.codec
            ps = gossip._stacked_pack_spec(prev)
            bufs = jax.vmap(lambda t: packing.pack_tree(t, ps))(prev)
            deq = tuple(jax.vmap(lambda z, b=b: codec.decode(
                codec.encode(z, n_blocks=ps.buffer_blocks(b),
                             block_rows=ps.block_rows, impl="auto"),
                z.dtype, n_blocks=ps.buffer_blocks(b),
                block_rows=ps.block_rows))(buf)
                for b, buf in enumerate(bufs))
            prev_deq = jax.vmap(lambda bs: packing.unpack_tree(bs, ps))(deq)
            ref = gossip.mix_dense_delayed(x, prev_deq, spec, gates, alive)
            for k in x:
                np.testing.assert_allclose(np.asarray(got[k]),
                                           np.asarray(ref[k]),
                                           rtol=2e-5, atol=2e-5)
            print("SHARD_MAP_DELAYED_QUANT_OK")
        """)


class TestProductionPipelinedQuantStep:
    @pytest.mark.slow
    def test_async_quant_step_ships_d_int8_collectives(self):
        """Acceptance, in lowered HLO: gossip_impl='ppermute_packed_async' +
        gossip_delay=1 + gossip_codec='int8_block' ships exactly d
        collective-permutes per round and every one of them carries the int8
        wire buffer; the in-flight donated state is the int8 wire; and the
        sync f32 async config still lowers textually identical to
        ppermute_packed (no drift from the codec plumbing)."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import sys; sys.path.insert(0, "src")
            import jax
            from repro.configs import registry
            from repro.configs.base import ShapeConfig, ParallelConfig, DFLConfig
            from repro.launch import steps
            from repro.models import params as P

            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = registry.reduced("qwen2.5-3b")
            shape = ShapeConfig("t", 64, 8, "train")
            texts = {}
            for gi, delay, codec in (("ppermute_packed", 0, "auto"),
                                     ("ppermute_packed_async", 0, "auto"),
                                     ("ppermute_packed_async", 1, "int8"),
                                     ("ppermute_packed_async", 1, "int8_block")):
                par = ParallelConfig(clients_per_pod=4, local_steps=2,
                                     grad_accum=2, gossip_impl=gi,
                                     gossip_delay=delay, gossip_codec=codec)
                setup = steps.build_train_step(cfg, shape, mesh, par,
                                               DFLConfig(degree=2))
                args = [P.shape_structs(setup.param_struct),
                        setup.input_specs["batch"], setup.input_specs["lr"],
                        setup.input_specs["alive"],
                        setup.input_specs["gates"]]
                if "inflight" in setup.input_specs:
                    args.append(setup.input_specs["inflight"])
                    assert all(str(s.dtype) == "int8"
                               for s in setup.input_specs["inflight"])
                texts[(gi, delay, codec)] = setup.step_fn.lower(
                    *args).as_text()
            d = setup.gossip_spec.degree
            for key, text in texts.items():
                perms = [l for l in text.splitlines()
                         if "collective_permute" in l]
                assert len(perms) == d, (key, len(perms), d)
                if key[2] in ("int8", "int8_block"):
                    # every shipped buffer is the int8 wire
                    assert all("xi8>" in l for l in perms), key
            assert (texts[("ppermute_packed_async", 0, "auto")]
                    == texts[("ppermute_packed", 0, "auto")]), \\
                "async delay=0 must still lower identically to ppermute_packed"
            print("ASYNC_QUANT_HLO_OK d=", d)
        """)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, cwd=".")
        assert "ASYNC_QUANT_HLO_OK" in out.stdout, out.stdout + out.stderr


class TestByzantineScreens:
    """The fourth engine layer: screen ("none" | "norm_clip" |
    "trimmed_mean") composes with codec x timing x substrate through the
    config alone — no new executors, the screen="none" paths byte-identical
    to the pre-screen engine."""

    def test_screen_config_validation(self):
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(screen="median")
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="dense", screen="norm_clip")
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="per_leaf",
                                      screen="trimmed_mean")
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(screen="norm_clip", clip_tau=0.0)
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(screen="trimmed_mean", trim_f=-1)
        cfg = engine.parse_gossip_impl("ppermute_packed", screen="norm_clip",
                                       clip_tau=2.5)
        assert (cfg.screen, cfg.clip_tau) == ("norm_clip", 2.5)

    def test_telemetry_needs_packed_substrate(self):
        from repro.telemetry import TelemetryConfig
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="dense",
                                      telemetry=TelemetryConfig())
        with pytest.raises(ValueError):
            engine.GossipEngineConfig(substrate="per_leaf",
                                      telemetry=TelemetryConfig())
        # the metrics-only blocked cell is legal (TELEMETRY_SUBSTRATES)
        cfg = engine.GossipEngineConfig(substrate="blocked", block=2,
                                        telemetry=TelemetryConfig())
        assert cfg.telemetry is not None
        cfg = engine.parse_gossip_impl("ppermute_packed",
                                       telemetry=TelemetryConfig())
        assert cfg.telemetry == TelemetryConfig()

    def test_norm_clip_identity_at_large_tau_is_bitwise(self):
        """When no sender exceeds tau x the receiver's own norm, every clip
        factor is 1.0 and the screened stacked f32 round is BITWISE equal
        to the unscreened one (incl. alive + gates)."""
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        ex0 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked"), spec)
        exc = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      screen="norm_clip", clip_tau=1e6), spec)
        for kw in ({},
                   {"alive": jnp.asarray(np.r_[np.ones(7), 0, 1, 1],
                                         jnp.float32),
                    "gates": jnp.asarray([1., 0., 1., 1.], jnp.float32)}):
            a0, ac = ex0(x, **kw), exc(x, **kw)
            for k in x:
                np.testing.assert_array_equal(np.asarray(a0[k]),
                                              np.asarray(ac[k]))

    def test_norm_clip_screens_attacker_and_counts_clips(self):
        """A huge sender is rescaled to tau x the receiver's own norm
        (whole-model norms, all pack buffers) and the per-sender clip
        telemetry counts exactly its live receivers."""
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        xa = jax.tree.map(lambda v: v.at[3].mul(1e4), x)
        ex0 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked"), spec)
        from repro.telemetry import metrics as telemetry_metrics
        exc = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      screen="norm_clip", clip_tau=3.0,
                                      telemetry=telemetry_metrics.clip_only()),
            spec)
        got, stats = exc(xa)
        plain = ex0(xa)
        # the attacker's OWN row keeps its huge self-term by design —
        # screens defend receivers, not the attacker
        others = np.arange(10) != 3
        mx_scr = max(float(jnp.max(jnp.abs(got[k][others]))) for k in x)
        mx_pl = max(float(jnp.max(jnp.abs(plain[k][others]))) for k in x)
        assert mx_scr < mx_pl / 50, (mx_scr, mx_pl)
        counts = np.asarray(stats["clipped"])
        in_deg = sum((np.asarray(rf) == 3) & np.asarray(m).astype(bool)
                     for rf, m in zip(spec.recv_from, spec.live_masks))
        assert counts[3] == int(np.sum(in_deg)), (counts, np.sum(in_deg))
        assert counts.sum() == counts[3], counts

    def test_trimmed_f0_is_renormalized_mean(self):
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        ex0 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked"), spec)
        ext = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      screen="trimmed_mean", trim_f=0), spec)
        gt, pl = ext(x), ex0(x)
        for k in x:
            np.testing.assert_allclose(np.asarray(gt[k]), np.asarray(pl[k]),
                                       rtol=3e-6, atol=3e-6)

    def test_trimmed_matches_ref_oracle_with_alive_and_gates(self):
        """Engine trimmed cell == vmapped ref.trimmed_mix over the packed
        stack with the raw/contrib weight tables (dead senders and gated
        schedules excluded from the order statistics)."""
        from repro.kernels.gossip_mix import ref as mix_ref
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        alive = jnp.asarray(np.r_[np.ones(7), 0, 1, 1], jnp.float32)
        gates = jnp.asarray([1., 0., 1., 1.], jnp.float32)
        ext = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      screen="trimmed_mean", trim_f=1), spec)
        gt = ext(x, alive=alive, gates=gates)
        ps = gossip._stacked_pack_spec(x)
        bufs = jax.vmap(lambda t: packing.pack_tree(t, ps))(x)
        raw, contrib = gossip.raw_contrib_tables(spec, alive, gates)
        u = jnp.maximum(raw, 0.0) * contrib
        lv = (contrib > 0.0).astype(jnp.float32)
        outs = []
        for buf in bufs:
            stack = jnp.stack([buf] + [jnp.take(buf, jnp.asarray(rf), axis=0)
                                       for rf in spec.recv_from], axis=1)
            outs.append(jax.vmap(
                lambda st, uu, ll: mix_ref.trimmed_mix(st, uu, ll, 1)
            )(stack, u, lv))
        ref = jax.vmap(lambda bs: packing.unpack_tree(bs, ps))(tuple(outs))
        for k in x:
            np.testing.assert_allclose(np.asarray(gt[k]), np.asarray(ref[k]),
                                       rtol=1e-6, atol=1e-6)

    def test_trimmed_neutralizes_sign_flip_where_mean_is_poisoned(self):
        """Deviation-from-clean-round on receivers whose attacker
        in-multiplicity is <= trim: the trimmed cell stays near the clean
        round while the plain mean is dragged by the attacker. (A receiver
        fed the same attacker on two schedules needs trim >= 2 — the
        order-statistics contract, asserted via the multiplicity filter.)"""
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        xa = jax.tree.map(lambda v: v.at[3].mul(-50.0), x)
        ex0 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked"), spec)
        ext = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      screen="trimmed_mean", trim_f=1), spec)
        mult = sum(((np.asarray(rf) == 3) & np.asarray(m).astype(bool))
                   .astype(int)
                   for rf, m in zip(spec.recv_from, spec.live_masks))
        recv = np.where(mult == 1)[0]
        err_t = max(float(jnp.max(jnp.abs(ext(xa)[k][recv] - ext(x)[k][recv])))
                    for k in x)
        err_p = max(float(jnp.max(jnp.abs(ex0(xa)[k][recv] - ex0(x)[k][recv])))
                    for k in x)
        assert err_t < err_p / 10, (err_t, err_p)

    @pytest.mark.parametrize("codec", ["int8", "int8_block"])
    def test_int8_trimmed_decodes_within_quant_tolerance(self, codec):
        """The dequant-side trimmed kernel (int8 wire decoded inside the
        fused trim pass) tracks the f32 trimmed cell within the wire's
        quantization error."""
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        alive = jnp.asarray(np.r_[np.ones(7), 0, 1, 1], jnp.float32)
        gates = jnp.asarray([1., 0., 1., 1.], jnp.float32)
        exf = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      screen="trimmed_mean", trim_f=1), spec)
        exq = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", codec=codec,
                                      screen="trimmed_mean", trim_f=1), spec)
        gf = exf(x, alive=alive, gates=gates)
        gq = exq(x, alive=alive, gates=gates)
        for k in x:
            np.testing.assert_allclose(np.asarray(gq[k]), np.asarray(gf[k]),
                                       rtol=5e-2, atol=5e-2)

    def test_screens_compose_with_delay(self):
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        alive = jnp.asarray(np.r_[np.ones(7), 0, 1, 1], jnp.float32)
        for codec in ("f32", "int8_block"):
            for screen, kw in (("norm_clip", dict(clip_tau=3.0)),
                               ("trimmed_mean", dict(trim_f=1))):
                ex = engine.build_gossip_executor(
                    engine.GossipEngineConfig(substrate="stacked",
                                              codec=codec, delay=1,
                                              screen=screen, **kw), spec)
                st = ex.init_state(_tree(10, seed=6))
                mixed, new_st = ex(x, state=st, alive=alive)
                assert all(bool(jnp.isfinite(v).all())
                           for v in mixed.values()), (codec, screen)


class TestShardMapScreens:
    """Screened cells on the production shard_map substrate, vs their
    stacked twins (whole-model norm_clip needed a two-phase shard_map
    round; trimmed excludes fixed-point deliveries, which arrive as zeros
    on the wire there)."""

    def _run(self, code):
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, cwd=".")
        assert "OK" in out.stdout, out.stdout + out.stderr

    @pytest.mark.slow
    def test_shard_map_screens_match_stacked_twins(self):
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import engine, gossip, packing, topology
            from repro.launch.mesh import shard_map

            mesh = jax.make_mesh((8,), ("client",))
            ov = topology.expander_overlay(8, 4, seed=0)
            spec = gossip.make_gossip_spec(ov)
            r = np.random.default_rng(9)
            x = {"a": jnp.asarray(r.standard_normal((8, 6, 5)), jnp.float32),
                 "b": jnp.asarray(r.standard_normal((8, 11)), jnp.float32)}
            xa = jax.tree.map(lambda v: v.at[2].mul(-30.0), x)  # attacker
            alive = jnp.asarray([1., 1., 1., 0., 1., 1., 1., 1.], jnp.float32)
            gates = jnp.asarray([1., 0., 1., 1.], jnp.float32)
            locals_ = {"a": jax.ShapeDtypeStruct((6, 5), jnp.float32),
                       "b": jax.ShapeDtypeStruct((11,), jnp.float32)}
            pspec = packing.make_pack_spec(locals_)
            specs = jax.tree.map(lambda _: P("client"), x)
            put = lambda t: jax.device_put(t, jax.tree.map(
                lambda _: NamedSharding(mesh, P("client")), t))
            for codec in ("f32", "int8_block"):
                for screen, kw in (("norm_clip", dict(clip_tau=3.0)),
                                   ("trimmed_mean", dict(trim_f=1))):
                    exs = engine.build_gossip_executor(
                        engine.GossipEngineConfig(substrate="shard_map",
                                                  codec=codec, screen=screen,
                                                  **kw),
                        spec, axis_names="client", pack_spec=pspec)
                    exst = engine.build_gossip_executor(
                        engine.GossipEngineConfig(substrate="stacked",
                                                  codec=codec, screen=screen,
                                                  **kw), spec)

                    def body(t, a, g):
                        local = jax.tree.map(lambda v: v[0], t)
                        mixed = exs(local, alive=a, gates=g)
                        return jax.tree.map(lambda v: v[None], mixed)

                    fn = jax.jit(shard_map(body, mesh,
                                           in_specs=(specs, P(), P()),
                                           out_specs=specs))
                    got = fn(put(xa), alive, gates)
                    ref = exst(xa, alive=alive, gates=gates)
                    tol = 1e-6 if codec == "f32" else 5e-2
                    for k in x:
                        np.testing.assert_allclose(
                            np.asarray(got[k]), np.asarray(ref[k]),
                            rtol=tol, atol=tol)
            print("SHARD_MAP_SCREENS_OK")
        """)

    @pytest.mark.slow
    def test_screened_byzantine_step_ships_d_collectives(self):
        """Acceptance, in lowered HLO: every screened cell of the
        production step — with the Byzantine attack operands threaded —
        still ships exactly d collective-permutes per round."""
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import sys; sys.path.insert(0, "src")
            import jax
            from repro.configs import registry
            from repro.configs.base import ShapeConfig, ParallelConfig, DFLConfig
            from repro.launch import steps
            from repro.models import params as P

            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = registry.reduced("qwen2.5-3b")
            shape = ShapeConfig("t", 64, 8, "train")
            for gi, screen in (("ppermute_packed", "norm_clip"),
                               ("ppermute_packed", "trimmed_mean"),
                               ("ppermute_packed_quant", "norm_clip"),
                               ("ppermute_packed_quant", "trimmed_mean")):
                par = ParallelConfig(clients_per_pod=4, local_steps=2,
                                     grad_accum=2, gossip_impl=gi,
                                     gossip_screen=screen, gossip_trim_f=1)
                setup = steps.build_train_step(cfg, shape, mesh, par,
                                               DFLConfig(degree=2,
                                                         byzantine=True))
                assert "attack" in setup.input_specs
                args = [P.shape_structs(setup.param_struct),
                        setup.input_specs["batch"], setup.input_specs["lr"],
                        setup.input_specs["alive"],
                        setup.input_specs["gates"],
                        setup.input_specs["attack"],
                        setup.input_specs["attack_key"]]
                text = setup.step_fn.lower(*args).as_text()
                perms = [l for l in text.splitlines()
                         if "collective_permute" in l]
                d = setup.gossip_spec.degree
                assert len(perms) == d, (gi, screen, len(perms), d)
            print("SCREENED_STEP_HLO_OK")
        """)


class TestChebyshevMultiRound:
    """Chebyshev-accelerated multi-round gossip (sub_rounds = k > 1, the
    second timing axis): config validation, the traced (k,) coefficient
    operand contract, the stacked cell vs the dense ``chebyshev_mix``
    oracle (incl. alive masks + gates + dead-client identity), consensus
    acceleration over plain repetition on the ring, k-fold wire
    accounting, zero retraces under varying coefficients x churn x gates,
    and — in the slow lane — the shard_map twin plus the production-step
    anchors (exactly k*d collective-permutes; sub_rounds=1 lowers
    textually identical to the sync engine)."""

    def test_cheby_config_validation(self):
        with pytest.raises(ValueError, match="sub_rounds"):
            engine.GossipEngineConfig(sub_rounds=0)
        with pytest.raises(ValueError, match="sub_rounds"):
            engine.GossipEngineConfig(sub_rounds=1.5)
        for substrate, kw in (("dense", {}), ("per_leaf", {}),
                              ("blocked", dict(block=4))):
            with pytest.raises(ValueError, match="sub_rounds > 1"):
                engine.GossipEngineConfig(substrate=substrate,
                                          sub_rounds=2, **kw)
        with pytest.raises(ValueError, match="synchronous"):
            engine.GossipEngineConfig(substrate="stacked", delay=1,
                                      sub_rounds=2)
        for screen in ("norm_clip", "trimmed_mean"):
            with pytest.raises(ValueError, match="screen"):
                engine.GossipEngineConfig(substrate="stacked",
                                          screen=screen, sub_rounds=2)
        with pytest.raises(ValueError, match="stateful"):
            engine.GossipEngineConfig(substrate="stacked", codec="topk_ef",
                                      sub_rounds=2)
        # the same cells stay legal at k=1 (the sync engine) and the
        # stateless codecs compose at k>1
        engine.GossipEngineConfig(substrate="stacked", screen="norm_clip")
        engine.GossipEngineConfig(substrate="stacked", codec="topk_ef")
        engine.GossipEngineConfig(substrate="stacked", codec="int8_block",
                                  sub_rounds=3)

    def test_cheby_operand_contract(self):
        spec = gossip.make_gossip_spec(topology.ring_overlay(8))
        x = _tree(8)
        ex2 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", sub_rounds=2),
            spec)
        with pytest.raises(ValueError, match="cheby"):
            ex2(x)  # k > 1 needs the (k,) coefficient operand
        ex1 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked"), spec)
        with pytest.raises(ValueError, match="cheby"):
            ex1(x, cheby=jnp.ones((1,), jnp.float32))  # k = 1 must not
        om = ex2.cheby_coeffs()
        assert om.shape == (2,) and om.dtype == np.float32
        assert om[0] == 1.0  # the first sub-round IS the plain mix

    @pytest.mark.parametrize("k", [2, 3])
    def test_stacked_cheby_matches_dense_oracle(self, k):
        from repro.core import mixing
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=5)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", sub_rounds=k),
            spec)
        om = ex.cheby_coeffs()
        alive = jnp.asarray(np.r_[np.ones(7), 0, 1, 1], jnp.float32)
        gates = jnp.asarray([1., 0., 1., 1.], jnp.float32)
        for kw in ({}, {"alive": alive}, {"alive": alive, "gates": gates}):
            got = ex(x, cheby=jnp.asarray(om), **kw)
            m = np.asarray(gossip.gated_mixing_matrix(
                spec, kw.get("gates"), kw.get("alive")))
            for key in x:
                ref = mixing.chebyshev_mix(np.asarray(x[key]), m, om)
                np.testing.assert_allclose(np.asarray(got[key]), ref,
                                           rtol=2e-5, atol=2e-5)
        # a dead client's identity row survives the whole recurrence
        # bit-for-bit: y == x^(j) makes every x^(j+1) collapse to x^(0)
        got = ex(x, cheby=jnp.asarray(om), alive=alive)
        for key in x:
            np.testing.assert_array_equal(np.asarray(got[key][7]),
                                          np.asarray(x[key][7]))

    def test_cheby_beats_plain_repetition_on_the_ring(self):
        from repro.core import spectral
        spec = gossip.make_gossip_spec(topology.ring_overlay(8))
        x = _tree(8, seed=1)
        ex1 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked"), spec)
        ex2 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", sub_rounds=2),
            spec)
        # theory: 1/T_2(1/lam) < lam^2 whenever 0 < lam < 1
        assert spectral.chebyshev_lambda(spec.lam, 2) < spec.lam ** 2

        def resid(t):
            return sum(float(jnp.sum(jnp.square(
                v - v.mean(axis=0, keepdims=True)))) for v in t.values())

        cheb = ex2(x, cheby=jnp.asarray(ex2.cheby_coeffs()))
        plain = ex1(ex1(x))  # same wire budget: two plain applications
        assert resid(cheb) < resid(plain) < resid(x)

    def test_wire_bytes_multiply_by_sub_rounds(self):
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10)
        pack = packing.make_stacked_pack_spec(
            jax.tree.map(lambda v: v[0], x))
        wires = {}
        for k in (1, 2, 3):
            ex = engine.build_gossip_executor(
                engine.GossipEngineConfig(substrate="shard_map",
                                          sub_rounds=k),
                spec, axis_names="client", pack_spec=pack)
            wires[k] = ex.wire_bytes_per_round()
        assert wires[1] > 0
        assert wires[2] == 2 * wires[1] and wires[3] == 3 * wires[1]

    def test_varying_coefficients_churn_gates_zero_retraces(self):
        from repro.telemetry import TraceCounter
        spec = gossip.make_gossip_spec(
            topology.expander_overlay(10, 4, seed=2))
        x = _tree(10, seed=3)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", sub_rounds=2),
            spec)
        fn = jax.jit(lambda t, a, g, c: ex(t, alive=a, gates=g, cheby=c))
        r = np.random.default_rng(0)
        for t in range(4):
            alive = (r.random(10) > 0.3).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1
            gates = (np.arange(4) != t % 4).astype(np.float32)
            cheby = jnp.asarray([1.0, 1.0 + 0.1 * t], jnp.float32)
            x = fn(x, jnp.asarray(alive), jnp.asarray(gates), cheby)
        assert TraceCounter.cache_size(fn) == 1
        assert all(bool(jnp.isfinite(v).all()) for v in x.values())

    def test_elastic_trainer_sub_rounds_composes_with_telemetry(self):
        from repro.core import dfedavg
        from repro.launch.elastic import ElasticTrainer
        from repro.overlay import plan as plan_lib
        from repro.telemetry import TelemetryConfig
        n = 12
        tr = ElasticTrainer(
            overlay=topology.expander_overlay(n, 4, seed=0),
            loss_fn=lambda p, b: (jnp.mean(jnp.square(p["w"] - b["t"])),
                                  {}),
            dcfg=dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2,
                                        momentum=0.9),
            plan=plan_lib.OnePeerPlan(),
            engine=engine.GossipEngineConfig(
                substrate="stacked", sub_rounds=2,
                telemetry=TelemetryConfig()))
        params = {"w": jnp.asarray(
            np.random.default_rng(1).standard_normal((n, 16)), jnp.float32)}
        r = np.random.default_rng(0)
        for rnd in range(4):
            alive = (r.random(n) > 0.2).astype(np.float32)
            params, _, _ = tr.observe_heartbeats(alive, params)
            params, _ = tr.step(
                params, {"t": jnp.zeros((n, 2, 16), jnp.float32)}, 0.2)
        assert tr.n_traces == 1  # coefficients + churn + gates are data
        # telemetry composes: metrics measure the FIRST sub-round only, so
        # they stay comparable across the sub_rounds axis
        assert set(tr.last_metrics) == {"resid_sqnorm", "in_degree",
                                        "sched_contrib"}
        assert np.isfinite(np.asarray(params["w"])).all()


class TestChebyshevSlowLane:
    def _run(self, code):
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, cwd=".")
        assert "OK" in out.stdout, out.stdout + out.stderr

    @pytest.mark.slow
    def test_shard_map_cheby_matches_oracle_and_ships_kd_permutes(self):
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import engine, gossip, mixing, packing, topology
            from repro.launch.mesh import shard_map

            mesh = jax.make_mesh((8,), ("client",))
            ov = topology.expander_overlay(8, 4, seed=0)
            spec = gossip.make_gossip_spec(ov)
            r = np.random.default_rng(9)
            x = {"a": jnp.asarray(r.standard_normal((8, 6, 5)), jnp.float32),
                 "b": jnp.asarray(r.standard_normal((8, 11)), jnp.float32)}
            alive = jnp.asarray([1., 1., 1., 0., 1., 1., 1., 1.], jnp.float32)
            gates = jnp.asarray([1., 0., 1., 1.], jnp.float32)
            locals_ = {"a": jax.ShapeDtypeStruct((6, 5), jnp.float32),
                       "b": jax.ShapeDtypeStruct((11,), jnp.float32)}
            pspec = packing.make_pack_spec(locals_)
            specs = jax.tree.map(lambda _: P("client"), x)
            put = lambda t: jax.device_put(t, jax.tree.map(
                lambda _: NamedSharding(mesh, P("client")), t))
            for k in (2, 3):
                ex = engine.build_gossip_executor(
                    engine.GossipEngineConfig(substrate="shard_map",
                                              sub_rounds=k),
                    spec, axis_names="client", pack_spec=pspec)
                om = ex.cheby_coeffs()

                def body(t, a, g, c):
                    local = jax.tree.map(lambda v: v[0], t)
                    mixed = ex(local, alive=a, gates=g, cheby=c)
                    return jax.tree.map(lambda v: v[None], mixed)

                fn = jax.jit(shard_map(body, mesh,
                                       in_specs=(specs, P(), P(), P()),
                                       out_specs=specs))
                args = (put(x), alive, gates, jnp.asarray(om))
                got = fn(*args)
                m = np.asarray(gossip.gated_mixing_matrix(spec, gates,
                                                          alive))
                for key in x:
                    ref = mixing.chebyshev_mix(np.asarray(x[key]), m, om)
                    np.testing.assert_allclose(np.asarray(got[key]), ref,
                                               rtol=2e-5, atol=2e-5)
                text = fn.lower(*args).as_text()
                perms = [l for l in text.splitlines()
                         if "collective_permute" in l]
                assert len(perms) == k * spec.degree, (k, len(perms))
            print("SHARD_MAP_CHEBY_OK")
        """)

    @pytest.mark.slow
    def test_production_step_ships_kd_permutes_and_k1_identity(self):
        """Acceptance, in lowered HLO on the production step: sub_rounds=k
        ships exactly k*d collective-permutes, wire accounting multiplies
        by k, the (k,) cheby operand threads as one more donated traced
        input, and sub_rounds=1 lowers TEXTUALLY IDENTICAL to the default
        sync engine (zero-cost axis)."""
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax
            from repro.configs import registry
            from repro.configs.base import ShapeConfig, ParallelConfig, DFLConfig
            from repro.launch import steps
            from repro.models import params as P

            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = registry.reduced("qwen2.5-3b")
            shape = ShapeConfig("t", 64, 8, "train")
            texts, wires = {}, {}
            for k in (1, 2, 3):
                par = ParallelConfig(clients_per_pod=4, local_steps=2,
                                     grad_accum=2,
                                     gossip_impl="ppermute_packed",
                                     gossip_sub_rounds=k)
                setup = steps.build_train_step(cfg, shape, mesh, par,
                                               DFLConfig(degree=2))
                args = [P.shape_structs(setup.param_struct),
                        setup.input_specs["batch"],
                        setup.input_specs["lr"],
                        setup.input_specs["alive"],
                        setup.input_specs["gates"]]
                if k > 1:
                    om = np.asarray(setup.cheby_coeffs)
                    assert om.shape == (k,) and om[0] == 1.0
                    assert setup.input_specs["cheby"].shape == (k,)
                    args.append(setup.input_specs["cheby"])
                else:
                    assert setup.cheby_coeffs is None
                    assert "cheby" not in setup.input_specs
                texts[k] = setup.step_fn.lower(*args).as_text()
                wires[k] = setup.wire_bytes_per_round
                d = setup.gossip_spec.degree
                perms = [l for l in texts[k].splitlines()
                         if "collective_permute" in l]
                assert len(perms) == k * d, (k, len(perms), d)
            assert wires[2] == 2 * wires[1] and wires[3] == 3 * wires[1]
            # the k=1 cell IS the sync engine, byte for byte
            par0 = ParallelConfig(clients_per_pod=4, local_steps=2,
                                  grad_accum=2,
                                  gossip_impl="ppermute_packed")
            setup0 = steps.build_train_step(cfg, shape, mesh, par0,
                                            DFLConfig(degree=2))
            args0 = [P.shape_structs(setup0.param_struct),
                     setup0.input_specs["batch"],
                     setup0.input_specs["lr"],
                     setup0.input_specs["alive"],
                     setup0.input_specs["gates"]]
            assert texts[1] == setup0.step_fn.lower(*args0).as_text()
            print("CHEBY_STEP_HLO_OK")
        """)
