"""Overlay-lab tests: graph-family registry, graph -> overlay conversion,
and time-varying round plans on the packed gossip engine.

Acceptance (ISSUE 3): gated time-varying gossip (one-peer rotation over a
precompiled d-schedule pool) runs with ZERO retraces across rounds and
matches the dense gated-mixing oracle bit-for-bit in f32; `convert.py`
round-trips an arbitrary connected graph into a valid schedule-based
Overlay executable by `ppermute_mix_packed`.
"""
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional dep (requirements-dev.txt): property tests degrade, not error
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.base import DFLConfig
from repro.core import dfedavg, gossip, spectral, topology
from repro.launch.elastic import ElasticTrainer
from repro.launch.steps import build_overlay
from repro.overlay import convert, plan as plan_lib, registry


# ----------------------------------------------------------------- registry
class TestRegistry:
    @pytest.mark.parametrize("family,n,expect_scheds", [
        ("ring", 16, 2),
        ("expander", 16, 4),
        ("complete", 12, 11),
        ("torus", 24, 4),
        ("hypercube", 16, 4),
        ("random_regular", 16, 4),
        ("onepeer_exp", 12, 6),   # shifts +-1, +-2, +-4
        ("onepeer_exp", 16, 7),   # shifts +-1, +-2, +-4, 8 (+8 == -8)
        ("erdos_renyi", 30, None),
    ])
    def test_family_builds_valid_connected(self, family, n, expect_scheds):
        ov, meta = registry.build(family, n, degree=4, seed=0)
        assert ov.n == n
        assert meta["connected"] and meta["spectral_gap"] > 0
        if expect_scheds is not None:
            assert meta["n_schedules"] == expect_scheds
        for s in ov.schedules:  # valid permutation schedules
            assert np.array_equal(np.sort(s), np.arange(n))
        ov.mixing_matrix()      # Chow weights well-defined

    def test_unknown_family_rejected(self):
        with pytest.raises(ValueError, match="unknown overlay family"):
            registry.build("moebius", 16)

    def test_torus_is_wraparound_grid(self):
        ov, meta = registry.build("torus", 24)  # 4 x 6
        adj = ov.simple_adjacency()
        assert (adj.sum(1) == 4).all()
        assert adj[0, 6] == 1 and adj[0, 18] == 1   # row wrap (r=4, c=6)
        assert adj[0, 1] == 1 and adj[0, 5] == 1    # col wrap

    def test_hypercube_needs_power_of_two(self):
        with pytest.raises(ValueError):
            registry.build("hypercube", 12)
        ov, meta = registry.build("hypercube", 32)
        assert meta["n_schedules"] == 5
        assert (ov.simple_adjacency().sum(1) == 5).all()

    def test_dflconfig_selects_registry_families(self):
        """`DFLConfig.topology` reaches every registered family through the
        production `build_overlay` entry point."""
        for family, n in [("torus", 16), ("hypercube", 16),
                          ("random_regular", 16), ("onepeer_exp", 16),
                          ("expander", 16), ("complete", 8)]:
            ov = build_overlay(n, DFLConfig(topology=family, degree=4))
            assert ov is not None and ov.n == n
            assert ov.spectral_report().connected

    def test_meta_ranks_families_by_gap(self):
        """The sweepable claim: metadata orders families the way the paper's
        theory says (complete > hypercube > ring at equal n)."""
        gaps = {f: registry.build(f, 16)[1]["spectral_gap"]
                for f in ("complete", "hypercube", "ring")}
        assert gaps["complete"] > gaps["hypercube"] > gaps["ring"]


# ------------------------------------------------------------------ convert
def _random_connected_adj(n, p, seed):
    rng = np.random.default_rng(seed)
    for _ in range(64):
        u = rng.random((n, n))
        a = np.triu((u < p).astype(np.int64), k=1)
        adj = a + a.T
        if spectral.is_connected(adj):
            return adj
    return None


def _check_conversion(n, p, seed):
    adj = _random_connected_adj(n, p, seed)
    if adj is None:
        return
    maxd = int(adj.sum(1).max())
    ov = convert.overlay_from_adjacency(adj)
    # lossless: the schedule multigraph IS the input graph
    np.testing.assert_array_equal(ov.multigraph_adjacency(), adj)
    # schedule count: Delta + 1 (Vizing) below the Euler-split cutoff; the
    # split path trades a few extra colors for near-linear time above it
    bound = maxd + (1 if maxd <= convert._EULER_CUTOFF else 8)
    assert len(ov.schedules) <= bound, (len(ov.schedules), maxd)
    if maxd > convert._EULER_CUTOFF:
        # pure Misra-Gries (no split) must still meet the Vizing bound
        ov_mg = convert.overlay_from_adjacency(adj, euler_cutoff=maxd)
        np.testing.assert_array_equal(ov_mg.multigraph_adjacency(), adj)
        assert len(ov_mg.schedules) <= maxd + 1, (len(ov_mg.schedules), maxd)
    for s in ov.schedules:
        assert np.array_equal(np.sort(s), np.arange(n))
        assert np.array_equal(np.argsort(s), s)
    # executable: Chow mixing matrix exists and is row-stochastic
    m = ov.mixing_matrix()
    np.testing.assert_allclose(m.sum(1), 1.0, atol=1e-9)


class TestConvert:
    def test_structured_graphs_round_trip(self):
        ring = topology.ring_overlay(12).simple_adjacency().astype(np.int64)
        for adj in (ring, topology.erdos_renyi_adjacency(20, seed=3
                                                         ).astype(np.int64)):
            ov = convert.overlay_from_adjacency(adj)
            np.testing.assert_array_equal(ov.multigraph_adjacency(), adj)

    def test_euler_split_high_degree(self):
        """Complete graphs force the Euler-tour divide path; the split costs
        a few extra colors but stays lossless."""
        for n in (16, 21):
            adj = np.ones((n, n), np.int64) - np.eye(n, dtype=np.int64)
            ov = convert.overlay_from_adjacency(adj)
            np.testing.assert_array_equal(ov.multigraph_adjacency(), adj)
            assert len(ov.schedules) <= (n - 1) + 8  # Delta + O(log Delta)

    def test_euler_split_halves_degrees(self):
        adj = _random_connected_adj(20, 0.5, 0)
        left, right = convert.euler_split(adj)
        np.testing.assert_array_equal(left + right, adj)
        deg = adj.sum(1)
        for half in (left, right):
            assert (np.abs(half.sum(1) - deg / 2.0) <= 1.0).all()

    def test_disconnected_rejected(self):
        adj = np.zeros((6, 6), np.int64)
        adj[0, 1] = adj[1, 0] = 1
        adj[2, 3] = adj[3, 2] = 1
        with pytest.raises(ValueError, match="disconnected"):
            convert.overlay_from_adjacency(adj)

    def test_invalid_adjacency_rejected(self):
        with pytest.raises(ValueError):  # asymmetric
            convert.overlay_from_adjacency(np.triu(np.ones((4, 4)), 1))
        with pytest.raises(ValueError):  # self loops
            convert.overlay_from_adjacency(np.ones((4, 4), np.int64))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(4, 32), p=st.floats(0.15, 0.7),
           seed=st.integers(0, 1000))
    def test_conversion_properties(n, p, seed):
        _check_conversion(n, p, seed)
else:
    @pytest.mark.parametrize("n,p,seed", [
        (6, 0.5, 0), (12, 0.3, 7), (20, 0.2, 42), (32, 0.15, 9),
        (15, 0.6, 3), (9, 0.4, 11),
    ])
    def test_conversion_properties(n, p, seed):
        _check_conversion(n, p, seed)


# ------------------------------------------------------- spectral sanity
def _check_alon_boppana(n, d, seed):
    """Random d-regular matching unions are near-Ramanujan (Friedman): the
    largest nontrivial adjacency eigenvalue sits within half the
    Alon-Boppana-to-trivial gap of the 2 sqrt(d-1) bound."""
    ov = registry.random_regular_overlay(n, d, seed)
    adj = ov.simple_adjacency()
    assert (adj.sum(1) == d).all()
    ev = np.linalg.eigvalsh(adj)
    mu = max(abs(ev[0]), abs(ev[-2]))
    bound = 2.0 * np.sqrt(d - 1.0)
    assert mu <= bound + 0.5 * (d - bound), (n, d, seed, mu, bound)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([16, 32, 48, 64]), d=st.sampled_from([4, 6]),
           seed=st.integers(0, 150))
    def test_random_regular_spectral_gap(n, d, seed):
        _check_alon_boppana(n, d, seed)
else:
    @pytest.mark.parametrize("n,d,seed", [
        (16, 4, 0), (32, 4, 17), (64, 4, 123), (32, 6, 5), (64, 6, 77),
    ])
    def test_random_regular_spectral_gap(n, d, seed):
        _check_alon_boppana(n, d, seed)


# -------------------------------------------------------------- round plans
class TestRoundPlans:
    def test_one_peer_rotation_covers_pool(self):
        p = plan_lib.OnePeerPlan()
        seen = np.zeros(5)
        for rnd in range(5):
            g = p.gates(rnd, 5)
            assert g.sum() == 1.0 and g.dtype == np.float32
            seen += g
        np.testing.assert_array_equal(seen, 1.0)  # each schedule exactly once

    def test_random_subset_size_and_determinism(self):
        p = plan_lib.RandomSubsetPlan(k=2, seed=3)
        for rnd in range(6):
            g = p.gates(rnd, 6)
            assert g.sum() == 2.0
            np.testing.assert_array_equal(g, p.gates(rnd, 6))  # stateless

    def test_throttle_fraction_rotates(self):
        p = plan_lib.ThrottlePlan(fraction=0.5)
        seen = np.zeros(6)
        for rnd in range(4):
            g = p.gates(rnd, 6)
            assert g.sum() == 3.0
            seen += g
        assert (seen > 0).all()  # rotation reaches the whole pool

    def test_make_plan_factory(self):
        assert plan_lib.make_plan("one_peer").gates(1, 4)[1] == 1.0
        assert plan_lib.make_plan("static").gates(0, 3).sum() == 3.0
        with pytest.raises(ValueError):
            plan_lib.make_plan("fourier")


# ------------------------------------------------- gated mixing (stacked)
def _tree(n, seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.standard_normal((n, 6, 5)), jnp.float32),
            "b": jnp.asarray(r.standard_normal((n, 11)), jnp.float32)}


class TestGatedMixing:
    def test_gated_matrix_row_stochastic_and_composes_with_alive(self):
        ov = topology.expander_overlay(12, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        r = np.random.default_rng(0)
        for t in range(4):
            g = (r.random(4) > 0.5).astype(np.float32)
            alive = (r.random(12) > 0.3).astype(np.float32)
            m = np.asarray(gossip.gated_mixing_matrix(
                spec, jnp.asarray(g), jnp.asarray(alive)))
            np.testing.assert_allclose(m.sum(1), 1.0, atol=1e-5)
            for i in np.nonzero(alive == 0)[0]:  # dead receivers: identity
                assert m[i, i] == pytest.approx(1.0)

    def test_stacked_gated_matches_dense_oracle(self):
        ov = topology.expander_overlay(10, 4, seed=2)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(10, seed=5)
        r = np.random.default_rng(1)
        for t in range(4):
            g = (r.random(4) > 0.4).astype(np.float32)
            alive = (r.random(10) > 0.25).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1
            got = gossip.mix_packed_stacked(x, spec, jnp.asarray(alive),
                                            gates=jnp.asarray(g))
            ref = gossip.mix_dense_gated(x, spec, jnp.asarray(g),
                                         jnp.asarray(alive))
            for k in x:
                np.testing.assert_allclose(got[k], ref[k],
                                           rtol=2e-5, atol=2e-5)

    def test_all_gates_zero_is_identity(self):
        ov = topology.expander_overlay(8, 4, seed=1)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(8)
        got = gossip.mix_packed_stacked(x, spec, gates=jnp.zeros(4))
        for k in x:
            np.testing.assert_allclose(got[k], x[k], rtol=1e-6)

    def test_all_gates_one_matches_ungated(self):
        ov = topology.expander_overlay(8, 4, seed=1)
        spec = gossip.make_gossip_spec(ov)
        x = _tree(8, seed=2)
        got = gossip.mix_packed_stacked(x, spec, gates=jnp.ones(4))
        ref = gossip.mix_dense(x, ov.mixing_matrix())
        for k in x:
            np.testing.assert_allclose(got[k], ref[k], rtol=2e-5, atol=2e-5)

    def test_gates_on_converted_overlay_with_fixed_points(self):
        """Gate semantics under fixed points (matching schedules leave nodes
        uncovered): the full-permutation convention keeps rows stochastic."""
        adj = topology.erdos_renyi_adjacency(12, seed=1).astype(np.int64)
        ov = convert.overlay_from_adjacency(adj)
        spec = gossip.make_gossip_spec(ov)
        x = {"w": jnp.asarray(
            np.random.default_rng(0).standard_normal((12, 7)), jnp.float32)}
        g = (np.random.default_rng(2).random(spec.degree) > 0.4
             ).astype(np.float32)
        got = gossip.mix_packed_stacked(x, spec, gates=jnp.asarray(g))
        ref = gossip.mix_dense_gated(x, spec, jnp.asarray(g))
        np.testing.assert_allclose(got["w"], ref["w"], rtol=2e-5, atol=2e-5)


# ---------------------------------- acceptance: packed executor + retraces
class TestGatedPackedShardMap:
    def _run(self, code):
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, cwd=".")
        assert "OK" in out.stdout, out.stdout + out.stderr

    def test_one_peer_rotation_bitwise_and_zero_retrace(self):
        """ISSUE 3 acceptance: one-peer rotation over the precompiled
        d-schedule pool — zero retraces across rounds, bit-for-bit equal to
        the dense gated oracle in f32 (gates+alive composed)."""
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import gossip, topology
            from repro.launch.mesh import shard_map
            from repro.overlay.plan import OnePeerPlan
            from repro.telemetry import TraceCounter

            mesh = jax.make_mesh((8,), ("client",))
            ov = topology.expander_overlay(8, 4, seed=0)
            spec = gossip.make_gossip_spec(ov)
            r = np.random.default_rng(0)
            x = {"w": jnp.asarray(r.standard_normal((8, 6, 5)), jnp.float32),
                 "b": jnp.asarray(r.standard_normal((8, 11)), jnp.float32)}
            specs = jax.tree.map(lambda _: P("client"), x)
            xs = jax.device_put(x, jax.tree.map(
                lambda _: NamedSharding(mesh, P("client")), x))

            tracer = TraceCounter("one_peer")
            @tracer.wrap
            def body(t, a, g):
                local = jax.tree.map(lambda v: v[0], t)
                out = gossip.ppermute_mix_packed(local, spec, "client",
                                                 alive=a, gates=g)
                return jax.tree.map(lambda v: v[None], out)
            fn = jax.jit(shard_map(body, mesh, in_specs=(specs, P(), P()),
                                   out_specs=specs))
            plan = OnePeerPlan()
            for rnd in range(10):
                g = plan.gates(rnd, spec.degree)
                alive = np.ones(8, np.float32)
                if rnd >= 5:
                    alive[rnd % 3] = 0.0   # compose with straggler masking
                got = fn(xs, jnp.asarray(alive), jnp.asarray(g))
                ref = gossip.mix_dense_gated(x, spec, jnp.asarray(g),
                                             jnp.asarray(alive))
                for k in x:   # bit-for-bit in f32
                    np.testing.assert_array_equal(np.asarray(got[k]),
                                                  np.asarray(ref[k]))
            tracer.expect(1, what="one-peer gates are data")
            print("ONE_PEER_BITWISE_OK traces=%d" % tracer.count)
        """)

    def test_converted_overlay_executable_by_ppermute_mix_packed(self):
        """ISSUE 3 acceptance: an arbitrary connected graph, converted to
        schedules, executes on the packed engine and matches the oracle."""
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.core import gossip, spectral, topology
            from repro.launch.mesh import shard_map
            from repro.overlay import convert

            rng = np.random.default_rng(7)
            while True:   # arbitrary connected 8-node graph
                u = rng.random((8, 8))
                a = np.triu((u < 0.4).astype(np.int64), 1)
                adj = a + a.T
                if spectral.is_connected(adj):
                    break
            ov = convert.overlay_from_adjacency(adj)
            np.testing.assert_array_equal(ov.multigraph_adjacency(), adj)
            spec = gossip.make_gossip_spec(ov)

            mesh = jax.make_mesh((8,), ("client",))
            x = {"w": jnp.asarray(rng.standard_normal((8, 6, 5)),
                                  jnp.float32)}
            specs = jax.tree.map(lambda _: P("client"), x)
            xs = jax.device_put(x, jax.tree.map(
                lambda _: NamedSharding(mesh, P("client")), x))

            def body(t):
                local = jax.tree.map(lambda v: v[0], t)
                out = gossip.ppermute_mix_packed(local, spec, "client")
                return jax.tree.map(lambda v: v[None], out)
            fn = jax.jit(shard_map(body, mesh, in_specs=(specs,),
                                   out_specs=specs))
            got = fn(xs)
            ref = gossip.mix_dense(x, ov.mixing_matrix())
            np.testing.assert_allclose(np.asarray(got["w"]),
                                       np.asarray(ref["w"]),
                                       rtol=2e-5, atol=2e-5)
            print("CONVERTED_EXEC_OK schedules=%d" % spec.degree)
        """)


# ------------------------------------------------------- elastic + plans
def quad_loss(params, batch):
    return jnp.mean(jnp.square(params["w"] - batch["target"])), {}


def _batches(targets, k):
    return {"target": jnp.broadcast_to(
        targets[:, None], (targets.shape[0], k, targets.shape[1]))}


class TestElasticWithPlan:
    def test_one_peer_plan_zero_retrace_and_oracle_parity(self):
        """Time-varying rounds through the elastic trainer: rotating gates
        (+ straggler churn) reuse ONE executable, and every round matches a
        manual local-step + dense gated-mixing oracle loop."""
        n, dim = 10, 4
        r = np.random.default_rng(0)
        targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
        cfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.3, momentum=0.5)
        overlay = topology.expander_overlay(n, 4, seed=3)
        trainer = ElasticTrainer(overlay=overlay, loss_fn=quad_loss,
                                 dcfg=cfg, straggler_rounds=1,
                                 failure_rounds=99,
                                 plan=plan_lib.OnePeerPlan())
        spec = trainer.spec

        params = {"w": jnp.zeros((n, dim))}
        ref = {"w": jnp.zeros((n, dim))}

        def local(p, b):
            def client(pc, bc):
                v = jax.tree.map(jnp.zeros_like, pc)
                pc, _, loss = dfedavg.local_round(pc, v, bc, quad_loss, cfg,
                                                  lr=0.3)
                return pc, loss
            return jax.vmap(client)(p, b)

        rng = np.random.default_rng(1)
        for rnd in range(8):
            mask = np.ones(n, np.float32)
            if rnd in (3, 5):
                mask[rng.integers(n)] = 0.0
            gates = trainer.gates_for_round(rnd)
            params, _, _ = trainer.observe_heartbeats(mask, params)
            batches = _batches(targets, 2)
            params, _ = trainer.step(params, batches, 0.3)
            ref, _ = local(ref, batches)
            ref = gossip.mix_dense_gated(ref, spec, gates, jnp.asarray(mask))
            np.testing.assert_allclose(np.asarray(params["w"]),
                                       np.asarray(ref["w"]),
                                       rtol=2e-5, atol=2e-5)
        assert trainer.n_traces == 1, trainer.n_traces

    def test_static_plan_is_bitwise_equal_to_no_plan(self):
        """Regression: a StaticPlan must be inert. On overlays whose Chow
        self-weight is negative (onepeer_exp at n=32: w0 < 0), all-ones
        gates are NOT a no-op (the gated branch clamps w0) — so the gate
        pathway must stay off for static plans, matching plan=None
        bit-for-bit."""
        n, dim = 32, 5
        overlay, _ = registry.build("onepeer_exp", n)
        spec = gossip.make_gossip_spec(overlay)
        assert min(spec.self_weights) < 0  # the case that used to diverge
        r = np.random.default_rng(0)
        targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
        cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.0)
        outs = []
        for plan in (None, plan_lib.StaticPlan(),
                     plan_lib.make_plan("static")):
            trainer = ElasticTrainer(overlay=overlay, loss_fn=quad_loss,
                                     dcfg=cfg, straggler_rounds=1,
                                     failure_rounds=99, plan=plan)
            params = {"w": jnp.zeros((n, dim))}
            for _ in range(3):
                trainer.observe_heartbeats(np.ones(n), params)
                params, _ = trainer.step(params, _batches(targets, 1), 0.2)
            outs.append(np.asarray(params["w"]))
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[0], outs[2])

    def test_plan_survives_repair(self):
        """A membership change rebuilds the spec (new schedule count); the
        stateless plan keeps issuing valid gates and training continues."""
        n, dim = 12, 3
        targets = jnp.zeros((n, dim))
        cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.0)
        trainer = ElasticTrainer(overlay=topology.expander_overlay(n, 4,
                                                                   seed=0),
                                 loss_fn=quad_loss, dcfg=cfg,
                                 straggler_rounds=1, failure_rounds=2,
                                 plan=plan_lib.OnePeerPlan())
        params = {"w": jnp.ones((n, dim))}
        alive = np.ones(n)
        for _ in range(2):
            params, _, _ = trainer.observe_heartbeats(alive, params)
            params, _ = trainer.step(params, _batches(targets, 1), 0.2)
        alive[4] = 0
        params, _, _ = trainer.observe_heartbeats(alive, params)
        params, _ = trainer.step(params, _batches(targets, 1), 0.2)
        params, _, old2new = trainer.observe_heartbeats(alive, params)
        assert old2new is not None and trainer.n_clients == n - 1
        targets2 = jnp.zeros((n - 1, dim))
        for _ in range(4):
            params, _, _ = trainer.observe_heartbeats(np.ones(n - 1), params)
            params, _ = trainer.step(params, _batches(targets2, 1), 0.2)
        assert trainer.n_traces == 2          # one per membership
        assert bool(jnp.isfinite(params["w"]).all())
        assert trainer.gates_for_round().shape == (trainer.spec.degree,)
