"""Spectral theory tests: the paper's §3 claims, checked numerically."""
import math

import numpy as np
import pytest
try:  # optional dep (requirements-dev.txt): property tests degrade, not error
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import mixing, spectral, topology


class TestLaplacian:
    def test_ring_eigenvalues_closed_form(self):
        n = 12
        ev = spectral.laplacian_spectrum(topology.ring_overlay(n).simple_adjacency())
        want = sorted(2 - 2 * math.cos(2 * math.pi * k / n) for k in range(n))
        np.testing.assert_allclose(ev, want, atol=1e-9)

    def test_complete_graph_kappa_is_one(self):
        adj = topology.complete_adjacency(10)
        assert spectral.kappa(adj) == pytest.approx(1.0)

    def test_disconnected_graph_detected(self):
        adj = np.zeros((4, 4))
        adj[0, 1] = adj[1, 0] = 1
        adj[2, 3] = adj[3, 2] = 1
        assert not spectral.is_connected(adj)
        assert spectral.kappa(adj) == float("inf")


class TestPaperBounds:
    def test_ring_kappa_quadratic_blowup(self):
        """Paper §3.1: kappa(ring) >= N^2/pi^2."""
        for n in (16, 64, 128):
            kap = spectral.kappa(topology.ring_overlay(n).simple_adjacency())
            assert kap >= spectral.ring_kappa_lower_bound(n) * 0.999

    def test_expander_beats_ring_lambda(self):
        """The headline claim: expander lambda stays bounded, ring's -> 1."""
        for n in (32, 64, 128):
            ring = topology.ring_overlay(n).chow_weights()
            exp = topology.expander_overlay(n, 4, seed=0).chow_weights()
            assert exp.lam < ring.lam
        # and the gap grows with n
        lam_128 = topology.expander_overlay(128, 4, seed=0).chow_weights().lam
        assert lam_128 < 0.95  # bounded away from 1 at n=128

    def test_ramanujan_bound_decreasing_in_d(self):
        vals = [spectral.ramanujan_bound(d) for d in (3, 4, 8, 16)]
        assert vals == sorted(vals, reverse=True)

    def test_theta_star_optimal(self):
        """theta* = 1/kappa minimizes lambda(theta) (paper Fig. 2)."""
        for kap in (2.0, 5.0, 20.0):
            t_star = spectral.theta_star(kap)
            best = spectral.chow_lambda(kap, t_star)
            for t in np.linspace(0.01, 0.99, 33):
                assert best <= spectral.chow_lambda(kap, float(t)) + 1e-12

    def test_c_lambda_increasing(self):
        """C_lambda (Thm 2.5) increases in lambda: better graphs generalize."""
        lams = np.linspace(0.05, 0.95, 10)
        cs = [spectral.c_lambda(float(l)) for l in lams]
        assert all(a < b for a, b in zip(cs, cs[1:]))


class TestMixingMatrices:
    @pytest.mark.parametrize("builder", [
        mixing.chow_matrix, mixing.metropolis_hastings_matrix,
        mixing.max_degree_matrix])
    def test_definition_2_1(self, builder):
        adj = topology.expander_overlay(20, 4, seed=1).simple_adjacency()
        m = builder(adj)
        mixing.validate_mixing_matrix(m, adj)

    def test_uniform_average_is_complete_graph_limit(self):
        m = mixing.uniform_average_matrix(8)
        mixing.validate_mixing_matrix(m, topology.complete_adjacency(8))

    def test_chow_lambda_matches_formula(self):
        adj = topology.expander_overlay(24, 4, seed=3).simple_adjacency()
        kap = spectral.kappa(adj)
        m = mixing.chow_matrix(adj)
        lam_emp = spectral.mixing_lambda(m)
        lam_formula = spectral.chow_lambda(kap)
        assert lam_emp == pytest.approx(lam_formula, abs=1e-9)


def _check_expander_overlay_properties(n, d, seed):
    """Property: any (n, d, seed) draw yields a valid overlay whose Chow mixing
    matrix satisfies Definition 2.1 and whose schedule decomposition matches."""
    if d % 2 == 1 and n % 2 == 1:
        n += 1
    ov = topology.expander_overlay(n, d, seed=seed)
    assert ov.degree == d
    m = ov.mixing_matrix()
    mixing.validate_mixing_matrix(m)
    # decomposition: M = w0 I + c sum_s P_s
    w = ov.chow_weights()
    m2 = w.self_weight * np.eye(n)
    for s in ov.schedules:
        m2[np.arange(n), s] += w.edge_weight
    np.testing.assert_allclose(m, m2, atol=1e-12)
    # rows sum to one; lambda in (0, 1)
    np.testing.assert_allclose(m.sum(1), 1.0, atol=1e-9)
    assert 0.0 < w.lam < 1.0


def _check_mixing_time_consistent(lam):
    t = spectral.mixing_time(lam, eps=1e-3)
    assert lam ** t <= 1e-3 * (1 + 1e-9)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(8, 48), d=st.integers(2, 6), seed=st.integers(0, 10_000))
    def test_expander_overlay_properties(n, d, seed):
        _check_expander_overlay_properties(n, d, seed)

    @settings(max_examples=20, deadline=None)
    @given(lam=st.floats(0.01, 0.99))
    def test_mixing_time_consistent(lam):
        _check_mixing_time_consistent(lam)
else:
    @pytest.mark.parametrize("n,d,seed", [(8, 2, 0), (17, 3, 42), (48, 6, 999),
                                          (32, 4, 7), (11, 5, 123)])
    def test_expander_overlay_properties(n, d, seed):
        _check_expander_overlay_properties(n, d, seed)

    @pytest.mark.parametrize("lam", [0.01, 0.37, 0.5, 0.93, 0.99])
    def test_mixing_time_consistent(lam):
        _check_mixing_time_consistent(lam)


# --------------------------------------------------- Chebyshev acceleration
class TestChebyshevCoefficients:
    """The sub_rounds=k coefficient chooser (spectral.chebyshev_omegas /
    chebyshev_lambda) and the registry convention it leans on: the lambda
    the registry reports IS mixing_lambda of the Chow matrix —
    max(|lambda_2|, |lambda_N|), in [0, 1) for every connected overlay."""

    def _overlays(self):
        from repro.overlay import registry
        return [registry.build("ring", 16)[0],
                registry.build("expander", 16, degree=4, seed=0)[0],
                registry.build("random_regular", 16, degree=4, seed=1)[0]]

    def test_registry_lambda_sign_and_normalization(self):
        from repro.overlay import registry
        for ov in self._overlays():
            meta = registry.overlay_meta(ov)
            w = ov.chow_weights()
            # one lambda, three spellings: the registry record, the Chow
            # weights, and the empirical spectrum of the mixing matrix
            assert meta["lam"] == w.lam
            lam_emp = spectral.mixing_lambda(ov.mixing_matrix())
            assert lam_emp == pytest.approx(w.lam, abs=1e-9)
            assert 0.0 <= w.lam < 1.0  # the sign/normalization pin
            assert meta["spectral_gap"] == pytest.approx(1.0 - w.lam)
            # and the k=2 record is the Chebyshev contraction of THAT lam
            assert meta["cheby_lambda_k2"] == pytest.approx(
                spectral.chebyshev_lambda(w.lam, 2))
            assert meta["cheby_lambda_k2"] < w.lam ** 2

    def test_chebyshev_schedule_matches_spectral(self):
        from repro.overlay import registry
        for ov in self._overlays():
            for k in (1, 2, 4):
                om = registry.chebyshev_schedule(ov, k)
                np.testing.assert_array_equal(
                    om, spectral.chebyshev_omegas(ov.chow_weights().lam, k))
                assert om.shape == (k,) and om.dtype == np.float32
                assert om[0] == 1.0

    def test_omegas_recurrence_and_degenerate_lambda(self):
        # T-ratio recurrence: omega_{j+1} = 1/(1 - (lam^2/4) omega_j),
        # seeded at omega_1 = 2; our omegas[0] = 1 is the plain first round
        lam = 0.8
        om = spectral.chebyshev_omegas(lam, 4)
        w = 2.0
        for j in range(1, 4):
            w = 1.0 / (1.0 - 0.25 * lam * lam * w)
            assert om[j] == pytest.approx(w, rel=1e-6)
        # lam outside [0, 1) degenerates to plain repetition, never a blowup
        for bad in (-0.5, 1.0, 1.5):
            np.testing.assert_array_equal(
                spectral.chebyshev_omegas(bad, 3), np.ones(3, np.float32))
        assert spectral.chebyshev_lambda(1.0, 2) == 1.0
        assert spectral.chebyshev_lambda(0.0, 2) == 0.0


def _check_chebyshev_contraction(lam, k):
    """Property: on any consensus-style spectrum, k Chebyshev sub-rounds
    contract the worst mode by 1/T_k(1/lam) — strictly beating lam^k plain
    repetition — and preserve the consensus (all-ones) mode exactly."""
    eff = spectral.chebyshev_lambda(lam, k)
    if k == 1:
        assert eff == pytest.approx(lam)
    else:
        assert eff < lam ** k * (1 + 1e-9)
    # exact on a 2x2 toy whose nontrivial eigenvalue is exactly lam:
    # m = [[(1+lam)/2, (1-lam)/2], [(1-lam)/2, (1+lam)/2]]
    m = 0.5 * np.array([[1 + lam, 1 - lam], [1 - lam, 1 + lam]])
    om = spectral.chebyshev_omegas(lam, k)
    x = np.array([1.0, -1.0])  # pure worst-mode deviation
    y = mixing.chebyshev_mix(x, m, om)
    assert abs(y[0]) == pytest.approx(eff, abs=1e-6)
    ones = mixing.chebyshev_mix(np.ones(2), m, om)
    np.testing.assert_allclose(ones, 1.0, atol=1e-12)


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(lam=st.floats(0.05, 0.98), k=st.integers(1, 6))
    def test_chebyshev_contraction(lam, k):
        _check_chebyshev_contraction(lam, k)
else:
    @pytest.mark.parametrize("lam,k", [(0.05, 1), (0.37, 2), (0.74, 2),
                                       (0.9, 3), (0.98, 6)])
    def test_chebyshev_contraction(lam, k):
        _check_chebyshev_contraction(lam, k)
