"""Overlay construction / join / failure-repair tests (paper §4)."""
import numpy as np
import pytest
try:  # optional dep (requirements-dev.txt): property tests degrade, not error
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import topology


class TestConstruction:
    def test_ring_is_two_regular(self):
        ov = topology.ring_overlay(10)
        deg = ov.multigraph_adjacency().sum(1)
        np.testing.assert_array_equal(deg, 2)

    def test_expander_even_degree(self):
        ov = topology.expander_overlay(20, 4, seed=0)
        assert len(ov.schedules) == 4
        assert ov.coords.shape == (20, 2)

    def test_expander_odd_degree_has_matching(self):
        ov = topology.expander_overlay(20, 3, seed=0)
        assert len(ov.schedules) == 3
        invs = [np.array_equal(np.argsort(s), s) for s in ov.schedules]
        assert sum(invs) == 1  # exactly one involution (the matching)

    def test_base_ring_included(self):
        """Paper §5: expander built by adding edges on top of the Ring."""
        n = 16
        ov = topology.expander_overlay(n, 4, seed=0, include_base_ring=True)
        adj = ov.simple_adjacency()
        for i in range(n):
            assert adj[i, (i + 1) % n] == 1  # natural ring edges present

    def test_erdos_renyi_connected_and_logn_degree(self):
        n = 200
        adj = topology.erdos_renyi_adjacency(n, seed=0)
        from repro.core import spectral
        assert spectral.is_connected(adj)
        mean_deg = adj.sum() / n
        assert 0.3 * np.log(n) < mean_deg < 3.0 * np.log(n)

    def test_odd_degree_odd_n_rejected(self):
        with pytest.raises(ValueError):
            topology.expander_overlay(15, 3)


class TestJoin:
    def test_add_node_preserves_validity(self):
        ov = topology.expander_overlay(12, 4, seed=0)
        ov2 = ov.add_node(np.random.default_rng(1))
        assert ov2.n == 13
        assert ov2.spectral_report().connected
        ov2.mixing_matrix()  # validates schedules internally

    def test_repeated_joins(self):
        ov = topology.expander_overlay(8, 4, seed=0)
        rng = np.random.default_rng(2)
        for _ in range(5):
            ov = ov.add_node(rng)
        assert ov.n == 13
        assert ov.spectral_report().connected

    def test_add_node_schedule_structure(self):
        """Joins keep every schedule a valid permutation, keep the schedule
        set closed under inverse, and splice the new node into each ring
        (ring degree 2 per space; the matching keeps a fixed point)."""
        ov = topology.expander_overlay(12, 5, seed=3)  # 2 rings + matching
        ov2 = ov.add_node(np.random.default_rng(0))
        assert len(ov2.schedules) == len(ov.schedules)
        keys = {tuple(s.tolist()) for s in ov2.schedules}
        for s in ov2.schedules:
            assert np.array_equal(np.sort(s), np.arange(13))
            assert tuple(np.argsort(s).tolist()) in keys  # inverse present
        invs = [np.array_equal(np.argsort(s), s) for s in ov2.schedules]
        assert sum(invs) == 1                   # the matching survived
        matching = ov2.schedules[invs.index(True)]
        assert matching[12] == 12               # degree deficit until rebuild
        # the new node rides every ring: degree 2 per ring space
        ring_adj = np.zeros((13, 13))
        idx = np.arange(13)
        for s, inv in zip(ov2.schedules, invs):
            if not inv:
                ring_adj[idx, s] += 1
        assert ring_adj[12].sum() == 2 * (ov2.coords.shape[1])
        assert ov2.coords.shape == (13, ov.coords.shape[1])

    def test_add_node_then_remove_round_trips_membership(self):
        """Join + immediate failure of the joined node keeps a valid,
        connected overlay on the original membership."""
        ov = topology.expander_overlay(10, 4, seed=1)
        ov2 = ov.add_node(np.random.default_rng(4))
        repaired, old2new = ov2.remove_nodes([10])
        assert repaired.n == 10
        np.testing.assert_array_equal(old2new[:10], np.arange(10))
        assert repaired.spectral_report().connected
        assert repaired.chow_weights().lam < 1.0

    def test_joins_keep_spectral_gap_sane(self):
        """Growth must not collapse connectivity: lambda stays bounded away
        from 1 through repeated joins (fresh rings re-randomize)."""
        ov = topology.expander_overlay(16, 4, seed=0)
        rng = np.random.default_rng(7)
        base = ov.chow_weights().lam
        for _ in range(6):
            ov = ov.add_node(rng)
        lam = ov.chow_weights().lam
        assert lam < 1.0 and lam < base + 0.15


class TestRepair:
    def test_single_failure_splice(self):
        """Two-hop splice: pred connects to succ in every ring (paper §4.1)."""
        ov = topology.ring_overlay(10)
        repaired, old2new = ov.remove_nodes([4])
        assert repaired.n == 9
        assert old2new[4] == -1
        succ = repaired.schedules[0]
        # node 3 (new idx 3) must now point at node 5 (new idx 4)
        assert succ[old2new[3]] == old2new[5]
        assert repaired.spectral_report().connected

    def test_run_of_failures_splice(self):
        ov = topology.ring_overlay(12)
        repaired, _ = ov.remove_nodes([3, 4, 5])
        assert repaired.n == 9
        assert repaired.spectral_report().connected

    def test_expander_stays_connected_after_20pct_failures(self):
        """Paper §5.2 resilience: 20% drop keeps the expander connected."""
        ov = topology.expander_overlay(40, 4, seed=0)
        rng = np.random.default_rng(0)
        dead = rng.choice(40, size=8, replace=False)
        repaired, _ = ov.remove_nodes(list(dead))
        rep = repaired.spectral_report()
        assert rep.connected
        assert repaired.chow_weights().lam < 1.0

    def test_matching_repair_repairs_orphans(self):
        ov = topology.expander_overlay(16, 3, seed=1)
        repaired, _ = ov.remove_nodes([0, 7])
        assert repaired.n == 14
        # matching schedule still an involution
        m = [s for s in repaired.schedules
             if np.array_equal(np.argsort(s), s)]
        assert len(m) >= 1
        assert repaired.spectral_report().connected


def _check_repair_properties(n, seed, frac):
    """Property: splice repair of any failure set keeps a valid, (almost
    always) connected overlay with a well-defined mixing matrix."""
    ov = topology.expander_overlay(n, 4, seed=seed)
    rng = np.random.default_rng(seed)
    k = max(1, int(frac * n))
    dead = rng.choice(n, size=k, replace=False)
    repaired, old2new = ov.remove_nodes(list(dead))
    assert repaired.n == n - k
    assert sorted(x for x in old2new if x >= 0) == list(range(n - k))
    if repaired.spectral_report().connected:
        m = repaired.mixing_matrix()
        np.testing.assert_allclose(m.sum(1), 1.0, atol=1e-9)


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(10, 40), seed=st.integers(0, 1000),
           frac=st.floats(0.05, 0.3))
    def test_repair_properties(n, seed, frac):
        _check_repair_properties(n, seed, frac)
else:
    @pytest.mark.parametrize("n,seed,frac", [(10, 0, 0.1), (24, 42, 0.25),
                                             (40, 999, 0.3), (33, 7, 0.05)])
    def test_repair_properties(n, seed, frac):
        _check_repair_properties(n, seed, frac)
