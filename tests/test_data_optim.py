"""Data pipeline + optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import federated, mnist, pipeline, shakespeare
from repro.optim import (adamw_init, adamw_step, clip_by_global_norm, cosine,
                         constant, global_norm, inverse_time, sgdm_init,
                         sgdm_step, warmup_cosine)


class TestFederatedSplits:
    def test_iid_partition(self):
        parts = federated.iid_split(1000, 10, seed=0)
        assert sum(len(p) for p in parts) == 1000
        all_idx = np.concatenate(parts)
        assert len(np.unique(all_idx)) == 1000

    def test_label_shard_single_label(self):
        """Paper non-IID: each client sees exactly one label."""
        labels = np.repeat(np.arange(10), 100)
        parts = federated.label_shard_split(labels, 10, seed=0)
        for i, p in enumerate(parts):
            assert len(np.unique(labels[p])) == 1

    def test_label_shard_more_clients_than_classes(self):
        labels = np.repeat(np.arange(10), 100)
        parts = federated.label_shard_split(labels, 20, seed=0)
        assert len(parts) == 20
        assert all(len(p) > 0 for p in parts)

    def test_dirichlet_covers_all(self):
        labels = np.repeat(np.arange(5), 200)
        parts = federated.dirichlet_split(labels, 8, alpha=0.5, seed=0)
        assert sum(len(p) for p in parts) == 1000

    def test_span_split_overlap(self):
        spans = federated.span_split(10_000, 10, overlap=0.2)
        assert len(spans) == 10
        for (a1, b1), (a2, b2) in zip(spans, spans[1:]):
            assert a2 < b1  # consecutive spans overlap


class TestBatchers:
    def test_client_batcher_shapes_and_determinism(self):
        tr, _ = mnist.make_mnist_like(500, 100, seed=0)
        parts = federated.iid_split(500, 4, seed=0)
        b = pipeline.ClientBatcher(tr.x, tr.y, parts, batch_size=8,
                                   local_steps=3, seed=1)
        r1 = b.round_batches(5)
        r2 = b.round_batches(5)
        assert r1["x"].shape == (4, 3, 8, 784)
        np.testing.assert_array_equal(r1["x"], r2["x"])  # restart-safe
        r3 = b.round_batches(6)
        assert not np.array_equal(r1["x"], r3["x"])

    def test_token_batcher_next_token_labels(self):
        toks, vocab = shakespeare.corpus(repeat=2)
        spans = federated.span_split(len(toks), 4)
        b = pipeline.TokenBatcher(toks, spans, batch_size=2, seq_len=16,
                                  local_steps=2, seed=0)
        r = b.round_batches(0)
        assert r["tokens"].shape == (4, 2, 2, 16)
        np.testing.assert_array_equal(r["labels"][..., :-1], r["tokens"][..., 1:])

    def test_mnist_like_learnable(self):
        """The synthetic MNIST must be learnable by the paper's MLP quickly."""
        from repro.models import mlp
        from repro.models.params import init_params
        tr, te = mnist.make_mnist_like(2000, 500, seed=0)
        params = init_params(mlp.param_struct(), jax.random.key(0))

        @jax.jit
        def step(p, x, y):
            (l, aux), g = jax.value_and_grad(mlp.loss_fn, has_aux=True)(
                p, {"x": x, "y": y})
            return jax.tree.map(lambda w, gg: w - 0.1 * gg, p, g), aux["acc"]

        r = np.random.default_rng(0)
        for i in range(60):
            idx = r.integers(0, len(tr.x), 64)
            params, _ = step(params, jnp.asarray(tr.x[idx]), jnp.asarray(tr.y[idx]))
        _, aux = mlp.loss_fn(params, {"x": jnp.asarray(te.x), "y": jnp.asarray(te.y)})
        assert float(aux["acc"]) > 0.8


class TestOptim:
    def test_sgdm_heavy_ball(self):
        p = {"w": jnp.asarray([1.0, -2.0])}
        st = sgdm_init(p)
        g = {"w": jnp.asarray([0.5, 0.5])}
        p1, st = sgdm_step(p, g, st, lr=0.1, beta=0.9)
        np.testing.assert_allclose(p1["w"], [0.95, -2.05], rtol=1e-6)
        p2, st = sgdm_step(p1, g, st, lr=0.1, beta=0.9)
        # v2 = 0.9*(-0.05) - 0.05 = -0.095
        np.testing.assert_allclose(p2["w"], [0.95 - 0.095, -2.05 - 0.095], rtol=1e-6)

    def test_adamw_converges_quadratic(self):
        p = {"w": jnp.full(4, 5.0)}
        st = adamw_init(p)
        for i in range(200):
            g = {"w": 2 * p["w"]}
            p, st = adamw_step(p, g, st, lr=0.1)
        assert float(jnp.max(jnp.abs(p["w"]))) < 0.1

    def test_schedules(self):
        assert float(constant(0.5)(100)) == 0.5
        assert float(inverse_time(2.0)(4)) == pytest.approx(0.5)
        c = cosine(1.0, 100, final_frac=0.1)
        assert float(c(0)) == pytest.approx(1.0)
        assert float(c(100)) == pytest.approx(0.1)
        w = warmup_cosine(1.0, 10, 110)
        assert float(w(5)) == pytest.approx(0.5)

    def test_clip(self):
        t = {"a": jnp.asarray([3.0, 4.0])}
        assert float(global_norm(t)) == pytest.approx(5.0)
        clipped, norm = clip_by_global_norm(t, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


class TestCompression:
    def test_error_feedback_unbiased_over_time(self):
        """EF memory ensures the *sum* of compressed payloads tracks the sum
        of true values (the EF-SGD telescoping property)."""
        from repro.core import compression
        r = np.random.default_rng(0)
        xs = [jnp.asarray(r.standard_normal(64), jnp.float32) for _ in range(30)]
        state = compression.ErrorFeedbackState.init(xs[0])
        sent_sum = jnp.zeros(64)
        true_sum = jnp.zeros(64)
        for x in xs:
            payload, state = compression.ef_compress(x, state, k_fraction=0.25)
            sent_sum = sent_sum + payload
            true_sum = true_sum + x
        resid_norm = float(jnp.linalg.norm(true_sum - sent_sum))
        # residual = what's still in memory, bounded (doesn't grow with T)
        assert resid_norm <= float(jnp.linalg.norm(state.residual)) + 1e-4
