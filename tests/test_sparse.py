"""Sparse top-k gossip with error feedback — the stateful WireCodec
contract, the codec registry, and the trainers' ``engine=`` front door.

Covers the PR's acceptance criteria:
  * TopKEFCodec.encode is ``ef_compress`` (the simulator oracle) on the
    packed buffer, bitwise, with the residual threading across rounds;
  * the EF residual (codec state) rides the SAME old2new splice-repair
    remap as the params and the in-flight snapshot, byte-exact;
  * churn x cohorts x gates never retrace the sparse round;
  * the production shard_map step ships exactly d collectives, all of them
    the folded int8 top-k wire, at <= 10% of the dense f32 wire bytes;
  * ``engine=GossipEngineConfig(...)`` is bitwise-equivalent to the legacy
    per-knob spelling, which now warns;
  * ``register_codec`` makes a custom codec a first-class engine citizen.
"""
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression, dfedavg, engine, gossip, packing, \
    topology
from repro.launch.elastic import ElasticTrainer
from repro.overlay.plan import OnePeerPlan, RandomKActiveSet


def _quad_loss(params, batch):
    return jnp.mean(jnp.square(params["w"] - batch["target"])), {}


def _batches(targets, k=1):
    return {"target": jnp.broadcast_to(
        targets[:, None], (targets.shape[0], k) + targets.shape[1:])}


def _trainer(n, **kw):
    kw.setdefault("overlay", topology.ring_overlay(n))
    kw.setdefault("loss_fn", _quad_loss)
    kw.setdefault("dcfg", dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2,
                                                 momentum=0.9))
    return ElasticTrainer(**kw)


class TestTopKEFCodec:
    def test_encode_matches_ef_compress_oracle_multi_round(self):
        """The codec on a pad-free packed buffer IS ef_compress: decoded
        payload and carried residual match the oracle bitwise, three rounds
        deep (the residual is what makes round r depend on round r-1)."""
        rows = 16
        codec = engine.get_codec("topk_ef")
        r = np.random.default_rng(0)
        state = codec.init_state(
            jax.ShapeDtypeStruct((rows, packing.LANE), jnp.float32))
        oracle = compression.ErrorFeedbackState.init(
            {"b": jnp.zeros((rows, packing.LANE), jnp.float32)})
        for rnd in range(3):
            buf = jnp.asarray(r.standard_normal((rows, packing.LANE)),
                              jnp.float32)
            wire, state = codec.encode(buf, n_blocks=1, block_rows=rows,
                                       impl="ref", state=state)
            dense = codec.decode(wire, jnp.float32, n_blocks=1,
                                 block_rows=rows)
            want, oracle = compression.ef_compress(
                {"b": buf}, oracle, codec.k_fraction)
            np.testing.assert_array_equal(np.asarray(dense),
                                          np.asarray(want["b"]))
            np.testing.assert_array_equal(
                np.asarray(state), np.asarray(oracle.residual["b"]))

    def test_wire_is_at_most_a_tenth_of_f32(self):
        """ISSUE acceptance: the k=1% wire ships <= 10% of the dense f32
        bytes for a realistically sized buffer."""
        struct = jax.ShapeDtypeStruct((4096, packing.LANE), jnp.float32)
        topk = engine.get_codec("topk_ef").wire_struct(struct, 1)
        f32 = engine.get_codec("f32").wire_struct(struct, 1)
        ratio = ((np.prod(topk.shape) * topk.dtype.itemsize)
                 / (np.prod(f32.shape) * f32.dtype.itemsize))
        assert ratio <= 0.10, ratio

    def test_stateful_codec_rejects_screens_and_per_leaf(self):
        with pytest.raises(ValueError, match="stateful codec"):
            engine.GossipEngineConfig(substrate="per_leaf", codec="topk_ef")
        with pytest.raises(ValueError, match="stateful codec"):
            engine.GossipEngineConfig(substrate="stacked", codec="topk_ef",
                                      screen="norm_clip")


class TestCodecRegistry:
    def test_unknown_codec_is_a_clear_error(self):
        with pytest.raises(ValueError, match="unknown codec"):
            engine.get_codec("definitely_not_registered")

    def test_registered_codec_is_first_class_in_the_front_door(self):
        """register_codec -> the name works in GossipEngineConfig and the
        trainer's engine= front door with zero executor special-casing."""
        if "topk_ef_test_k5" not in engine.CODECS:
            engine.register_codec(
                "topk_ef_test_k5",
                engine.TopKEFCodec(0.05, name="topk_ef_test_k5"))
        assert "topk_ef_test_k5" in engine.CODECS
        n, dim = 6, 256
        trainer = _trainer(n, engine=engine.GossipEngineConfig(
            substrate="stacked", codec="topk_ef_test_k5"))
        r = np.random.default_rng(0)
        params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
        targets = jnp.zeros((n, dim), jnp.float32)
        for _ in range(2):
            params, losses = trainer.step(params, _batches(targets), 0.2)
        assert bool(jnp.isfinite(losses).all())
        assert trainer._codec_state is not None
        assert trainer.n_traces == 1


class TestEngineFrontDoor:
    def test_engine_config_bitwise_equals_legacy_default(self):
        """engine=stacked/f32 and the legacy default knobs drive the exact
        same round: params agree bitwise after three rounds."""
        n, dim = 8, 64
        r = np.random.default_rng(1)
        p0 = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
        targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # defaults must NOT warn
            legacy = _trainer(n)
        front = _trainer(n, engine=engine.GossipEngineConfig(
            substrate="stacked", codec="f32"))
        pa = pb = p0
        for _ in range(3):
            pa, _ = legacy.step(pa, _batches(targets), 0.1)
            pb, _ = front.step(pb, _batches(targets), 0.1)
        np.testing.assert_array_equal(np.asarray(pa["w"]),
                                      np.asarray(pb["w"]))

    def test_legacy_knobs_warn_and_still_work(self):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            trainer = _trainer(6, gossip_codec="int8_block")
        assert any(issubclass(x.category, DeprecationWarning) for x in w), w
        assert trainer.gossip_codec == "int8_block"

    def test_engine_plus_legacy_knobs_is_an_error(self):
        with pytest.raises(ValueError, match="not both"):
            _trainer(6, gossip_codec="int8",
                     engine=engine.GossipEngineConfig(substrate="stacked"))


class TestCodecStateElastic:
    def test_residual_survives_splice_repair_byte_exact(self):
        """The EF residual rides repair_and_remap with the params and the
        in-flight wire: surviving rows are byte-identical post-splice."""
        # dim large enough that k = 1% of the packed buffer is smaller than
        # the payload — below that, top-k captures every nonzero entry and
        # the residual is legitimately all-zero
        n, dim = 12, 1 << 16
        r = np.random.default_rng(2)
        targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
        trainer = _trainer(n, straggler_rounds=1, failure_rounds=2,
                           engine=engine.GossipEngineConfig(
                               substrate="stacked", codec="topk_ef",
                               delay=1))
        params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
        params, _ = trainer.step(params, _batches(targets), 0.1)
        alive = np.ones(n)
        alive[5] = 0
        params, _, old2new = trainer.observe_heartbeats(alive, params)
        assert old2new is None                    # straggler, not dead yet
        params, _ = trainer.step(params, _batches(targets), 0.1)
        pre_state = [np.asarray(b) for b in trainer._codec_state]
        pre_wire = [np.asarray(b) for b in trainer._inflight]
        assert sum(float(np.abs(b).sum()) for b in pre_state) > 0
        params, _, old2new = trainer.observe_heartbeats(alive, params)
        assert old2new is not None and old2new[5] == -1
        survivors = np.arange(n) != 5
        for b_pre, b_post in zip(pre_state, trainer._codec_state):
            assert str(np.asarray(b_post).dtype) == "float32"
            np.testing.assert_array_equal(np.asarray(b_post),
                                          b_pre[survivors])
        for b_pre, b_post in zip(pre_wire, trainer._inflight):
            np.testing.assert_array_equal(np.asarray(b_post),
                                          b_pre[survivors])
        surv_targets = jnp.concatenate([targets[:5], targets[6:]])
        params, _ = trainer.step(params, _batches(surv_targets), 0.1)
        assert params["w"].shape[0] == n - 1
        assert bool(jnp.isfinite(params["w"]).all())
        assert trainer.n_traces == 2              # one re-jit per membership

    def test_churn_cohorts_gates_never_retrace_the_sparse_round(self):
        """Straggler churn x random-k cohorts x one-peer gate rotation with
        the stateful codec: alive/gates/state are data, ONE executable."""
        n, dim = 10, 128
        trainer = _trainer(n, straggler_rounds=2, failure_rounds=10**9,
                           plan=OnePeerPlan(),
                           active_plan=RandomKActiveSet(k=6, seed=3),
                           engine=engine.GossipEngineConfig(
                               substrate="stacked", codec="topk_ef"))
        r = np.random.default_rng(3)
        params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
        targets = jnp.zeros((n, dim), jnp.float32)
        for rnd in range(6):
            alive = (r.random(n) > 0.3).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1.0
            params, _, old2new = trainer.observe_heartbeats(alive, params)
            assert old2new is None
            params, _ = trainer.step(params, _batches(targets), 0.2)
        assert trainer.n_traces == 1, trainer.n_traces
        assert bool(jnp.isfinite(params["w"]).all())


class TestProductionStepSparse:
    @pytest.mark.slow
    def test_hlo_d_collectives_state_remap_and_zero_retrace(self):
        """The full shard_map production step with gossip_codec="topk_ef":
        exactly d collective-permutes (each the folded int8 wire), wire
        bytes <= 10% of the dense f32 build, the codec state donated and
        threading (nonzero residual after a round), one executable under
        churn + gate rotation, and the state's global layout row-remappable
        exactly like the params."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import sys; sys.path.insert(0, "src")
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import registry
            from repro.configs.base import ShapeConfig, ParallelConfig, DFLConfig
            from repro.launch import steps
            from repro.models import params as P
            from repro.telemetry import TraceCounter

            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = registry.reduced("qwen2.5-3b")
            shape = ShapeConfig("t", 64, 8, "train")
            dfl = DFLConfig(degree=2, round_plan="one_peer")

            def build(codec, telemetry=False):
                par = ParallelConfig(clients_per_pod=4, local_steps=2,
                                     grad_accum=2,
                                     gossip_impl="ppermute_packed",
                                     gossip_codec=codec,
                                     gossip_telemetry=telemetry)
                return steps.build_train_step(cfg, shape, mesh, par, dfl)

            setup = build("topk_ef")
            assert setup.init_codec_state is not None
            assert "codec_state" in setup.input_specs
            args = [P.shape_structs(setup.param_struct),
                    setup.input_specs["batch"], setup.input_specs["lr"],
                    setup.input_specs["alive"], setup.input_specs["gates"],
                    setup.input_specs["codec_state"]]
            text = setup.step_fn.lower(*args).as_text()
            d = setup.gossip_spec.degree
            perms = [l for l in text.splitlines()
                     if "collective_permute" in l]
            assert len(perms) == d, (len(perms), d)
            assert all("xi8>" in l for l in perms), "non-int8 top-k wire"

            wire = {c: build(c, telemetry=True).wire_bytes_per_round
                    for c in ("f32", "topk_ef")}
            ratio = wire["topk_ef"] / wire["f32"]
            assert ratio <= 0.10, ratio

            r = np.random.default_rng(0)
            structs = P.shape_structs(setup.param_struct)
            params = jax.tree.map(
                lambda s, sh: jax.device_put(
                    jnp.asarray(r.standard_normal(s.shape) * 0.02, s.dtype),
                    sh),
                structs, setup.in_shardings[0])
            batch = {k: jnp.zeros(v.shape, v.dtype)
                     for k, v in setup.input_specs["batch"].items()}
            cstate = setup.init_codec_state(params)
            n = setup.n_clients
            for rnd in range(3):
                alive = (r.random(n) > 0.3).astype(np.float32)
                if alive.sum() < 2:
                    alive[:] = 1.0
                gates = np.zeros(d, np.float32)
                gates[rnd % d] = 1.0
                params, _m, cstate = setup.step_fn(
                    params, batch, jnp.float32(0.01), jnp.asarray(alive),
                    jnp.asarray(gates), cstate)
            jax.block_until_ready(params)
            assert TraceCounter.cache_size(setup.step_fn) == 1
            resid = sum(float(jnp.sum(jnp.abs(c))) for c in cstate)
            assert resid > 0, "EF residual stayed zero"
            # the global codec-state layout leads with the device axes, the
            # per-client rows inside — a host-side old2new row take (the
            # splice-repair remap) is well-formed and byte-exact
            for spec, buf in zip(setup.input_specs["codec_state"], cstate):
                assert str(spec.dtype) == "float32"
                host = np.asarray(buf)
                perm = np.arange(host.shape[0])[::-1]
                np.testing.assert_array_equal(host[perm][perm], host)
            print("SPARSE_STEP_OK d=", d, "ratio=", round(ratio, 4))
        """)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, cwd=".")
        assert "SPARSE_STEP_OK" in out.stdout, out.stdout + out.stderr
