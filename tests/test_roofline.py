"""Roofline tooling tests: scan-aware HLO cost analyzer vs ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline import analysis, hw
from repro.roofline.hlo_cost import analyze_hlo


def _hlo(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


class TestHloCost:
    def test_plain_matmul_exact(self):
        t = _hlo(lambda x, w: x @ w,
                 jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 512), jnp.float32))
        assert analyze_hlo(t, 1).flops == 2 * 128 * 256 * 512

    def test_scan_multiplies_trip_count(self):
        """The reason this module exists: cost_analysis counts scan bodies once."""
        f = lambda x, w: jax.lax.scan(lambda h, _: (h @ w, None), x, None,
                                      length=10)[0]
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        compiled = jax.jit(f).lower(x, w).compile()
        ours = analyze_hlo(compiled.as_text(), 1).flops
        xla = analysis.cost_dict(compiled.cost_analysis()).get("flops", 0.0)
        assert ours == 10 * 2 * 64 * 64 * 64
        assert xla < ours / 5  # documents the undercount

    def test_nested_scan(self):
        def f(x, w):
            def outer(h, _):
                return jax.lax.scan(lambda g, __: (g @ w, None), h, None,
                                    length=5)[0], None
            return jax.lax.scan(outer, x, None, length=3)[0]
        t = _hlo(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                 jax.ShapeDtypeStruct((32, 32), jnp.float32))
        c = analyze_hlo(t, 1)
        assert c.flops == 15 * 2 * 32 * 32 * 32
        assert sorted(c.while_trip_counts) == [3, 5]

    def test_batched_einsum(self):
        t = _hlo(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                 jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                 jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
        assert analyze_hlo(t, 1).flops == 2 * 4 * 8 * 16 * 8

    def test_matches_cost_analysis_without_scans(self):
        def f(x, w1, w2):
            return jax.nn.relu(x @ w1) @ w2
        specs = [jax.ShapeDtypeStruct(s, jnp.float32)
                 for s in [(32, 64), (64, 128), (128, 16)]]
        compiled = jax.jit(f).lower(*specs).compile()
        ours = analyze_hlo(compiled.as_text(), 1).flops
        xla = analysis.cost_dict(compiled.cost_analysis()).get("flops", 0.0)
        # dot flops dominate; ours counts only dots, so ours <= xla <= ours+eps
        dots = 2 * 32 * 64 * 128 + 2 * 32 * 128 * 16
        assert ours == dots
        assert xla >= dots


class TestRooflineTerms:
    def test_terms_and_dominance(self):
        f = lambda x, w: x @ w
        specs = [jax.ShapeDtypeStruct((256, 256), jnp.float32)] * 2
        compiled = jax.jit(f).lower(*specs).compile()
        roof = analysis.roofline(compiled.cost_analysis(), compiled.as_text(), 1)
        assert roof.compute_s == pytest.approx(
            2 * 256**3 / hw.PEAK_FLOPS_BF16)
        assert roof.dominant in ("compute", "memory", "collective")
        # a tiny matmul is memory-bound on v5e
        assert roof.dominant == "memory"

    def test_model_flops_formulas(self):
        assert analysis.model_flops_train(1e9, 1000) == 6e12
        assert analysis.model_flops_prefill(1e9, 1000) == 2e12
        assert analysis.model_flops_decode(1e9, 8) == 16e9


class TestCollectiveParsing:
    def test_ppermute_bytes_counted(self):
        import subprocess
        import sys
        import textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            import sys; sys.path.insert(0, "src")
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.roofline.hlo_cost import analyze_hlo
            mesh = jax.make_mesh((4,), ("x",))
            def f(a):
                return jax.lax.ppermute(a, "x", [(i, (i+1) % 4) for i in range(4)])
            from repro.launch.mesh import shard_map
            fn = shard_map(f, mesh, in_specs=P("x"), out_specs=P("x"))
            t = jax.jit(fn).lower(
                jax.ShapeDtypeStruct((4, 1024), jnp.float32)).compile().as_text()
            c = analyze_hlo(t, 4)
            # per-device shard is (1, 1024) f32 = 4096 bytes on the wire
            assert c.collective_bytes["collective-permute"] == 4096, c
            print("PPERMUTE_BYTES_OK")
        """)
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, cwd=".")
        assert "PPERMUTE_BYTES_OK" in out.stdout, out.stdout + out.stderr
