"""Telemetry subsystem tests (ISSUE 8).

Covers the three layers and their composition:

* in-graph round metrics (`repro.telemetry.metrics` + the engine/step
  plumbing): consensus-residual oracle checks, byte-identical mixed outputs
  with telemetry on vs off, wire-byte accounting;
* the event stream (`repro.telemetry.events` / `log`): TraceCounter
  semantics, JSONL round-trip, event ordering under attack -> suspicion ->
  quarantine-splice repair;
* the report layer (`repro.telemetry.report`): bench-dir + run-log merge.

The slow lane asserts the PR's acceptance on the PRODUCTION step, in
lowered HLO: telemetry ON ships exactly d collective-permutes and zero
additional collectives of any kind vs OFF (f32 AND int8_block), executes
>= 3 rounds of straggler churn + one-peer gate rotation + active-cohort
rotation on ONE executable, and the step's params output is bitwise
independent of the telemetry flag.
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dfedavg, engine, failures as failures_lib, gossip, \
    topology
from repro.launch.elastic import ElasticTrainer
from repro.overlay import plan as plan_lib
from repro.telemetry import (TelemetryConfig, TelemetryLogger, TraceCounter,
                             read_jsonl)
from repro.telemetry import events as tel_events
from repro.telemetry import metrics as tel_metrics
from repro.telemetry import report as tel_report


def _tree(n, seed=0, shapes=((6, 5), (11,))):
    r = np.random.default_rng(seed)
    return {f"p{i}": jnp.asarray(r.standard_normal((n,) + s), jnp.float32)
            for i, s in enumerate(shapes)}


def _quad_loss(p, b):
    return jnp.mean(jnp.square(p["w"] - b["t"])), {}


# ------------------------------------------------------------ TraceCounter
class TestTraceCounter:
    def test_hit_counts_traces_not_calls(self):
        tc = TraceCounter("t")

        @jax.jit
        @tc.wrap
        def f(x):
            return x * 2

        for i in range(5):
            f(jnp.float32(i))
        assert tc.count == 1
        f(jnp.arange(3.0))  # new shape => one new trace
        assert tc.count == 2
        assert TraceCounter.cache_size(f) == 2

    def test_expect_raises_with_context(self):
        tc = TraceCounter("guard")
        tc.hit()
        tc.expect(1)
        with pytest.raises(AssertionError, match="guard.*expected 2"):
            tc.expect(2, what="churn must be data")

    def test_hits_emit_compile_events(self, tmp_path):
        log = TelemetryLogger(tmp_path / "t.jsonl")
        tc = TraceCounter("round", logger=log)
        tc.hit()
        tc.hit()
        log.close()
        recs = [r for r in read_jsonl(tmp_path / "t.jsonl")
                if r["kind"] == "compile"]
        assert [r["count"] for r in recs] == [1, 2]
        assert all(r["counter"] == "round" for r in recs)


# ------------------------------------------------------------ event stream
class TestEventStream:
    def test_jsonl_round_trip_and_validation(self, tmp_path):
        path = tmp_path / "run.jsonl"
        with TelemetryLogger(path, run="unit", n_clients=4) as log:
            log.event("note", msg="hello")
            with log.phase("gossip"):
                pass
            log.round(0, loss=1.5)
            log.repair({"dead": [2], "spliced": True, "n_after": 3})
        recs = read_jsonl(path)
        for r in recs:
            tel_events.validate_event(r)
        assert [r["kind"] for r in recs] == ["run", "note", "round", "repair"]
        assert [r["seq"] for r in recs] == list(range(len(recs)))
        rnd = recs[2]
        assert rnd["loss"] == 1.5 and "gossip" in rnd["phases"]

    def test_unknown_kind_rejected(self, tmp_path):
        with TelemetryLogger(tmp_path / "x.jsonl") as log:
            with pytest.raises(ValueError, match="kind"):
                log.event("bogus")

    def test_ordering_under_attack_and_quarantine_splice(self, tmp_path):
        """The ISSUE's event-ordering acceptance: one run where a scripted
        attacker activates, gets clipped (suspicion), is quarantined via the
        splice repair, and the re-jit lands as a compile event — all in
        stream order, with round records interleaved once per step."""
        n = 12
        path = tmp_path / "run.jsonl"
        logger = TelemetryLogger(path, run="quarantine", n_clients=n)
        atk = failures_lib.AttackPlan(
            n_clients=n, events=((2, (3,), "scale", 50.0),))
        tr = ElasticTrainer(
            overlay=topology.expander_overlay(n, 4, seed=0),
            loss_fn=_quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=2, lr=0.2, momentum=0.9),
            engine=engine.GossipEngineConfig(
                substrate="stacked", screen="norm_clip", clip_tau=3.0,
                telemetry=TelemetryConfig()),
            quarantine_rounds=2, attack_plan=atk, logger=logger)
        params = _tree(n, shapes=((64,),))
        params = {"w": params["p0"]}
        for rnd in range(6):
            m = tr.overlay.n
            params, _, _ = tr.observe_heartbeats(np.ones(m, np.float32),
                                                 params)
            batch = {"t": jnp.zeros((tr.overlay.n, 2, 64), jnp.float32)}
            params, _ = tr.step(params, batch, 0.2)
        logger.close()

        recs = read_jsonl(path)
        kinds = [r["kind"] for r in recs]
        assert kinds.count("round") == 6
        # the attacker was evicted by the quarantine splice: exactly one
        # repair, and therefore exactly two compiles (init + re-jit)
        assert kinds.count("repair") == 1 and kinds.count("compile") == 2
        assert tr.n_traces == 2
        seq_of = {k: [r["seq"] for r in recs if r["kind"] == k]
                  for k in set(kinds)}
        # activation precedes the first clip, which precedes the repair,
        # which precedes the re-jit — the stream tells the story in order
        assert seq_of["attack"][0] < seq_of["suspicion"][0] \
            < seq_of["repair"][0] < seq_of["compile"][1]
        repair = [r for r in recs if r["kind"] == "repair"][0]
        assert repair["quarantined"] == [3] and repair["spliced"]
        # round records carry the metric summaries
        rnd0 = [r for r in recs if r["kind"] == "round"][0]
        assert {"loss", "resid_sqnorm", "in_degree_mean",
                "phases"} <= set(rnd0)


# --------------------------------------------------------- engine metrics
class TestEngineMetrics:
    def _spec(self, n=10, d=4, seed=2):
        return gossip.make_gossip_spec(topology.expander_overlay(n, d,
                                                                 seed=seed))

    def test_stacked_consensus_residual_matches_oracle(self):
        spec = self._spec()
        x = _tree(10, seed=5)
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      telemetry=TelemetryConfig()), spec)
        alive = jnp.asarray(np.r_[np.ones(7), 0, 1, 1], jnp.float32)
        mixed, met = ex(x, alive=alive)
        # oracle: contrib-weighted squared distance to each mixed-in source
        _, contrib = gossip.raw_contrib_tables(spec, alive, None)
        w = np.asarray(contrib)                    # (n, 1 + S)
        flat = np.concatenate(
            [np.asarray(v).reshape(10, -1) for v in x.values()], axis=1)
        resid = np.zeros(10)
        for s, rf in enumerate(spec.recv_from):
            src = flat[np.asarray(rf)]
            resid += w[:, 1 + s] * np.sum((src - flat) ** 2, axis=1)
        np.testing.assert_allclose(np.asarray(met["resid_sqnorm"]), resid,
                                   rtol=1e-5)
        # in-degree drops for receivers of the dead client only
        np.testing.assert_allclose(np.asarray(met["in_degree"]),
                                   w[:, 1:].sum(axis=1), rtol=1e-6)
        # telemetry must not perturb the mixed output by a single bit
        ex0 = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked"), spec)
        plain = ex0(x, alive=alive)
        for k in x:
            assert np.array_equal(np.asarray(mixed[k]), np.asarray(plain[k]))

    @pytest.mark.parametrize("codec", ["f32", "int8_block"])
    def test_delayed_cells_mixed_output_bit_identical(self, codec):
        spec = self._spec()
        x = _tree(10, seed=7)
        mk = lambda tel: engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked", delay=1,
                                      codec=codec, telemetry=tel), spec)
        ex_t, ex_0 = mk(TelemetryConfig()), mk(None)
        st_t, st_0 = ex_t.init_state(x), ex_0.init_state(x)
        for _ in range(2):
            out = ex_t(x, state=st_t)
            mixed_t, st_t, met = out
            mixed_0, st_0 = ex_0(x, state=st_0)
            for k in x:
                assert np.array_equal(np.asarray(mixed_t[k]),
                                      np.asarray(mixed_0[k]))
            x = mixed_t
        assert float(met["resid_sqnorm"].sum()) >= 0.0
        assert np.isfinite(np.asarray(met["resid_sqnorm"])).all()

    def test_wire_bytes_per_round_counts_codec_bytes(self):
        spec = self._spec()
        x = _tree(10)
        from repro.core import packing
        pack = packing.make_stacked_pack_spec(
            jax.tree.map(lambda v: v[0], x))
        wires = {}
        for codec in ("f32", "int8_block"):
            ex = engine.build_gossip_executor(
                engine.GossipEngineConfig(substrate="shard_map", codec=codec),
                spec, axis_names="client", pack_spec=pack)
            wires[codec] = ex.wire_bytes_per_round()
        assert wires["f32"] > 0
        # int8 payload: ~4x smaller, plus the per-tile scale rows
        assert wires["f32"] / 4 <= wires["int8_block"] < wires["f32"] / 2
        # dense has no packed wire; per_leaf refuses the accounting
        exd = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="dense"), spec)
        assert exd.wire_bytes_per_round() == 0
        exl = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="per_leaf"), spec,
            axis_names="client")
        with pytest.raises(ValueError):
            exl.wire_bytes_per_round()

    def test_summarize_metrics_shapes(self):
        spec = self._spec()
        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      telemetry=TelemetryConfig()), spec)
        _, met = ex(_tree(10))
        met = dict(met)
        met["wire_bytes"] = jnp.float32(1234.0)
        met["attack_energy"] = jnp.float32(0.0)
        s = tel_metrics.summarize_metrics(met, n_clients=10)
        assert s["wire_bytes"] == 1234 and s["attack_energy"] == 0.0
        assert s["in_degree_mean"] == pytest.approx(4.0)
        assert len(s["sched_mass"]) == spec.degree
        assert tel_metrics.summarize_metrics(None) == {}
        assert tel_metrics.summarize_metrics({}) == {}


# ------------------------------------------------- elastic runtime guards
class TestElasticTelemetry:
    def test_zero_retraces_under_churn_gates_cohorts(self):
        n = 12
        tr = ElasticTrainer(
            overlay=topology.expander_overlay(n, 4, seed=0),
            loss_fn=_quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.9),
            plan=plan_lib.OnePeerPlan(),
            active_plan=plan_lib.RandomKActiveSet(k=8, seed=0),
            telemetry=TelemetryConfig())
        params = {"w": _tree(n, shapes=((32,),))["p0"]}
        r = np.random.default_rng(0)
        for rnd in range(4):
            alive = (r.random(n) > 0.2).astype(np.float32)
            params, _, _ = tr.observe_heartbeats(alive, params)
            batch = {"t": jnp.zeros((n, 2, 32), jnp.float32)}
            params, _ = tr.step(params, batch, 0.2)
        assert tr.n_traces == 1  # churn + gates + cohorts are all data
        assert tr.last_metrics is not None
        assert set(tr.last_metrics) == {"resid_sqnorm", "in_degree",
                                        "sched_contrib"}

    def test_telemetry_off_keeps_metrics_none(self):
        n = 8
        tr = ElasticTrainer(
            overlay=topology.expander_overlay(n, 4, seed=0),
            loss_fn=_quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.9))
        params = {"w": _tree(n, shapes=((16,),))["p0"]}
        params, _, _ = tr.observe_heartbeats(np.ones(n, np.float32), params)
        params, _ = tr.step(params,
                            {"t": jnp.zeros((n, 2, 16), jnp.float32)}, 0.2)
        assert tr.last_metrics is None and tr.n_traces == 1

    def test_validation_rejects_unsupported_compositions(self):
        ov = topology.expander_overlay(8, 4, seed=0)
        dcfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.9)
        with pytest.raises(ValueError, match="step_builder"):
            ElasticTrainer(overlay=ov, loss_fn=_quad_loss, dcfg=dcfg,
                           step_builder=lambda spec, tr: None,
                           telemetry=TelemetryConfig())
        # blocked + telemetry is now a supported (metrics-only) cell, but
        # Chebyshev sub-rounds still don't ride the blocked substrate
        with pytest.raises(ValueError, match="sub_rounds > 1"):
            ElasticTrainer(overlay=ov, loss_fn=_quad_loss, dcfg=dcfg,
                           engine=engine.GossipEngineConfig(
                               substrate="blocked", block=8, sub_rounds=2))
        with pytest.raises(TypeError, match="TelemetryConfig"):
            ElasticTrainer(overlay=ov, loss_fn=_quad_loss, dcfg=dcfg,
                           telemetry=True)


# ---------------------------------------------- blocked-substrate metrics
class TestBlockedTelemetry:
    """Satellite: the metrics-only blocked telemetry cell. Consensus
    residual + in-degree are measured on the device-local (B,)-leading rows
    the blocked round already gathers; the island's P("clients") out_spec
    concatenates them back to the (n,)-stacked layout. Validated against
    the stacked-telemetry oracle, with the zero-extra-collectives contract
    asserted in lowered HLO (slow lane)."""

    def _blocked_island(self, spec, block, tel):
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import shard_map

        ex = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="blocked", block=block,
                                      telemetry=tel),
            spec, axis_names="clients")
        mesh = Mesh(np.asarray(jax.devices()[:spec.n_clients // block]),
                    ("clients",))

        def body(t, a, g):
            return ex(t, alive=a, gates=g)

        out_specs = ((P("clients"), P("clients")) if tel is not None
                     else P("clients"))
        return jax.jit(shard_map(body, mesh,
                                 in_specs=(P("clients"), P(), P()),
                                 out_specs=out_specs))

    def test_blocked_metrics_match_stacked_oracle(self):
        n = 12
        spec = gossip.make_gossip_spec(topology.expander_overlay(n, 4,
                                                                 seed=0))
        x = _tree(n, seed=3)
        stacked = engine.build_gossip_executor(
            engine.GossipEngineConfig(substrate="stacked",
                                      telemetry=TelemetryConfig()), spec)
        fn = self._blocked_island(spec, n, TelemetryConfig())
        for t in range(3):
            alive = (np.random.default_rng(t).random(n) > 0.3
                     ).astype(np.float32)
            if alive.sum() < 2:
                alive[:] = 1
            gates = np.zeros(spec.degree, np.float32)
            gates[t % spec.degree] = 1.0
            ref_mixed, ref = stacked(x, alive=jnp.asarray(alive),
                                     gates=jnp.asarray(gates))
            got_mixed, met = fn(x, jnp.asarray(alive), jnp.asarray(gates))
            for k in x:   # telemetry-on blocked round == stacked round
                np.testing.assert_array_equal(np.asarray(got_mixed[k]),
                                              np.asarray(ref_mixed[k]))
            assert met["resid_sqnorm"].shape == (n,)
            assert met["sched_contrib"].shape == (n, spec.degree)
            np.testing.assert_allclose(np.asarray(met["resid_sqnorm"]),
                                       np.asarray(ref["resid_sqnorm"]),
                                       rtol=2e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(met["in_degree"]),
                                       np.asarray(ref["in_degree"]),
                                       rtol=1e-6)
            np.testing.assert_allclose(np.asarray(met["sched_contrib"]),
                                       np.asarray(ref["sched_contrib"]),
                                       rtol=1e-6)

    def test_trainer_blocked_telemetry_zero_retraces(self):
        n = 8
        tr = ElasticTrainer(
            overlay=topology.expander_overlay(n, 4, seed=0),
            loss_fn=_quad_loss,
            dcfg=dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.9),
            plan=plan_lib.OnePeerPlan(),
            engine=engine.GossipEngineConfig(
                substrate="blocked", block=n,
                telemetry=TelemetryConfig()))
        params = {"w": _tree(n, shapes=((16,),))["p0"]}
        r = np.random.default_rng(0)
        for rnd in range(4):
            alive = (r.random(n) > 0.2).astype(np.float32)
            params, _, _ = tr.observe_heartbeats(alive, params)
            params, _ = tr.step(
                params, {"t": jnp.zeros((n, 2, 16), jnp.float32)}, 0.2)
        assert tr.n_traces == 1  # metrics + churn + gates are all data
        met = tr.last_metrics
        assert set(met) == {"resid_sqnorm", "in_degree", "sched_contrib"}
        assert met["resid_sqnorm"].shape == (n,)
        assert met["in_degree"].shape == (n,)
        assert met["sched_contrib"].shape == (n, tr.overlay.degree)
        for v in met.values():
            assert np.isfinite(np.asarray(v)).all()

    @pytest.mark.slow
    def test_blocked_telemetry_ships_zero_extra_collectives(self):
        """Acceptance, in lowered HLO on a real 4-device blocked layout:
        telemetry ON ships exactly the same count of EVERY collective kind
        as OFF (the cross-block permutes included), and the cross-device
        metrics still match the stacked oracle."""
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import Mesh, PartitionSpec as P
            from repro.core import engine, gossip, topology
            from repro.launch.mesh import shard_map
            from repro.telemetry import TelemetryConfig

            n, b = 16, 4
            spec = gossip.make_gossip_spec(
                topology.expander_overlay(n, 4, seed=0))
            r = np.random.default_rng(0)
            tree = {"a": jnp.asarray(r.standard_normal((n, 6, 5)),
                                     jnp.float32),
                    "b": jnp.asarray(r.standard_normal((n, 11)),
                                     jnp.float32)}
            alive = jnp.asarray((np.random.default_rng(1).random(n) > 0.25)
                                .astype(np.float32))
            gates = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
            mesh = Mesh(np.asarray(jax.devices()[: n // b]), ("clients",))
            texts, outs = {}, {}
            for tel in (False, True):
                ex = engine.build_gossip_executor(
                    engine.GossipEngineConfig(
                        substrate="blocked", block=b,
                        telemetry=TelemetryConfig() if tel else None),
                    spec, axis_names="clients")
                def body(t, a, g, ex=ex):
                    return ex(t, alive=a, gates=g)
                out_specs = ((P("clients"), P("clients")) if tel
                             else P("clients"))
                fn = jax.jit(shard_map(body, mesh,
                                       in_specs=(P("clients"), P(), P()),
                                       out_specs=out_specs))
                texts[tel] = fn.lower(tree, alive, gates).as_text()
                outs[tel] = fn(tree, alive, gates)
            KINDS = ("collective-permute", "all-reduce", "all-gather",
                     "reduce-scatter", "all-to-all")
            counts = {tel: {k: texts[tel].count(k) for k in KINDS}
                      for tel in (False, True)}
            assert counts[True] == counts[False], counts
            perms = [l for l in texts[True].splitlines()
                     if "collective_permute" in l]
            assert len(perms) > 0  # the expander DOES cross blocks
            mixed_t, met = outs[True]
            for k in tree:
                assert np.array_equal(np.asarray(mixed_t[k]),
                                      np.asarray(outs[False][k]))
            ex_s = engine.build_gossip_executor(
                engine.GossipEngineConfig(substrate="stacked",
                                          telemetry=TelemetryConfig()),
                spec)
            _, ref = ex_s(tree, alive=alive, gates=gates)
            np.testing.assert_allclose(np.asarray(met["resid_sqnorm"]),
                                       np.asarray(ref["resid_sqnorm"]),
                                       rtol=2e-5, atol=1e-6)
            np.testing.assert_allclose(np.asarray(met["in_degree"]),
                                       np.asarray(ref["in_degree"]),
                                       rtol=1e-6)
            print("BLOCKED_TEL_OK n_perms=", len(perms))
        """)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, cwd=".")
        assert "BLOCKED_TEL_OK" in out.stdout, out.stdout + out.stderr


# ------------------------------------------------------------- the report
class TestReport:
    def test_build_summary_merges_benches_and_runs(self, tmp_path):
        bench = tmp_path / "bench"
        bench.mkdir()
        (bench / "engine.json").write_text(json.dumps(
            {"bench": "engine", "rounds_per_sec": 12.5, "n_traces": 1}))
        (bench / "telemetry.json").write_text(json.dumps(
            {"bench": "telemetry",
             "wire_bytes": {"f32": 262144, "int8_block": 65792},
             "cells": [{"label": "on", "rounds_per_sec": 10.0}]}))
        log = tmp_path / "run.jsonl"
        with TelemetryLogger(log, run="demo") as lg:
            lg.round(0, loss=2.0, resid_sqnorm=9.0)
            lg.round(1, loss=1.0, resid_sqnorm=4.0)
            lg.repair({"dead": [1], "spliced": True, "n_after": 7})
        out = tmp_path / "summary.json"
        summary = tel_report.build_summary(bench_dir=str(bench),
                                           logs=[str(log)], out=str(out))
        assert summary["wire_bytes_per_round"] == {"f32": 262144,
                                                   "int8_block": 65792}
        assert summary["retraces"]["engine/engine"] == 1
        assert any(v["rounds_per_sec"] == 12.5
                   for v in summary["rounds_per_sec"].values())
        run = summary["runs"][0]
        assert run["rounds"] == 2 and run["repairs"] == 1
        assert run["consensus"] == [[0, 9.0], [1, 4.0]]
        assert json.loads(out.read_text()) == summary


# ---------------------------------------- acceptance on the production step
class TestProductionStepTelemetry:
    def _run(self, code):
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, cwd=".")
        assert "OK" in out.stdout, out.stdout + out.stderr
        return out.stdout

    @pytest.mark.slow
    def test_on_ships_d_collectives_and_zero_extra(self):
        """Acceptance, in lowered HLO, f32 AND int8_block: with telemetry
        ON the step still ships exactly d collective-permutes and the count
        of EVERY collective kind equals the telemetry-OFF build — the
        metrics are free-riding on values the round already moves."""
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import sys; sys.path.insert(0, "src")
            import jax
            from repro.configs import registry
            from repro.configs.base import ShapeConfig, ParallelConfig, DFLConfig
            from repro.launch import steps
            from repro.models import params as P

            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = registry.reduced("qwen2.5-3b")
            shape = ShapeConfig("t", 64, 8, "train")
            KINDS = ("collective-permute", "all-reduce", "all-gather",
                     "reduce-scatter", "all-to-all")
            for gi, delay, codec in (("ppermute_packed", 0, "auto"),
                                     ("ppermute_packed_async", 1,
                                      "int8_block")):
                texts = {}
                for tel in (False, True):
                    par = ParallelConfig(clients_per_pod=4, local_steps=2,
                                         grad_accum=2, gossip_impl=gi,
                                         gossip_delay=delay,
                                         gossip_codec=codec,
                                         gossip_telemetry=tel)
                    setup = steps.build_train_step(cfg, shape, mesh, par,
                                                   DFLConfig(degree=2))
                    args = [P.shape_structs(setup.param_struct),
                            setup.input_specs["batch"],
                            setup.input_specs["lr"],
                            setup.input_specs["alive"],
                            setup.input_specs["gates"]]
                    if "inflight" in setup.input_specs:
                        args.append(setup.input_specs["inflight"])
                    texts[tel] = setup.step_fn.lower(*args).as_text()
                    if tel:
                        assert setup.wire_bytes_per_round > 0
                d = setup.gossip_spec.degree
                counts = {tel: {k: texts[tel].count(k) for k in KINDS}
                          for tel in (False, True)}
                assert counts[True] == counts[False], (gi, codec, counts)
                for tel in (False, True):
                    perms = [l for l in texts[tel].splitlines()
                             if "collective_permute" in l]
                    assert len(perms) == d, (gi, codec, tel, len(perms), d)
            print("TELEMETRY_HLO_OK")
        """)

    @pytest.mark.slow
    def test_one_executable_and_bitwise_params_over_rounds(self):
        """Acceptance, executed: >= 3 rounds of straggler churn + one-peer
        gate rotation + active-cohort rotation reuse ONE executable with
        telemetry ON (f32 and int8_block), the metrics arrive finite with
        the exact static wire-byte constant, and the params trajectory is
        BITWISE identical to the telemetry-OFF run."""
        self._run("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
            import sys; sys.path.insert(0, "src")
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import registry
            from repro.configs.base import ShapeConfig, ParallelConfig, DFLConfig
            from repro.launch import steps
            from repro.models import params as P
            from repro.telemetry import TraceCounter

            mesh = jax.make_mesh((4, 4), ("data", "model"))
            cfg = registry.reduced("qwen2.5-3b")
            shape = ShapeConfig("t", 16, 4, "train")
            dfl = DFLConfig(degree=2, round_plan="one_peer")

            def drive(codec, delay, tel, rounds=4):
                par = ParallelConfig(clients_per_pod=4, local_steps=1,
                                     grad_accum=1,
                                     gossip_impl="ppermute_packed_async",
                                     gossip_delay=delay, gossip_codec=codec,
                                     gossip_telemetry=tel)
                setup = steps.build_train_step(cfg, shape, mesh, par, dfl)
                r = np.random.default_rng(0)
                structs = P.shape_structs(setup.param_struct)
                params = jax.tree.map(
                    lambda s, sh: jax.device_put(
                        jnp.asarray(r.standard_normal(s.shape) * 0.02,
                                    s.dtype), sh),
                    structs, setup.in_shardings[0])
                inflight = (setup.init_inflight(params)
                            if "inflight" in setup.input_specs else None)
                batch = {k: jnp.zeros(v.shape, v.dtype)
                         for k, v in setup.input_specs["batch"].items()}
                n = setup.gossip_spec.n_clients
                d = setup.gossip_spec.degree
                mets = []
                for rnd in range(rounds):
                    alive = (r.random(n) > 0.2).astype(np.float32)
                    alive *= (np.arange(n) % 2 == rnd % 2)  # cohorts
                    if alive.sum() < 2:
                        alive[:] = 1.0
                    gates = np.zeros(d, np.float32)
                    gates[rnd % d] = 1.0                    # one-peer
                    args = [params, batch, jnp.float32(0.01),
                            jnp.asarray(alive), jnp.asarray(gates)]
                    if inflight is not None:
                        args.append(inflight)
                    out = setup.step_fn(*args)
                    params, metrics = out[0], out[1]
                    if inflight is not None:
                        inflight = out[2]
                    mets.append(metrics)
                assert TraceCounter.cache_size(setup.step_fn) == 1, codec
                return setup, params, mets

            for codec, delay in (("auto", 0), ("int8_block", 1)):
                setup, p_on, mets = drive(codec, delay, True)
                _, p_off, mets_off = drive(codec, delay, False)
                for a, b in zip(jax.tree.leaves(p_on), jax.tree.leaves(p_off)):
                    assert np.array_equal(np.asarray(a), np.asarray(b))
                assert all("telemetry" not in m for m in mets_off)
                tel = mets[-1]["telemetry"]
                assert int(np.asarray(tel["wire_bytes"]).max()) \\
                    == setup.wire_bytes_per_round
                for k in ("resid_sqnorm", "in_degree", "sched_contrib"):
                    assert np.isfinite(np.asarray(tel[k])).all(), (codec, k)
            print("TELEMETRY_STEP_EXEC_OK")
        """)


class TestRoundSampling:
    """TelemetryLogger(round_every=k): sampled round records."""

    def test_default_stream_unchanged(self):
        a = TelemetryLogger(run="a")
        b = TelemetryLogger(run="b", round_every=1)
        for rnd in range(4):
            a.round(rnd, loss=float(rnd))
            b.round(rnd, loss=float(rnd))
        strip = lambda recs: [{k: v for k, v in r.items() if k != "ts"}
                              for r in recs if r["kind"] == "round"]
        assert strip(a.records) == strip(b.records)

    def test_round_every_samples_and_peeks(self):
        log = TelemetryLogger(round_every=3)
        assert [log.wants_round(r) for r in range(6)] == [
            True, False, False, True, False, False]
        for rnd in range(7):
            log.round(rnd, loss=float(rnd))
        rounds = [r["round"] for r in log.of_kind("round")]
        assert rounds == [0, 3, 6]

    def test_off_rounds_accumulate_phases_into_the_next_record(self):
        log = TelemetryLogger(round_every=2)
        for rnd in range(1, 3):           # rnd 1 skipped, rnd 2 emitted
            with log.phase("work"):
                pass
            log.round(rnd, loss=0.0)
        (rec,) = log.of_kind("round")
        assert rec["round"] == 2
        assert "work" in rec["phases"]    # both rounds' seconds folded in

    def test_round_every_validated(self):
        with pytest.raises(ValueError, match="round_every"):
            TelemetryLogger(round_every=0)
