"""Pallas kernel tests: interpret-mode kernel body vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_sgdm import ops as sgdm_ops
from repro.kernels.fused_sgdm import ref as sgdm_ref
from repro.kernels.gossip_mix import ops as mix_ops
from repro.kernels.gossip_mix import ref as mix_ref
from repro.kernels.quant_gossip import ops as q_ops
from repro.kernels.quant_gossip import ref as q_ref

SHAPES = [(1024,), (255,), (8, 128), (3, 7, 129), (2, 2048)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.standard_normal(shape), dtype)


class TestGossipMixKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("k", [2, 5])
    def test_matches_ref(self, shape, dtype, k):
        stack = _rand((k,) + shape, dtype)
        w = _rand((k,), jnp.float32, seed=1)
        got = mix_ops.gossip_mix(stack, w, impl="pallas_interpret")
        want = mix_ref.gossip_mix(stack, w)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5, atol=1e-5)

    def test_weighted_sum_semantics(self):
        stack = jnp.stack([jnp.ones(100), 2 * jnp.ones(100), 3 * jnp.ones(100)])
        w = jnp.asarray([0.5, 0.25, 0.25])
        out = mix_ops.gossip_mix(stack, w, impl="pallas_interpret")
        np.testing.assert_allclose(out, 1.75, rtol=1e-6)


class TestFusedSGDMKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, shape, dtype):
        w, v, g = (_rand(shape, dtype, s) for s in (0, 1, 2))
        got = sgdm_ops.sgdm(w, v, g, 0.01, 0.9, impl="pallas_interpret")
        want = sgdm_ref.sgdm(w, v, g, 0.01, 0.9)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6,
                                       atol=1e-6)

    def test_pytree_wrapper_matches_momentum_update(self):
        from repro.core.dfedavg import momentum_update
        tree = {"a": _rand((64, 64), jnp.float32),
                "b": {"c": _rand((33,), jnp.float32, 1)}}
        vel = jax.tree.map(lambda x: x * 0.1, tree)
        grads = jax.tree.map(lambda x: x * 0.01, tree)
        got_p, got_v = sgdm_ops.sgdm_update(tree, vel, grads, 0.1, 0.9,
                                            impl="pallas_interpret")
        want_p, want_v = momentum_update(tree, vel, grads, 0.1, 0.9)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6),
                     got_p, want_p)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-6),
                     got_v, want_v)


class TestQuantGossipKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_quant_roundtrip_error_bounded(self, shape):
        x = _rand(shape, jnp.float32)
        q, scale = q_ops.quantize_int8(x, impl="pallas_interpret")
        back = q_ops.dequantize_int8(q, scale)
        amax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(back - x))) <= amax / 127.0 + 1e-7

    @pytest.mark.parametrize("shape", SHAPES[:3])
    def test_quant_matches_ref(self, shape):
        x = _rand(shape, jnp.float32, 3)
        qk, sk = q_ops.quantize_int8(x, impl="pallas_interpret")
        qr, sr = q_ops.quantize_int8(x, impl="ref")
        assert float(sk) == pytest.approx(float(sr))
        np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))

    def test_dequant_accumulate_matches_ref(self):
        x = _rand((500,), jnp.float32)
        acc = _rand((500,), jnp.float32, 1)
        q, s = q_ops.quantize_int8(x)
        got = q_ops.dequant_accumulate(q, s, 0.3, acc, impl="pallas_interpret")
        want = q_ref.dequant_accumulate(q, s, jnp.asarray(0.3), acc)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    def test_quantized_gossip_close_to_exact(self):
        """End-to-end: int8 gossip stays within quantization error of exact."""
        from repro.core import compression, gossip, topology
        ov = topology.expander_overlay(8, 4, seed=0)
        spec = gossip.make_gossip_spec(ov)
        x = {"w": _rand((8, 256), jnp.float32)}
        exact = gossip.mix_schedules(x, spec)["w"]
        # emulate the quantized path on the stacked axis
        q, s = compression.quantize_int8(x["w"])
        deq = compression.dequantize_int8(q, s)
        approx = gossip.mix_schedules({"w": deq}, spec)["w"]
        err = float(jnp.max(jnp.abs(exact - approx)))
        amax = float(jnp.max(jnp.abs(x["w"])))
        assert err <= 2 * amax / 127.0


class TestTrimmedMixKernel:
    """Coordinate-wise trimmed-mean mix (the Byzantine screen's kernel):
    fast semantic tests run the jnp oracle; the interpret-mode
    comparison-network parity sweeps are marked slow (the O(K^2) rank
    network is expensive under the Pallas interpreter)."""

    def _tables(self, k, seed=0):
        r = np.random.default_rng(seed)
        u = jnp.asarray(np.abs(r.standard_normal(k)) + 0.1, jnp.float32)
        live = jnp.ones(k, jnp.float32)
        return u, live

    @pytest.mark.slow
    @pytest.mark.parametrize("shape", SHAPES[:3])
    @pytest.mark.parametrize("trim", [0, 1, 2])
    def test_interpret_matches_ref(self, shape, trim):
        k = 6
        stack = _rand((k,) + shape, jnp.float32)
        u, live = self._tables(k)
        live = live.at[2].set(0.0)  # one dead sender in the sweep
        got = mix_ops.gossip_mix_trimmed(stack, u, live, trim=trim,
                                         impl="pallas_interpret")
        want = mix_ref.trimmed_mix(stack, u, live, trim)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.slow
    @pytest.mark.parametrize("n_s", [1, 2])
    def test_quant_interpret_matches_ref(self, n_s):
        """Dequant-side variant: int8 payloads with per-buffer (n_s=1) or
        per-row-block scales decoded inside the fused trim pass."""
        from repro.kernels.gossip_mix import kernel as mix_k
        k, rows = 5, 2 * mix_k.DEFAULT_BLOCK_ROWS
        r = np.random.default_rng(4)
        fresh = jnp.asarray(r.standard_normal((rows, mix_k.LANE)),
                            jnp.float32)
        q = jnp.asarray(r.integers(-127, 128, (k - 1, rows, mix_k.LANE)),
                        jnp.int8)
        scales = jnp.asarray(np.abs(r.standard_normal((k - 1, n_s))) * 0.01
                             + 1e-4, jnp.float32)
        u, live = self._tables(k, seed=5)
        got = mix_ops.gossip_mix_trimmed_quant_packed(
            fresh, q, scales, u, live, trim=1,
            block_rows=mix_k.DEFAULT_BLOCK_ROWS, impl="pallas_interpret")
        want = mix_ref.trimmed_mix_quant(fresh, q, scales, u, live, 1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_dead_and_gated_entries_invisible_to_order_stats(self):
        """An arbitrarily large value on a non-live entry must not displace
        which live values get trimmed (exclusion, not just zero-weighting)."""
        shape = (37,)
        stack = _rand((5,) + shape, jnp.float32, seed=7)
        u, live = self._tables(5, seed=7)
        live = live.at[3].set(0.0)
        poisoned = stack.at[3].set(1e6)
        a = mix_ref.trimmed_mix(stack, u, live, 1)
        b = mix_ref.trimmed_mix(poisoned, u, live, 1)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_trim0_is_renormalized_masked_mean(self):
        stack = _rand((4, 65), jnp.float32, seed=8)
        u, live = self._tables(4, seed=8)
        live = live.at[1].set(0.0)
        got = mix_ref.trimmed_mix(stack, u, live, 0)
        ul = np.asarray(u) * np.asarray(live)
        want = (ul[:, None] * np.asarray(stack)).sum(0) / ul.sum()
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)

    def test_trim_clamped_so_one_value_survives(self):
        """trim >= half the live count clamps to floor((n_live-1)/2):
        with 3 live entries and trim=5 the median survives."""
        stack = jnp.asarray([[1.0], [5.0], [100.0]], jnp.float32)
        u = jnp.ones(3, jnp.float32)
        live = jnp.ones(3, jnp.float32)
        got = mix_ref.trimmed_mix(stack, u, live, 5)
        np.testing.assert_allclose(np.asarray(got), [5.0], rtol=1e-6)

    def test_dead_self_identity_fallback(self):
        stack = _rand((4, 12), jnp.float32, seed=9)
        u, live = self._tables(4, seed=9)
        live = live.at[0].set(0.0)
        got = mix_ref.trimmed_mix(stack, u, live, 1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(stack[0]))

    def test_packed_sqnorms_interpret_matches_ref(self):
        from repro.kernels.gossip_mix import kernel as mix_k
        rows = 2 * mix_k.DEFAULT_BLOCK_ROWS
        buf = _rand((rows, mix_k.LANE), jnp.float32, seed=11)
        got = mix_ops.packed_sqnorms(buf, impl="pallas_interpret")
        want = mix_ref.block_sqnorms(buf, mix_k.DEFAULT_BLOCK_ROWS)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)
        assert got.shape == (2,)


class TestScatterAccumulateKernel:
    """Fused sparse scatter-accumulate (the topk_ef codec's reduce)."""

    def _sparse(self, rows, k, seed=0):
        r = np.random.default_rng(seed)
        acc = jnp.asarray(r.standard_normal((rows, 128)), jnp.float32)
        idx = jnp.asarray(r.choice(rows * 128, size=k, replace=False),
                          jnp.int32)
        vals = jnp.asarray(r.standard_normal(k), jnp.float32)
        return vals, idx, acc

    @pytest.mark.parametrize("rows,k", [(8, 16), (24, 100), (16, 1)])
    def test_interpret_matches_ref(self, rows, k):
        vals, idx, acc = self._sparse(rows, k, seed=rows + k)
        got = q_ops.scatter_accumulate_packed(
            vals, idx, 0.7, acc, block_rows=4, impl="pallas_interpret")
        want = q_ref.scatter_accumulate(vals, idx, jnp.asarray(0.7), acc)
        # per-element scalar RMW in the kernel vs one batched .at[].add in
        # the oracle: same math, different reduction order -> allclose
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_alive_weight_folds_into_the_pass(self):
        vals, idx, acc = self._sparse(8, 12, seed=3)
        dead = q_ops.scatter_accumulate_packed(
            vals, idx, 0.5, acc, alive=jnp.float32(0.0),
            block_rows=4, impl="pallas_interpret")
        np.testing.assert_array_equal(np.asarray(dead), np.asarray(acc))
        live = q_ops.scatter_accumulate_packed(
            vals, idx, 0.5, acc, alive=jnp.float32(1.0),
            block_rows=4, impl="pallas_interpret")
        want = q_ref.scatter_accumulate(vals, idx, jnp.asarray(0.5), acc)
        np.testing.assert_allclose(np.asarray(live), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_wire_fold_roundtrip_exact(self):
        """values + int32 indices -> one int8 wire -> back, bitwise."""
        from repro.core import packing
        vals, idx, _ = self._sparse(16, 37, seed=5)
        wire = q_ops.fold_topk_into_wire(vals, idx)
        assert wire.dtype == jnp.int8
        assert wire.shape == (packing.topk_wire_rows(37), packing.LANE)
        v2, i2 = q_ops.split_topk_wire(wire, 37)
        np.testing.assert_array_equal(np.asarray(v2), np.asarray(vals))
        np.testing.assert_array_equal(np.asarray(i2), np.asarray(idx))
