"""Elastic runtime integration: stragglers, permanent failure repair, resume."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import dfedavg, failures
from repro.core.topology import expander_overlay
from repro.launch.elastic import ElasticTrainer


def quad_loss(params, batch):
    loss = jnp.mean(jnp.square(params["w"] - batch["target"]))
    return loss, {}


def _batches(targets, k):
    return {"target": jnp.broadcast_to(targets[:, None],
                                       (targets.shape[0], k, targets.shape[1]))}


def test_elastic_full_lifecycle(tmp_path):
    """Train -> straggler round -> permanent failure -> repair -> resume."""
    n, dim = 12, 4
    r = np.random.default_rng(0)
    targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
    cfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.3, momentum=0.5)
    trainer = ElasticTrainer(
        overlay=expander_overlay(n, 4, seed=0), loss_fn=quad_loss, dcfg=cfg,
        ckpt=CheckpointManager(str(tmp_path), save_every=1),
        straggler_rounds=1, failure_rounds=2)
    params = {"w": jnp.zeros((n, dim))}

    # rounds 0-1: all healthy
    for rnd in range(2):
        params, _ = trainer.observe_heartbeats(np.ones(n), params)
        params, _losses = trainer.step(params, _batches(targets, 2), 0.3)
        trainer.checkpoint(rnd, params)
    assert trainer.n_clients == n

    # rounds 2-3: client 5 misses heartbeats -> straggler, then dead
    alive = np.ones(n); alive[5] = 0
    params, _ = trainer.observe_heartbeats(alive, params)  # straggler
    assert trainer.n_clients == n
    params, _losses = trainer.step(params, _batches(targets, 2), 0.3)

    params, _ = trainer.observe_heartbeats(alive, params)  # declared dead
    assert trainer.n_clients == n - 1
    assert trainer.repairs and trainer.repairs[0]["dead"] == [5]
    assert params["w"].shape[0] == n - 1

    surv_targets = jnp.concatenate([targets[:5], targets[6:]])
    params, _losses = trainer.step(params, _batches(surv_targets, 2), 0.3)
    trainer.checkpoint(3, params)
    assert bool(jnp.isfinite(params["w"]).all())

    # crash-resume: restore survivors' state from checkpoint
    m = CheckpointManager(str(tmp_path))
    restored, meta = m.restore({"w": jnp.zeros((n - 1, dim))})
    assert meta["n_clients"] == n - 1
    np.testing.assert_allclose(restored["w"], params["w"], rtol=1e-6)


def test_straggler_round_keeps_progress():
    """Straggler rounds must not corrupt the healthy clients' consensus."""
    n, dim = 8, 3
    targets = jnp.zeros((n, dim))
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.5, momentum=0.0)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=1),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=99)
    params = {"w": jnp.ones((n, dim))}
    alive = np.ones(n); alive[0] = 0
    for rnd in range(6):
        params, _ = trainer.observe_heartbeats(alive, params)
        params, _ = trainer.step(params, _batches(targets, 1), 0.5)
    # healthy clients converge toward 0 despite the dead neighbor
    healthy = params["w"][1:]
    assert float(jnp.max(jnp.abs(healthy))) < 0.2


def test_failure_plan_and_masks():
    plan = failures.sample_failures(20, 0.2, at_round=5, seed=0)
    assert len(plan.dead_at(4)) == 0
    assert len(plan.dead_at(5)) == 4
    mask = plan.alive_mask(10)
    assert mask.sum() == 16
