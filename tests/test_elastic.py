"""Elastic runtime integration: stragglers, permanent failure repair, resume.

The elastic trainer now rides the packed gossip engine: the alive mask is a
traced step argument (straggler churn must cause ZERO retraces) and repairs
return the real survivor permutation (per-client state must follow its
owner through the index compaction).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import dfedavg, engine, failures, gossip
from repro.core.topology import expander_overlay
from repro.launch.elastic import ElasticTrainer


def quad_loss(params, batch):
    loss = jnp.mean(jnp.square(params["w"] - batch["target"]))
    return loss, {}


def _batches(targets, k):
    return {"target": jnp.broadcast_to(targets[:, None],
                                       (targets.shape[0], k, targets.shape[1]))}


def test_elastic_full_lifecycle(tmp_path):
    """Train -> straggler round -> permanent failure -> repair -> resume."""
    n, dim = 12, 4
    r = np.random.default_rng(0)
    targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
    cfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.3, momentum=0.5)
    trainer = ElasticTrainer(
        overlay=expander_overlay(n, 4, seed=0), loss_fn=quad_loss, dcfg=cfg,
        ckpt=CheckpointManager(str(tmp_path), save_every=1),
        straggler_rounds=1, failure_rounds=2)
    params = {"w": jnp.zeros((n, dim))}

    # rounds 0-1: all healthy
    for rnd in range(2):
        params, _, _ = trainer.observe_heartbeats(np.ones(n), params)
        params, _losses = trainer.step(params, _batches(targets, 2), 0.3)
        trainer.checkpoint(rnd, params)
    assert trainer.n_clients == n

    # rounds 2-3: client 5 misses heartbeats -> straggler, then dead
    alive = np.ones(n)
    alive[5] = 0
    params, _, old2new = trainer.observe_heartbeats(alive, params)  # straggler
    assert trainer.n_clients == n and old2new is None
    params, _losses = trainer.step(params, _batches(targets, 2), 0.3)

    params, _, old2new = trainer.observe_heartbeats(alive, params)  # dead
    assert trainer.n_clients == n - 1
    assert trainer.repairs and trainer.repairs[0]["dead"] == [5]
    assert params["w"].shape[0] == n - 1
    # the REAL survivor permutation, not an identity map
    assert old2new is not None and old2new[5] == -1
    np.testing.assert_array_equal(
        old2new, np.asarray([0, 1, 2, 3, 4, -1, 5, 6, 7, 8, 9, 10]))

    surv_targets = jnp.concatenate([targets[:5], targets[6:]])
    params, _losses = trainer.step(params, _batches(surv_targets, 2), 0.3)
    trainer.checkpoint(3, params)
    assert bool(jnp.isfinite(params["w"]).all())

    # crash-resume: restore survivors' state from checkpoint
    m = CheckpointManager(str(tmp_path))
    restored, meta = m.restore({"w": jnp.zeros((n - 1, dim))})
    assert meta["n_clients"] == n - 1
    np.testing.assert_allclose(restored["w"], params["w"], rtol=1e-6)


def test_straggler_round_keeps_progress():
    """Straggler rounds must not corrupt the healthy clients' consensus."""
    n, dim = 8, 3
    targets = jnp.zeros((n, dim))
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.5, momentum=0.0)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=1),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=99)
    params = {"w": jnp.ones((n, dim))}
    alive = np.ones(n)
    alive[0] = 0
    for rnd in range(6):
        params, _, _ = trainer.observe_heartbeats(alive, params)
        params, _ = trainer.step(params, _batches(targets, 1), 0.5)
    # healthy clients converge toward 0 despite the dead neighbor
    healthy = params["w"][1:]
    assert float(jnp.max(jnp.abs(healthy))) < 0.2


def test_straggler_churn_zero_retrace():
    """Any straggler pattern must reuse ONE jitted executable (tentpole
    claim: alive is a step argument, not trace structure)."""
    n, dim = 10, 3
    targets = jnp.zeros((n, dim))
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.0)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=0),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=99)
    params = {"w": jnp.ones((n, dim))}
    rng = np.random.default_rng(0)
    for rnd in range(8):
        # different straggler set every round, incl. recoveries + all-healthy
        alive = (rng.random(n) > 0.3).astype(np.float32)
        if rnd == 3:
            alive[:] = 1.0
        params, _, old2new = trainer.observe_heartbeats(alive, params)
        assert old2new is None
        params, _ = trainer.step(params, _batches(targets, 1), 0.2)
    assert trainer.n_traces == 1, trainer.n_traces


def test_repair_retraces_exactly_once():
    """Membership changes re-jit exactly once; the rounds around them don't."""
    n, dim = 10, 3
    targets = jnp.zeros((n, dim))
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.0)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=0),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=2)
    params = {"w": jnp.ones((n, dim))}
    alive = np.ones(n)
    for _ in range(3):
        params, _, _ = trainer.observe_heartbeats(alive, params)
        params, _ = trainer.step(params, _batches(targets, 1), 0.2)
    assert trainer.n_traces == 1
    alive[4] = 0  # miss 2 heartbeats -> dead at the second observe
    params, _, _ = trainer.observe_heartbeats(alive, params)
    params, _ = trainer.step(params, _batches(targets, 1), 0.2)
    params, _, old2new = trainer.observe_heartbeats(alive, params)
    assert old2new is not None and trainer.n_clients == n - 1
    targets2 = jnp.zeros((n - 1, dim))
    for _ in range(3):
        params, _, _ = trainer.observe_heartbeats(np.ones(n - 1), params)
        params, _ = trainer.step(params, _batches(targets2, 1), 0.2)
    assert trainer.n_traces == 2, trainer.n_traces  # one per membership


def test_old2new_remaps_client_state_through_death():
    """Regression (was: identity old2new): per-client state must follow its
    owner through the survivor compaction, incl. caller-held state."""
    n, dim = 12, 4
    r = np.random.default_rng(1)
    targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.1, momentum=0.5)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=0),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=2)
    # tag each client's params + an "optimizer state" with its owner id
    params = {"w": jnp.tile(jnp.arange(n, dtype=jnp.float32)[:, None],
                            (1, dim))}
    opt_state = {"slot": jnp.arange(n, dtype=jnp.float32) * 100.0}

    alive = np.ones(n)
    alive[[3, 7]] = 0
    trainer.health.observe(alive)  # first miss: stragglers
    params2, opt2, old2new = trainer.observe_heartbeats(alive, params,
                                                        opt_state)
    assert old2new is not None
    survivors = [i for i in range(n) if i not in (3, 7)]
    np.testing.assert_array_equal(np.asarray(params2["w"][:, 0]),
                                  np.asarray(survivors, np.float32))
    np.testing.assert_array_equal(np.asarray(opt2["slot"]),
                                  np.asarray(survivors, np.float32) * 100.0)
    # the map itself: survivors compacted in order, dead -> -1
    expect = -np.ones(n, np.int64)
    expect[survivors] = np.arange(n - 2)
    np.testing.assert_array_equal(old2new, expect)
    # training continues on the survivors
    surv_targets = jnp.asarray(np.asarray(targets)[survivors])
    params2, _ = trainer.step(params2, _batches(surv_targets, 1), 0.1)
    assert params2["w"].shape[0] == n - 2


def test_health_counters_survive_repair():
    """Regression: a survivor mid-way to straggler/death keeps its missed
    count through the repair remap (was: fresh tracker dropped it)."""
    n = 8
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.1, momentum=0.0)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=0),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=3)
    params = {"w": jnp.zeros((n, 2))}
    # client 2 dies (3 misses); client 6 is mid-flight (2 misses so far)
    alive = np.ones(n)
    alive[2] = 0
    trainer.health.observe(alive)
    trainer.health.observe(alive)
    alive[6] = 0
    params, _, old2new = trainer.observe_heartbeats(alive, params)
    assert old2new is not None and old2new[2] == -1
    new6 = old2new[6]
    assert trainer.health.missed[new6] == 1         # carried, not reset
    assert new6 in trainer.health.stragglers()
    # one more miss for (old) client 6 -> it is declared dead, solely
    # because its pre-repair counter survived the remap
    alive2 = np.ones(n - 1)
    alive2[new6] = 0
    trainer.health.observe(alive2)
    trainer.health.observe(alive2)
    assert new6 in trainer.health.dead()


def test_elastic_packed_matches_dense_masked_reference():
    """Acceptance: a scripted FailurePlan through the (packed) elastic
    trainer matches a manual loop using the mix_dense_masked oracle."""
    n, dim = 10, 5
    r = np.random.default_rng(2)
    targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
    cfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.3, momentum=0.5)
    overlay = expander_overlay(n, 4, seed=3)
    trainer = ElasticTrainer(overlay=overlay, loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=99)
    plan = failures.FailurePlan(
        n_clients=n, events=((2, (1,)), (4, (6, 8))))  # stragglers only

    params = {"w": jnp.zeros((n, dim))}
    ref = {"w": jnp.zeros((n, dim))}
    mix_mat = overlay.mixing_matrix()

    def local(p, b):
        def client(pc, bc):
            v = jax.tree.map(jnp.zeros_like, pc)
            pc, _, loss = dfedavg.local_round(pc, v, bc, quad_loss, cfg,
                                             lr=0.3)
            return pc, loss
        return jax.vmap(client)(p, b)

    for rnd in range(6):
        mask = plan.alive_mask(rnd)
        params, _, _ = trainer.observe_heartbeats(mask, params)
        batches = _batches(targets, 2)
        params, _ = trainer.step(params, batches, 0.3)
        ref, _ = local(ref, batches)
        ref = gossip.mix_dense_masked(ref, mix_mat, mask)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(ref["w"]),
                                   rtol=2e-5, atol=2e-5)
    assert trainer.n_traces == 1


def test_delayed_zero_retrace_under_churn_and_plan():
    """Pipelined trainer (gossip_delay=1): straggler churn AND an active
    one-peer round plan must reuse ONE executable — the in-flight snapshot
    is step state, never trace structure."""
    from repro.overlay.plan import OnePeerPlan

    n, dim = 10, 3
    targets = jnp.zeros((n, dim))
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.0)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=0),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=99,
                             engine=engine.GossipEngineConfig(
                                 substrate="stacked", delay=1),
                             plan=OnePeerPlan())
    params = {"w": jnp.ones((n, dim))}
    rng = np.random.default_rng(0)
    for rnd in range(8):
        alive = (rng.random(n) > 0.3).astype(np.float32)
        if rnd == 3:
            alive[:] = 1.0
        params, _, old2new = trainer.observe_heartbeats(alive, params)
        assert old2new is None
        params, _ = trainer.step(params, _batches(targets, 1), 0.2)
    assert trainer.n_traces == 1, trainer.n_traces


def test_delayed_trainer_matches_dense_delayed_reference():
    """Acceptance: the pipelined trainer under scripted straggler churn
    matches a manual loop with the mix_dense_delayed oracle — the delayed
    snapshot is the previous round's post-local-step state, primed with the
    initial params."""
    n, dim = 10, 5
    r = np.random.default_rng(2)
    targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
    cfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.3, momentum=0.5)
    overlay = expander_overlay(n, 4, seed=3)
    trainer = ElasticTrainer(overlay=overlay, loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=99,
                             engine=engine.GossipEngineConfig(
                                 substrate="stacked", delay=1))
    params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
    ref = {"w": params["w"]}
    snap = {"w": params["w"]}          # y_{-1} := initial params
    spec = trainer.spec

    def local(p, b):
        def client(pc, bc):
            v = jax.tree.map(jnp.zeros_like, pc)
            pc, _, loss = dfedavg.local_round(pc, v, bc, quad_loss, cfg,
                                              lr=0.3)
            return pc, loss
        return jax.vmap(client)(p, b)

    rng = np.random.default_rng(0)
    for rnd in range(6):
        mask = (rng.random(n) > 0.25).astype(np.float32)
        if mask.sum() < 2:
            mask[:] = 1.0
        params, _, _ = trainer.observe_heartbeats(mask, params)
        batches = _batches(targets, 2)
        params, _ = trainer.step(params, batches, 0.3)
        w, _ = local(ref, batches)
        ref = gossip.mix_dense_delayed(w, snap, spec, None,
                                       jnp.asarray(mask))
        snap = w
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(ref["w"]),
                                   rtol=2e-5, atol=2e-5)
    assert trainer.n_traces == 1


def test_delayed_inflight_survives_repair():
    """The in-flight snapshot must follow the survivors through splice
    repair by the same old2new row compaction as the params (and the step
    after the repair must run on the remapped snapshot)."""
    n, dim = 12, 4
    r = np.random.default_rng(1)
    targets = jnp.asarray(r.standard_normal((n, dim)), jnp.float32)
    cfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.1, momentum=0.5)
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=0),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=2,
                             engine=engine.GossipEngineConfig(
                                 substrate="stacked", delay=1))
    params = {"w": jnp.asarray(r.standard_normal((n, dim)), jnp.float32)}
    params, _ = trainer.step(params, _batches(targets, 2), 0.1)  # primes
    alive = np.ones(n)
    alive[5] = 0
    params, _, old2new = trainer.observe_heartbeats(alive, params)
    assert old2new is None                       # straggler, not dead yet
    params, _ = trainer.step(params, _batches(targets, 2), 0.1)
    pre = [np.asarray(b) for b in trainer._inflight]
    params, _, old2new = trainer.observe_heartbeats(alive, params)  # dead
    assert old2new is not None and old2new[5] == -1
    survivors = np.arange(n) != 5
    for b_pre, b_post in zip(pre, trainer._inflight):
        assert np.asarray(b_post).shape[0] == n - 1
        np.testing.assert_array_equal(np.asarray(b_post), b_pre[survivors])
    surv_targets = jnp.concatenate([targets[:5], targets[6:]])
    params, _ = trainer.step(params, _batches(surv_targets, 2), 0.1)
    assert params["w"].shape[0] == n - 1
    assert bool(jnp.isfinite(params["w"]).all())
    assert trainer.n_traces == 2                 # one re-jit per membership


def test_failure_plan_and_masks():
    plan = failures.sample_failures(20, 0.2, at_round=5, seed=0)
    assert len(plan.dead_at(4)) == 0
    assert len(plan.dead_at(5)) == 4
    mask = plan.alive_mask(10)
    assert mask.sum() == 16


class TestAttackPlan:
    def test_round_vector_semantics(self):
        plan = failures.AttackPlan(6, events=(
            (0, (1,), "sign_flip", 2.0),
            (3, (4,), "scale", 5.0),
            (5, (1,), "noise", 0.7)))
        v0 = plan.round_vector(0)
        assert v0.shape == (2, 6)
        assert v0[0, 1] == -2.0 and v0[1, 1] == 0.0     # sign_flip: -mag
        assert np.all(v0[0, [0, 2, 3, 4, 5]] == 1.0)    # honest: identity
        v3 = plan.round_vector(3)
        assert v3[0, 4] == 5.0                          # scale joins
        v5 = plan.round_vector(5)
        assert v5[0, 1] == 1.0 and v5[1, 1] == 0.7      # later event overrides
        assert set(plan.attackers_at(2)) == {1}
        assert set(plan.attackers_at(4)) == {1, 4}

    def test_all_honest_vector_is_identity_on_apply(self):
        plan = failures.AttackPlan(4, events=((10, (2,), "scale", 3.0),))
        tree = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(
            (4, 3, 2)), jnp.float32)}
        key = np.array([0, 0], np.uint32)
        out = failures.apply_attack(tree, jnp.asarray(plan.round_vector(0)),
                                    jnp.asarray(key))
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))

    def test_apply_attack_modes(self):
        r = np.random.default_rng(3)
        tree = {"w": jnp.asarray(r.standard_normal((5, 4)), jnp.float32)}
        key = jnp.asarray(np.array([7, 1], np.uint32))
        plan = failures.AttackPlan(5, events=((0, (2,), "sign_flip", 10.0),
                                              (0, (4,), "noise", 2.0)))
        out = failures.apply_attack(tree, jnp.asarray(plan.round_vector(0)),
                                    key)
        w, ow = np.asarray(tree["w"]), np.asarray(out["w"])
        np.testing.assert_allclose(ow[2], -10.0 * w[2], rtol=1e-6)
        np.testing.assert_array_equal(ow[[0, 1, 3]], w[[0, 1, 3]])
        assert not np.allclose(ow[4], w[4])  # noise perturbed
        # same key reproduces, different round key differs
        out2 = failures.apply_attack(tree, jnp.asarray(plan.round_vector(0)),
                                     key)
        np.testing.assert_array_equal(np.asarray(out2["w"]), ow)

    def test_sample_attackers(self):
        plan = failures.sample_attackers(12, 3, mode="scale", magnitude=4.0,
                                         at_round=2, seed=1)
        assert plan.n_clients == 12 and len(plan.attackers_at(2)) == 3
        assert plan.attackers_at(1) == set()
        assert plan.events[0][2] == "scale"


def test_attacker_churn_and_screen_zero_retrace():
    """Tentpole retrace guard: an AttackPlan whose attacker set CHANGES
    mid-run plus an active screen must reuse ONE executable — the (2, n)
    attack vector and the PRNG key are step data, never trace structure."""
    n, dim = 10, 3
    targets = jnp.zeros((n, dim))
    cfg = dfedavg.DFedAvgMConfig(local_steps=1, lr=0.2, momentum=0.0)
    plan = failures.AttackPlan(n, events=(
        (1, (2,), "sign_flip", 5.0),
        (3, (7,), "scale", 10.0),
        (5, (2,), "noise", 1.0)))          # mode changes too
    rng = np.random.default_rng(0)
    for screen, kw in (("norm_clip", {"clip_tau": 3.0}),
                       ("trimmed_mean", {"trim_f": 1})):
        trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=0),
                                 loss_fn=quad_loss, dcfg=cfg,
                                 straggler_rounds=1, failure_rounds=99,
                                 engine=engine.GossipEngineConfig(
                                     substrate="stacked", screen=screen,
                                     **kw),
                                 attack_plan=plan)
        params = {"w": jnp.ones((n, dim))}
        for rnd in range(7):
            alive = (rng.random(n) > 0.2).astype(np.float32)  # churn too
            params, _, old2new = trainer.observe_heartbeats(alive, params)
            assert old2new is None
            params, _ = trainer.step(params, _batches(targets, 1), 0.2)
        assert trainer.n_traces == 1, (screen, trainer.n_traces)
        assert bool(jnp.isfinite(params["w"]).all())


def test_quarantine_evicts_attackers_through_splice_repair():
    """norm_clip telemetry -> suspicion -> quarantine -> the SAME splice
    repair as heartbeat death, with suspicion counters carried through
    old2new and attack-plan columns compacted to the survivors."""
    n, dim = 12, 4
    r = np.random.default_rng(0)
    targets = jnp.zeros((n, dim))
    cfg = dfedavg.DFedAvgMConfig(local_steps=2, lr=0.05, momentum=0.9)
    plan = failures.AttackPlan(n, events=((0, (3, 7), "sign_flip", 30.0),))
    trainer = ElasticTrainer(overlay=expander_overlay(n, 4, seed=1),
                             loss_fn=quad_loss, dcfg=cfg,
                             straggler_rounds=1, failure_rounds=99,
                             engine=engine.GossipEngineConfig(
                                 substrate="stacked", screen="norm_clip",
                                 clip_tau=3.0),
                             attack_plan=plan, quarantine_rounds=3)
    params = {"w": jnp.asarray(r.standard_normal((n, dim)) * 0.1,
                               jnp.float32)}
    repaired_at = None
    for rnd in range(6):
        params, _, old2new = trainer.observe_heartbeats(
            np.ones(trainer.n_clients), params)
        if old2new is not None:
            repaired_at = rnd
            break
        params, _ = trainer.step(
            params, _batches(jnp.zeros((trainer.n_clients, dim)), 2), 0.05)
    # every receiver of 3/7 clips them every round -> suspicion hits the
    # threshold after quarantine_rounds rounds and the repair fires
    assert repaired_at == 3, repaired_at
    assert trainer.repairs[-1]["dead"] == [3, 7]
    assert trainer.repairs[-1]["quarantined"] == [3, 7]
    assert trainer.n_clients == n - 2
    assert params["w"].shape[0] == n - 2
    # suspicion counters followed the survivors through old2new
    assert old2new[3] == -1 and old2new[7] == -1
    survivors = np.asarray(old2new) >= 0
    assert np.all(trainer.health.suspicion < trainer.quarantine_rounds)
    # attack columns compacted: the evicted attackers' scripts are gone
    np.testing.assert_array_equal(trainer._attack_cols,
                                  np.arange(n)[survivors])
    # post-repair rounds run clean (one re-jit for the membership change)
    params, _ = trainer.step(
        params, _batches(jnp.zeros((n - 2, dim)), 2), 0.05)
    assert trainer.n_traces == 2, trainer.n_traces
    assert bool(jnp.isfinite(params["w"]).all())


def test_suspicion_carried_through_remap():
    """A straggling-but-not-quarantined suspect keeps its counter at its
    compacted index when an unrelated client dies."""
    tracker = failures.HealthTracker(8, straggler_rounds=1, failure_rounds=2,
                                     quarantine_rounds=5)
    tracker.observe_suspicion(np.asarray([0, 0, 0, 0, 0, 2, 0, 1]))
    tracker.observe_suspicion(np.asarray([0, 0, 0, 0, 0, 1, 0, 0]))
    np.testing.assert_array_equal(tracker.suspicion,
                                  [0, 0, 0, 0, 0, 2, 0, 1])
    old2new = np.asarray([0, 1, -1, 2, 3, 4, 5, 6])  # client 2 dies
    remapped = tracker.remap(old2new)
    np.testing.assert_array_equal(remapped.suspicion,
                                  [0, 0, 0, 0, 2, 0, 1])
    assert list(remapped.suspects()) == []
